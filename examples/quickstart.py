"""Quickstart: define a workflow in the paper's ConfigMap JSON format
(Listing 1), run it through KubeAdaptor, and inspect the result.

  PYTHONPATH=src python examples/quickstart.py
"""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.dag import make_workflow
from repro.core.runner import run_experiment

# Listing-1-style workflow definition: a diamond DAG of stress tasks.
CONFIGMAP = {
    "0": {"input": [], "output": ["1", "2"],
          "image": ["shanchenggang/task-emulator:latest"],
          "cpuNum": ["1200"], "memNum": ["1200"],
          "args": ["-c", "1", "-m", "100", "-t", "5"]},
    "1": {"input": ["0"], "output": ["3"],
          "image": ["shanchenggang/task-emulator:latest"],
          "cpuNum": ["1200"], "memNum": ["1200"],
          "args": ["-c", "1", "-m", "100", "-t", "5"]},
    "2": {"input": ["0"], "output": ["3"],
          "image": ["shanchenggang/task-emulator:latest"],
          "cpuNum": ["1200"], "memNum": ["1200"],
          "args": ["-c", "1", "-m", "100", "-t", "5"]},
    "3": {"input": ["1", "2"], "output": [],
          "image": ["shanchenggang/task-emulator:latest"],
          "cpuNum": ["1200"], "memNum": ["1200"],
          "args": ["-c", "1", "-m", "100", "-t", "5"]},
}


def main():
    wf = make_workflow("quickstart", json.dumps(CONFIGMAP))
    print(f"workflow: {len(wf.tasks)} tasks, levels={[len(l) for l in wf.levels()]}")

    for engine in ("kubeadaptor", "batchjob", "argo"):
        res = run_experiment(engine, wf, repeats=1, seed=0)
        rec = res.metrics.wf_record(wf.with_instance(0))
        print(f"{engine:12s} lifecycle={rec.lifecycle:7.2f}s "
              f"avg_pod_exec={res.metrics.avg_pod_exec_time('quickstart'):5.2f}s "
              f"order_consistent={res.metrics.order_consistent(wf.with_instance(0))} "
              f"apiserver_calls={res.api_calls}")

    print("\ntask start timeline (KubeAdaptor):")
    res = run_experiment("kubeadaptor", wf, repeats=1, seed=0)
    for t, tid in res.metrics.wf_record(wf.with_instance(0)).starts:
        print(f"  t={t:6.2f}s  start {tid}")


if __name__ == "__main__":
    main()
