"""Batched serving example: prefill a batch of prompts, decode tokens
with the KV cache, report tokens/s — then run the same thing as a
KubeAdaptor serving workflow (prefill pod -> decode pods).

  PYTHONPATH=src python examples/serve_batch.py [--arch qwen2-0.5b]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.dag import Task, Workflow
from repro.core.payloads import fn_payload
from repro.core.runner import run_experiment
from repro.models import RunConfig, build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build(cfg, RunConfig())
    params = model.init(jax.random.PRNGKey(0))
    B, P, G = args.batch, args.prompt_len, args.gen

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    decode = jax.jit(model.decode)

    # ---- plain serving loop ------------------------------------------
    t0 = time.perf_counter()
    logits, cache = model.prefill(params, {"tokens": prompts})
    # grow cache to hold generated tokens
    if "k" in cache:
        pad = ((0, 0), (0, 0), (0, G), (0, 0), (0, 0))
        cache["k"] = jnp.pad(cache["k"], pad)
        cache["v"] = jnp.pad(cache["v"], pad)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(G - 1):
        logits, cache = decode(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    jax.block_until_ready(gen)
    dt = time.perf_counter() - t0
    print(f"generated {B}x{G} tokens in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s greedy, CPU)")
    assert gen.shape == (B, G)
    assert int(cache["pos"]) == P + G - 1

    # ---- same thing as a KubeAdaptor serving workflow ------------------
    results = {}

    def prefill_pod():
        lg, ch = model.prefill(params, {"tokens": prompts})
        results["cache"] = ch
        results["first"] = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        return {"prefill_tokens": int(B * P)}

    def decode_pod():
        ch, tok = results["cache"], results["first"]
        if "k" in ch:
            pad = ((0, 0), (0, 0), (0, G), (0, 0), (0, 0))
            ch["k"], ch["v"] = jnp.pad(ch["k"], pad), jnp.pad(ch["v"], pad)
        toks = [tok]
        for _ in range(G - 1):
            lg, ch = decode(params, ch, {"tokens": toks[-1]})
            toks.append(jnp.argmax(lg, -1).astype(jnp.int32))
        results["gen"] = jnp.concatenate(toks, axis=1)
        return {"generated": int(B * G)}

    tasks = {
        "prefill": Task(id="prefill", outputs=["decode"],
                        payload=fn_payload(prefill_pod), duration_s=1.0),
        "decode": Task(id="decode", inputs=["prefill"],
                       payload=fn_payload(decode_pod), duration_s=2.0),
    }
    wf = Workflow("serve", tasks)
    res = run_experiment("kubeadaptor", wf, repeats=1, payload_mode="real")
    rec = res.metrics.wf_record(wf.with_instance(0))
    print(f"serving workflow lifecycle (virtual): {rec.lifecycle:.1f}s, "
          f"order_consistent={res.metrics.order_consistent(wf.with_instance(0))}")
    assert results["gen"].shape == (B, G)
    print("OK")


if __name__ == "__main__":
    main()
