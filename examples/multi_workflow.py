"""Concurrent multi-workflow run: all four scientific workflows in
flight at once, each in its own namespace, sharing the 6-node cluster —
demonstrates namespace isolation, the resource-gathering admission gate
under contention, and per-workflow order consistency.

  PYTHONPATH=src python examples/multi_workflow.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs.workflows import get_workflow_spec
from repro.core.cluster import Cluster
from repro.core.dag import make_workflow
from repro.core.engine import KubeAdaptorEngine
from repro.core.events import EventRegistry
from repro.core.informer import InformerSet
from repro.core.metrics import MetricsCollector
from repro.core.sim import Sim
from repro.core.volumes import VolumeManager


def main():
    sim = Sim()
    cluster = Cluster(sim, seed=0)
    informers = InformerSet(sim, cluster)
    events = EventRegistry(sim)
    volumes = VolumeManager(sim, cluster)
    metrics = MetricsCollector(sim, cluster)
    engine = KubeAdaptorEngine(sim, cluster, informers, events, volumes,
                               metrics)

    wfs = [make_workflow(n, get_workflow_spec(n))
           for n in ("montage", "epigenomics", "cybershake", "ligo")]
    metrics.start_sampling()
    for w in wfs:                      # all four submitted concurrently
        engine.submit(w)
    sim.run(until=10_000)

    print(f"{'workflow':14s} {'lifecycle':>10s} {'consistent':>11s}")
    peak_cpu = max(c for _, c, _ in metrics.samples)
    for w in wfs:
        rec = metrics.wf_record(w)
        ok = metrics.order_consistent(w)
        print(f"{w.name:14s} {rec.lifecycle:9.1f}s {str(ok):>11s}")
        assert rec.ns_deleted > 0 and ok
    cpu_a, _ = cluster.allocatable()
    print(f"\npeak cluster CPU under contention: {peak_cpu}m / {cpu_a}m "
          f"({peak_cpu / cpu_a:.0%}) — admission gate respected")
    print("OK")


if __name__ == "__main__":
    main()
