"""End-to-end driver: distributed LM training orchestrated BY the
KubeAdaptor engine — the paper's control plane running a real ML
pipeline, with real JAX payloads, checkpointing and a fault injection.

The training DAG (namespace-isolated, data flowing through the shared
volume exactly like the paper's PVC):

    data_prep -> train_phase_1 -> ... -> train_phase_P -> eval

Each train phase runs `steps_per_phase` real jitted train steps and
checkpoints; a mid-run pod failure is injected to show the §4.5 fault
tolerance resuming from the checkpoint.

  PYTHONPATH=src python examples/workflow_train.py            # fast (~2 min)
  PYTHONPATH=src python examples/workflow_train.py --arch qwen2-0.5b \\
      --d-model 768 --layers 12 --steps 300                   # ~100M class
"""
import argparse
import dataclasses
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.core.cluster import Cluster, RUNNING
from repro.core.dag import Task, Workflow
from repro.core.engine import KubeAdaptorEngine
from repro.core.events import EventRegistry
from repro.core.informer import InformerSet
from repro.core.injector import WorkflowInjector
from repro.core.metrics import MetricsCollector
from repro.core.payloads import fn_payload
from repro.core.sim import Sim
from repro.core.volumes import VolumeManager
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import OptConfig, init_state
from repro.runtime.train import TrainRunConfig, build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--phases", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced width (0 = tiny test config)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model,
                                  d_ff=4 * args.d_model, head_dim=64,
                                  n_heads=args.d_model // 64, n_kv_heads=2)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    print(f"arch={cfg.name}  params~{cfg.param_count() / 1e6:.1f}M  "
          f"steps={args.steps} x {args.phases} phases")

    ckpt_dir = tempfile.mkdtemp(prefix="wf_train_")
    ckpt = Checkpointer(ckpt_dir)
    step_fn, *_ , model = build_train_step(
        cfg, None, B=args.batch, S=args.seq,
        trc=TrainRunConfig(opt=OptConfig(lr=3e-4, warmup_steps=20,
                                         total_steps=args.steps)))
    data = iter(SyntheticLM(DataConfig(args.batch, args.seq, cfg.vocab_size)))
    losses = []

    def data_prep():
        # warm the pipeline + write the tokenizer/dataset manifest
        next(data)
        return {"dataset": "synthetic-zipf", "vocab": cfg.vocab_size}

    def make_phase(phase_idx, n_steps):
        def train_phase():
            latest = ckpt.latest_step()
            sds = jax.eval_shape(lambda: init_state(
                model.init(jax.random.PRNGKey(0))))
            if latest is None:
                state = init_state(model.init(jax.random.PRNGKey(0)))
            else:
                state = ckpt.restore(sds)
            start = int(state.step)
            for _ in range(start, min(start + n_steps, args.steps)):
                state, m = step_fn(state, next(data))
                losses.append(float(m["loss"]))
            ckpt.save(state, int(state.step), blocking=True)
            return {"phase": phase_idx, "step": int(state.step),
                    "loss": losses[-1] if losses else None}
        return train_phase

    def evaluate():
        sds = jax.eval_shape(lambda: init_state(model.init(jax.random.PRNGKey(0))))
        state = ckpt.restore(sds)
        batch = next(data)
        loss = float(model.loss(state.params, jax.tree.map(jax.numpy.asarray, batch)))
        return {"eval_loss": loss, "step": int(state.step)}

    per_phase = args.steps // args.phases
    tasks = {"data_prep": Task(id="data_prep", outputs=["phase_1"],
                               payload=fn_payload(data_prep), duration_s=1.0)}
    prev = "data_prep"
    for i in range(1, args.phases + 1):
        tid = f"phase_{i}"
        nxt = f"phase_{i + 1}" if i < args.phases else "eval"
        tasks[tid] = Task(id=tid, inputs=[prev], outputs=[nxt],
                          payload=fn_payload(make_phase(i, per_phase)),
                          duration_s=5.0)
        prev = tid
    tasks["eval"] = Task(id="eval", inputs=[prev], outputs=[],
                         payload=fn_payload(evaluate), duration_s=2.0)
    wf = Workflow("lmtrain", tasks)

    sim = Sim()
    cluster = Cluster(sim, payload_mode="real", seed=0)
    informers = InformerSet(sim, cluster)
    events = EventRegistry(sim)
    volumes = VolumeManager(sim, cluster)
    metrics = MetricsCollector(sim, cluster)
    engine = KubeAdaptorEngine(sim, cluster, informers, events, volumes, metrics)
    injector = WorkflowInjector(sim, engine.submit)
    engine.on_workflow_done = injector.request_next
    injector.load([wf.with_instance(0)])
    injector.start()

    if args.inject_failure:
        # kill the phase-2 pod mid-run: fault tolerance restarts it and the
        # payload resumes from the checkpoint (no lost progress)
        def nuke():
            for p in cluster.list_pods():
                if p.task_id == "phase_2" and p.phase == RUNNING:
                    print("!! injecting pod failure on phase_2")
                    cluster.fail_pod(p.namespace, p.name)
                    return
            sim.after(1.0, nuke)
        sim.after(8.0, nuke)

    sim.run(until=1e9)
    rec = metrics.wf_record(wf.with_instance(0))
    vol_summary = {}
    print(f"\nworkflow lifecycle (virtual): {rec.lifecycle:.1f}s  "
          f"retries={rec.retries}")
    print(f"order consistent: {metrics.order_consistent(wf.with_instance(0))}")
    print(f"steps completed: {ckpt.latest_step()}  "
          f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training did not descend"
    print("OK")


if __name__ == "__main__":
    main()
