"""Paper Fig 6 + Fig 1: scheduling-order consistency per workflow.

Reports, per real-world workflow: one KubeAdaptor sample lifecycle (the
paper's Fig 6 timelines: 127.129 / 99.182 / 78.939 / 92.361 s), whether
execution order was a dependency-consistent topological linearization,
and — as the motivation — the dependency-violation count of raw
direct-to-scheduler submission (Fig 1)."""
import time

from benchmarks.common import ALL_WF, row, wf
from repro.core.runner import run_experiment


def _violations(metrics, workflow) -> int:
    rec = metrics.wf_record(workflow)
    out = 0
    for ts, tid in rec.starts:
        for dep in workflow.tasks[tid].inputs:
            if rec.finishes.get(dep, 1e18) > ts + 1e-9:
                out += 1
    return out


def run():
    rows = []
    fig6 = {"montage": 127.129, "epigenomics": 99.182,
            "cybershake": 78.939, "ligo": 92.361}
    for name in ALL_WF:
        w = wf(name)
        t0 = time.perf_counter()
        res = run_experiment("kubeadaptor", w, repeats=1, seed=42)
        wall = (time.perf_counter() - t0) * 1e6
        ok = res.metrics.order_consistent(w.with_instance(0))
        life = res.metrics.wf_record(w.with_instance(0)).lifecycle
        rows.append(row(
            f"fig6_consistency_{name}", wall,
            f"consistent={ok};lifecycle_s={life:.3f};paper_s={fig6[name]}"))
        direct = run_experiment("direct", w, repeats=1, seed=42)
        v = _violations(direct.metrics, w.with_instance(0))
        rows.append(row(
            f"fig1_direct_submit_{name}", wall,
            f"violations={v};consistent={direct.metrics.order_consistent(w.with_instance(0))}"))
    return rows
