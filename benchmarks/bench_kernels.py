"""Kernel micro-benchmarks (CPU): pure-jnp production paths vs the
interpret-mode Pallas kernels + correctness deltas vs the oracles.
Interpret mode measures correctness, not TPU speed — the derived field
carries the max-abs error, which is the signal that matters here."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked


def _timeit(fn, n=5):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 512, 4, 64
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32)
               for kk in jax.random.split(key, 3))
    expect = ref.attention_ref(q, k, v, causal=True)

    us = _timeit(lambda: chunked_attention(q, k, v, chunk=128, causal=True))
    err = float(jnp.abs(chunked_attention(q, k, v, chunk=128) - expect).max())
    rows.append(row("kernel_attn_jnp_chunked_512", us, f"max_err={err:.2e}"))

    us = _timeit(lambda: flash_attention(q, k, v, causal=True,
                                         interpret=True), n=2)
    err = float(jnp.abs(flash_attention(q, k, v, interpret=True) - expect).max())
    rows.append(row("kernel_attn_pallas_interpret_512", us,
                    f"max_err={err:.2e};note=interpret-mode-correctness"))

    b, s, h, p, n = 2, 256, 4, 16, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y_ref, st_ref = ref.ssd_ref(x, dt, A, Bm, Cm)

    us = _timeit(lambda: ssd_chunked(x, dt, A, Bm, Cm, 64))
    err = float(jnp.abs(ssd_chunked(x, dt, A, Bm, Cm, 64)[0] - y_ref).max())
    rows.append(row("kernel_ssd_jnp_chunked_256", us, f"max_err={err:.2e}"))

    us = _timeit(lambda: ssd_scan(x, dt, A, Bm, Cm, chunk=64,
                                  interpret=True), n=2)
    err = float(jnp.abs(ssd_scan(x, dt, A, Bm, Cm, chunk=64,
                                 interpret=True)[0] - y_ref).max())
    rows.append(row("kernel_ssd_pallas_interpret_256", us,
                    f"max_err={err:.2e};note=interpret-mode-correctness"))
    return rows
