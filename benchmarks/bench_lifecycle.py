"""Paper Fig 8 + §5.3 totals: average workflow lifecycle over 100
consecutive runs per engine per workflow (the paper's exact protocol —
the virtual clock makes 100 runs take milliseconds of wall time)."""
import time

from benchmarks.common import ALL_WF, ENGINES, PAPER, row, wf
from repro.core.runner import run_experiment

REPEATS = 100


def run():
    rows = []
    for name in ALL_WF:
        w = wf(name)
        life, total = {}, {}
        wall = 0.0
        for eng in ENGINES:
            t0 = time.perf_counter()
            res = run_experiment(eng, w, repeats=REPEATS, seed=3)
            wall += (time.perf_counter() - t0) * 1e6
            life[eng] = res.metrics.avg_lifecycle(name)
            total[eng] = res.metrics.total_time(name)
        red = 1 - life["kubeadaptor"] / life["argo"]
        p = PAPER["lifecycle"][name]
        rows.append(row(
            f"fig8_lifecycle_{name}", wall / len(ENGINES),
            f"kube_s={life['kubeadaptor']:.2f};batch_s={life['batchjob']:.2f};"
            f"argo_s={life['argo']:.2f};paper={p['kubeadaptor']}/"
            f"{p['batchjob']}/{p['argo']};reduction_vs_argo={red:.4f};"
            f"paper_reduction={PAPER['lifecycle_reduction_vs_argo'][name]}"))
        pt = PAPER["total_100_runs"][name]
        rows.append(row(
            f"sec53_total_100runs_{name}", wall / len(ENGINES),
            f"kube_s={total['kubeadaptor']:.0f};batch_s={total['batchjob']:.0f};"
            f"argo_s={total['argo']:.0f};paper={pt['kubeadaptor']:.0f}/"
            f"{pt['batchjob']:.0f}/{pt['argo']:.0f}"))
    return rows
