"""Regenerate the §Roofline table inside EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python -m benchmarks.make_report
"""
import json
import re
from pathlib import Path

ART = Path("artifacts/dryrun")
EXP = Path("EXPERIMENTS.md")


def table() -> str:
    rows = []
    for p in sorted(ART.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("tag") or d.get("mesh") != "pod16x16":
            continue
        if d["status"] == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — | — | "
                        f"skipped: full-attention @500k |")
            continue
        if d["status"] != "ok":
            rows.append(f"| {d['arch']} | {d['shape']} | ERROR {d.get('error','')} |")
            continue
        r = d["roofline"]
        ma = d["memory_analysis"]
        rows.append(
            f"| {d['arch']} | {d['shape']} "
            f"| {r['compute_s'] * 1e3:.1f} | {r['memory_s'] * 1e3:.1f} "
            f"| {r['collective_s'] * 1e3:.1f} | {r['dominant'].replace('_s', '')} "
            f"| {d['useful_flops_ratio']:.3f} | {d['roofline_fraction']:.4f} "
            f"| {ma['peak_bytes_per_device'] / 1e9:.1f} GB |")
    hdr = ("| arch | shape | compute ms | memory ms | collective ms | dominant "
           "| 6ND/HLO | roofline frac | peak/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def variants_table() -> str:
    rows = []
    for p in sorted(ART.glob("*__*__pod16x16__*.json")):
        d = json.loads(p.read_text())
        if d["status"] != "ok":
            rows.append(f"| {d['arch']}:{d['shape']} | {d['tag']} | FAILED |")
            continue
        r = d["roofline"]
        ma = d["memory_analysis"]
        rows.append(
            f"| {d['arch']}:{d['shape']} | {d['tag']} "
            f"| {r['compute_s'] * 1e3:.0f} | {r['memory_s'] * 1e3:.0f} "
            f"| {r['collective_s'] * 1e3:.0f} | {d['roofline_fraction']:.4f} "
            f"| {ma['peak_bytes_per_device'] / 1e9:.1f} GB |")
    hdr = ("| cell | variant | compute ms | memory ms | collective ms "
           "| frac | peak/dev |\n|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main():
    text = EXP.read_text()
    text = re.sub(r"<!-- ROOFLINE_TABLE_BEGIN -->.*?<!-- ROOFLINE_TABLE_END -->",
                  "<!-- ROOFLINE_TABLE_BEGIN -->\n" + table()
                  + "\n<!-- ROOFLINE_TABLE_END -->", text, flags=re.S)
    text = re.sub(r"<!-- VARIANTS_TABLE_BEGIN -->.*?<!-- VARIANTS_TABLE_END -->",
                  "<!-- VARIANTS_TABLE_BEGIN -->\n" + variants_table()
                  + "\n<!-- VARIANTS_TABLE_END -->", text, flags=re.S)
    EXP.write_text(text)
    print("EXPERIMENTS.md updated;",
          len(list(ART.glob("*.json"))), "artifacts")


if __name__ == "__main__":
    main()
