"""Engine micro-latencies (real wall time, not virtual): the mechanism
costs behind the paper's win — event dispatch vs polling, informer cache
reads vs apiserver round-trips, DAG scheduling throughput."""
import time

from benchmarks.common import row, wf
from repro.core.cluster import Cluster
from repro.core.dag import Task, Workflow, add_virtual_entry_exit
from repro.core.events import EventRegistry
from repro.core.informer import InformerSet
from repro.core.sim import Sim


def _bench(fn, n=1000):
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []

    # event registry dispatch
    sim = Sim()
    ev = EventRegistry(sim)
    hits = []
    ev.register("x", lambda: hits.append(1))

    def emit_and_drain():
        ev.emit("x")
        sim.run()

    us = _bench(emit_and_drain, 2000)
    rows.append(row("micro_event_dispatch", us, f"dispatches={len(hits)}"))

    # informer cache read vs cluster list (the apiserver-pressure delta)
    sim = Sim()
    cluster = Cluster(sim)
    informers = InformerSet(sim, cluster)
    from repro.core.cluster import PodObj
    cluster.create_namespace("bench")
    sim.run()
    for i in range(200):
        cluster.create_pod(PodObj(name=f"p{i}", namespace="bench",
                                  task_id=f"p{i}", workflow="bench",
                                  cpu_m=1, mem_mi=1, duration_s=1e9))
    sim.run(until=sim.now() + 5)
    us_lister = _bench(lambda: informers.pods.lister("bench"), 2000)
    us_api = _bench(lambda: cluster.list_pods("bench"), 2000)
    rows.append(row("micro_informer_lister_read", us_lister,
                    f"pods={len(informers.pods.cache)}"))
    rows.append(row("micro_apiserver_list", us_api,
                    "plus_simulated_50ms_rtt_per_call_in_virtual_time"))

    # level-1 scheduler throughput on a 1000-task DAG
    tasks = {}
    for i in range(1000):
        deps = [f"t{i - 1}"] if i and i % 7 else []
        tasks[f"t{i}"] = Task(id=f"t{i}", inputs=deps, duration_s=1.0)
    for t in tasks.values():
        for d in t.inputs:
            tasks[d].outputs.append(t.id)
    big = Workflow("big", add_virtual_entry_exit(tasks))
    us_topo = _bench(lambda: big.topo_order(), 50)
    us_lv = _bench(lambda: big.levels(), 50)
    rows.append(row("micro_topo_order_1000tasks", us_topo, "tasks=1002"))
    rows.append(row("micro_levels_1000tasks", us_lv, "tasks=1002"))

    # full sim throughput: events per second of one montage run
    w = wf("montage")
    from repro.core.runner import run_experiment
    t0 = time.perf_counter()
    run_experiment("kubeadaptor", w, repeats=5, seed=0)
    wall = time.perf_counter() - t0
    rows.append(row("micro_sim_montage_x5_wall", wall * 1e6,
                    f"virtual_to_wall_speedup={5 * 130 / max(wall, 1e-9):.0f}x"))
    return rows
