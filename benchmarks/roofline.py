"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (produced by `python -m repro.launch.dryrun`)
and emits, per (arch x shape x mesh): the three roofline terms in
seconds, the dominant term, MODEL_FLOPS/HLO_FLOPs, and the roofline
fraction. Missing artifacts are reported, not silently skipped."""
import json
from pathlib import Path

from benchmarks.common import row

ART = Path("artifacts/dryrun")


def load_cells(mesh: str = None, tag: str = ""):
    cells = []
    if not ART.exists():
        return cells
    for p in sorted(ART.glob("*.json")):
        d = json.loads(p.read_text())
        if mesh and d.get("mesh") != mesh:
            continue
        if (d.get("tag") or "") != tag:
            continue
        cells.append(d)
    return cells


def run():
    rows = []
    cells = load_cells(mesh="pod16x16")
    if not cells:
        return [row("roofline_missing", 0.0,
                    "run: PYTHONPATH=src python -m repro.launch.dryrun "
                    "--arch all --shape all --mesh both")]
    n_ok = n_skip = 0
    for d in cells:
        name = f"roofline_{d['arch']}__{d['shape']}"
        if d["status"] == "skipped":
            n_skip += 1
            rows.append(row(name, 0.0, "skipped=long_500k-needs-subquadratic"))
            continue
        if d["status"] != "ok":
            rows.append(row(name, 0.0, f"ERROR={d.get('error', '?')}"))
            continue
        n_ok += 1
        r = d["roofline"]
        rows.append(row(
            name, d["compile_s"] * 1e6,
            f"compute_ms={r['compute_s'] * 1e3:.2f};"
            f"memory_ms={r['memory_s'] * 1e3:.2f};"
            f"collective_ms={r['collective_s'] * 1e3:.2f};"
            f"dominant={r['dominant']};"
            f"useful_ratio={d['useful_flops_ratio']:.3f};"
            f"roofline_frac={d['roofline_fraction']:.4f};"
            f"peak_gb_per_dev={d['memory_analysis']['peak_bytes_per_device'] / 1e9:.2f}"))
    rows.append(row("roofline_summary", 0.0,
                    f"ok={n_ok};skipped={n_skip};mesh=pod16x16"))
    multi = [d for d in load_cells(mesh="pod2x16x16") if d["status"] == "ok"]
    rows.append(row("multipod_dryrun_summary", 0.0,
                    f"ok={len(multi)};mesh=pod2x16x16;proof=pod-axis-shards"))
    return rows
