"""Shared benchmark helpers."""
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.configs.workflows import WORKFLOWS, get_workflow_spec  # noqa: E402
from repro.core.dag import make_workflow  # noqa: E402

PAPER = {
    "lifecycle": {
        "montage": {"kubeadaptor": 129.85, "batchjob": 169.83, "argo": 229.57},
        "epigenomics": {"kubeadaptor": 111.12, "batchjob": 162.34, "argo": 197.18},
        "cybershake": {"kubeadaptor": 83.36, "batchjob": 125.44, "argo": 151.19},
        "ligo": {"kubeadaptor": 92.46, "batchjob": 143.80, "argo": 181.22},
    },
    "exec_kube": {"montage": 12.82, "epigenomics": 12.49,
                  "cybershake": 12.67, "ligo": 12.84},
    "exec_reduction_vs_argo": {"montage": 0.2445, "epigenomics": 0.4757,
                               "cybershake": 0.2372, "ligo": 0.2465},
    "lifecycle_reduction_vs_argo": {"montage": 0.4344, "epigenomics": 0.4365,
                                    "cybershake": 0.4486, "ligo": 0.4898},
    "total_100_runs": {
        "montage": {"kubeadaptor": 14081.86, "batchjob": 16976.73, "argo": 22942.3},
        "epigenomics": {"kubeadaptor": 12282.02, "batchjob": 16222.06, "argo": 19712.66},
        "cybershake": {"kubeadaptor": 9472.07, "batchjob": 12532.18, "argo": 15108.25},
        "ligo": {"kubeadaptor": 10356.19, "batchjob": 14373.86, "argo": 18117.57},
    },
}

ALL_WF = sorted(WORKFLOWS)
ENGINES = ("kubeadaptor", "batchjob", "argo")


def wf(name):
    return make_workflow(name, get_workflow_spec(name))


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
