"""Multi-tenant control plane sweep: tenants x arrival process x
admission policy (beyond-paper; the serialized paper experiment is one
point of this space).

Each scenario runs N identical tenants of wide fan-out workflows on a
2-node cluster (admission-bound), and reports per-policy makespan
spread, queueing delay, and deferral counts.  Since ISSUE 4 the sweep
runs on the PR-3 fast core (event-driven usage accounting, streaming
metrics, no pod log) and covers the pipeline policies: the ``drf``
ordering joins the legacy three, the ``fairness`` rows report the
bound-CPU ratio between a weight-3 and a weight-1 tenant (~1 under
fifo, >1.5 under fair-share — from the exact usage step functions, not
the 0.5 s sampler), and two pipeline rows exercise the new stages:
``mt_quota_caps`` (hard cap on one tenant: quota rejects, exact peak
vs cap) and ``mt_preempt`` (starved high-priority tenant evicting
batch pods: preemption count, SLO hit-rates).  Row schema:
benchmarks/README.md §Multi-tenant sweep.
"""
import time

from benchmarks.common import row, wf
from repro.configs.workflows import wide_fanout
from repro.core import calibration as cal
from repro.core.dag import make_workflow
from repro.core.runner import ControlPlane

POLICIES = ("fifo", "priority", "fair-share", "drf")
ARRIVALS = ("serial", "concurrent", "poisson")
TENANT_COUNTS = (2, 4)
SMALL_CLUSTER = cal.PaperCluster(n_nodes=2)

# PR-3 fast-core knobs (exactness vs the sampled/full mode is pinned by
# tests/test_event_core.py; decisions are bit-identical)
FAST_KW = dict(usage_mode="event", sample_mode="streaming",
               retain_pod_log=False)


def wide_wf(name):
    return make_workflow(name, wide_fanout())


def _stream_kwargs(arrival, i):
    if arrival == "serial":
        return {"arrival": "serial"}
    if arrival == "concurrent":
        return {"arrival": "concurrent", "concurrency": 2}
    return {"arrival": "poisson", "rate": 0.05, "burst": 1}


def sweep(n_tenants, arrival, policy, repeats=3, seed=7):
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=SMALL_CLUSTER, seed=seed, **FAST_KW)
    for i in range(n_tenants):
        plane.add_stream(wide_wf(f"t{i}"), repeats=repeats,
                         tenant=f"tenant{i}", priority=n_tenants - i,
                         weight=float(n_tenants - i),
                         **_stream_kwargs(arrival, i))
    res = plane.run(horizon_s=500_000)
    return res


def run():
    rows = []
    for n in TENANT_COUNTS:
        for arrival in ARRIVALS:
            for policy in POLICIES:
                t0 = time.perf_counter()
                res = sweep(n, arrival, policy)
                wall = (time.perf_counter() - t0) * 1e6
                s = res.metrics.tenant_summary()
                spans = [s[t]["makespan"] for t in sorted(s)]
                delays = [s[t]["avg_queue_delay"] for t in sorted(s)]
                rows.append(row(
                    f"mt_{n}tenants_{arrival}_{policy}", wall,
                    f"makespan_max_s={max(spans):.1f};"
                    f"makespan_min_s={min(spans):.1f};"
                    f"avg_queue_delay_s={sum(delays)/len(delays):.2f};"
                    f"deferrals={res.arbiter.deferrals};"
                    f"admitted={res.arbiter.admitted}"))

    # fairness focus: weight-3 vs weight-1 contended-CPU ratio per
    # policy, from the exact event-driven contention tracker (~1 under
    # fifo, >1.5 under fair-share — same semantics the 0.5 s sampler
    # used to approximate)
    for policy in POLICIES:
        t0 = time.perf_counter()
        plane = ControlPlane("kubeadaptor", admission_policy=policy,
                             cluster_cfg=SMALL_CLUSTER, seed=5, **FAST_KW)
        plane.add_stream(wide_wf("heavy"), repeats=4, tenant="heavy",
                         arrival="concurrent", concurrency=2,
                         weight=3.0, priority=10)
        plane.add_stream(wide_wf("light"), repeats=4, tenant="light",
                         arrival="concurrent", concurrency=2,
                         weight=1.0, priority=0)
        plane.metrics.track_contention(["heavy", "light"])
        res = plane.run(horizon_s=500_000)
        wall = (time.perf_counter() - t0) * 1e6
        avg = res.metrics.contended_cpu(["heavy", "light"])
        ratio = avg["heavy"] / max(avg["light"], 1) if avg else float("nan")
        s = res.metrics.tenant_summary()
        rows.append(row(
            f"mt_fairness_{policy}", wall,
            f"cpu_ratio_3to1={ratio:.2f};"
            f"heavy_makespan_s={s['heavy']['makespan']:.1f};"
            f"light_makespan_s={s['light']['makespan']:.1f}"))

    # dominant-resource focus: a memory-hog vs a cpu-hog tenant —
    # cpu-only fair-share over-serves the memory hog (it always looks
    # cpu-underserved); drf ranks it by its dominant (memory) share
    def hog(name, cpu_m, mem_mi, width=10):
        return make_workflow(name, {
            str(i): {"input": [], "output": [], "cpuNum": [str(cpu_m)],
                     "memNum": [str(mem_mi)],
                     "args": ["-c", "1", "-m", "100", "-t", "5"]}
            for i in range(width)})

    for policy in ("fair-share", "drf"):
        t0 = time.perf_counter()
        plane = ControlPlane("kubeadaptor", admission_policy=policy,
                             cluster_cfg=SMALL_CLUSTER, seed=3, **FAST_KW)
        plane.add_stream(hog("memhog", 200, 4000), repeats=3, tenant="mem",
                         arrival="concurrent", concurrency=2)
        plane.add_stream(hog("cpuhog", 1500, 300), repeats=3, tenant="cpu",
                         arrival="concurrent", concurrency=2)
        res = plane.run(horizon_s=500_000)
        wall = (time.perf_counter() - t0) * 1e6
        s = res.metrics.tenant_summary()
        rows.append(row(
            f"mt_mixed_hogs_{policy}", wall,
            f"mem_tenant_mean_mem_mi={res.metrics.tenant_mean_mem('mem'):.0f};"
            f"cpu_tenant_mean_cpu_m={res.metrics.tenant_mean_cpu('cpu'):.0f};"
            f"mem_makespan_s={s['mem']['makespan']:.1f};"
            f"cpu_makespan_s={s['cpu']['makespan']:.1f}"))

    # pipeline stages (ISSUE 4): hard quota caps ...
    t0 = time.perf_counter()
    plane = ControlPlane("kubeadaptor", admission_policy="quota",
                         cluster_cfg=SMALL_CLUSTER, seed=5, **FAST_KW)
    plane.add_stream(wide_wf("capped"), repeats=4, tenant="capped",
                     arrival="concurrent", concurrency=2, quota_cpu_m=4800)
    plane.add_stream(wide_wf("free"), repeats=4, tenant="free",
                     arrival="concurrent", concurrency=2)
    res = plane.run(horizon_s=500_000)
    wall = (time.perf_counter() - t0) * 1e6
    s = res.metrics.tenant_summary()
    rows.append(row(
        "mt_quota_caps", wall,
        f"quota_cpu_m=4800;"
        f"capped_peak_cpu_m={res.metrics.tenant_cpu_accs['capped'].peak:.0f};"
        f"quota_rejects={res.arbiter.quota_rejects};"
        f"capped_makespan_s={s['capped']['makespan']:.1f};"
        f"free_makespan_s={s['free']['makespan']:.1f}"))

    # ... and priority preemption with per-stream SLO tracking
    t0 = time.perf_counter()
    plane = ControlPlane("kubeadaptor", admission_policy="preempt",
                         cluster_cfg=SMALL_CLUSTER, seed=7, **FAST_KW)
    plane.add_stream(wide_wf("batch"), repeats=3, tenant="batch",
                     arrival="concurrent", concurrency=2, priority=0,
                     deadline_s=500.0)
    plane.add_stream(wf("montage"), repeats=2, tenant="prod",
                     arrival="poisson", rate=0.2, burst=2, priority=10,
                     deadline_s=160.0)
    res = plane.run(horizon_s=500_000)
    wall = (time.perf_counter() - t0) * 1e6
    s = res.metrics.tenant_summary()
    rows.append(row(
        "mt_preempt", wall,
        f"preemptions={res.arbiter.preemptions};"
        f"batch_preempted={s['batch']['preempted']:.0f};"
        f"prod_slo_hit_rate={s['prod']['deadline_hit_rate']:.2f};"
        f"batch_slo_hit_rate={s['batch']['deadline_hit_rate']:.2f};"
        f"prod_makespan_s={s['prod']['makespan']:.1f}"))

    # paper workflows as a multi-tenant mix (sanity: realistic DAGs)
    t0 = time.perf_counter()
    plane = ControlPlane("kubeadaptor", admission_policy="fair-share", seed=3,
                         **FAST_KW)
    for i, name in enumerate(("montage", "cybershake")):
        plane.add_stream(wf(name), repeats=3, tenant=f"paper{i}",
                         arrival="concurrent", concurrency=2)
    res = plane.run(horizon_s=500_000)
    wall = (time.perf_counter() - t0) * 1e6
    s = res.metrics.tenant_summary()
    rows.append(row(
        "mt_paper_mix_fair_share", wall,
        ";".join(f"{t}_makespan_s={s[t]['makespan']:.1f}" for t in sorted(s))))
    return rows
