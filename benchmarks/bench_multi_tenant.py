"""Multi-tenant control plane sweep: tenants x arrival process x
admission policy (beyond-paper; the serialized paper experiment is one
point of this space).

Each scenario runs N identical tenants of wide fan-out workflows on a
2-node cluster (admission-bound), and reports per-policy makespan
spread, queueing delay, and deferral counts. The ``fairness`` rows
additionally report the contended-CPU ratio between a weight-3 tenant
and a weight-1 tenant — ~1 under fifo, >1.5 under fair-share.
"""
import time

from benchmarks.common import row, wf
from repro.configs.workflows import wide_fanout
from repro.core import calibration as cal
from repro.core.dag import make_workflow
from repro.core.runner import ControlPlane

POLICIES = ("fifo", "priority", "fair-share")
ARRIVALS = ("serial", "concurrent", "poisson")
TENANT_COUNTS = (2, 4)
SMALL_CLUSTER = cal.PaperCluster(n_nodes=2)


def wide_wf(name):
    return make_workflow(name, wide_fanout())


def _stream_kwargs(arrival, i):
    if arrival == "serial":
        return {"arrival": "serial"}
    if arrival == "concurrent":
        return {"arrival": "concurrent", "concurrency": 2}
    return {"arrival": "poisson", "rate": 0.05, "burst": 1}


def sweep(n_tenants, arrival, policy, repeats=3, seed=7):
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=SMALL_CLUSTER, seed=seed)
    for i in range(n_tenants):
        plane.add_stream(wide_wf(f"t{i}"), repeats=repeats,
                         tenant=f"tenant{i}", priority=n_tenants - i,
                         weight=float(n_tenants - i),
                         **_stream_kwargs(arrival, i))
    res = plane.run(horizon_s=500_000)
    return res


def run():
    rows = []
    for n in TENANT_COUNTS:
        for arrival in ARRIVALS:
            for policy in POLICIES:
                t0 = time.perf_counter()
                res = sweep(n, arrival, policy)
                wall = (time.perf_counter() - t0) * 1e6
                s = res.metrics.tenant_summary()
                spans = [s[t]["makespan"] for t in sorted(s)]
                delays = [s[t]["avg_queue_delay"] for t in sorted(s)]
                rows.append(row(
                    f"mt_{n}tenants_{arrival}_{policy}", wall,
                    f"makespan_max_s={max(spans):.1f};"
                    f"makespan_min_s={min(spans):.1f};"
                    f"avg_queue_delay_s={sum(delays)/len(delays):.2f};"
                    f"deferrals={res.arbiter.deferrals};"
                    f"admitted={res.arbiter.admitted}"))

    # fairness focus: weight-3 vs weight-1 contended CPU ratio per policy
    for policy in POLICIES:
        t0 = time.perf_counter()
        plane = ControlPlane("kubeadaptor", admission_policy=policy,
                             cluster_cfg=SMALL_CLUSTER, seed=5)
        plane.add_stream(wide_wf("heavy"), repeats=4, tenant="heavy",
                         arrival="concurrent", concurrency=2,
                         weight=3.0, priority=10)
        plane.add_stream(wide_wf("light"), repeats=4, tenant="light",
                         arrival="concurrent", concurrency=2,
                         weight=1.0, priority=0)
        res = plane.run(horizon_s=500_000)
        wall = (time.perf_counter() - t0) * 1e6
        avg = res.metrics.contended_cpu(["heavy", "light"])
        ratio = avg["heavy"] / max(avg["light"], 1) if avg else float("nan")
        s = res.metrics.tenant_summary()
        rows.append(row(
            f"mt_fairness_{policy}", wall,
            f"cpu_ratio_3to1={ratio:.2f};"
            f"heavy_makespan_s={s['heavy']['makespan']:.1f};"
            f"light_makespan_s={s['light']['makespan']:.1f}"))

    # paper workflows as a multi-tenant mix (sanity: realistic DAGs)
    t0 = time.perf_counter()
    plane = ControlPlane("kubeadaptor", admission_policy="fair-share", seed=3)
    for i, name in enumerate(("montage", "cybershake")):
        plane.add_stream(wf(name), repeats=3, tenant=f"paper{i}",
                         arrival="concurrent", concurrency=2)
    res = plane.run(horizon_s=500_000)
    wall = (time.perf_counter() - t0) * 1e6
    s = res.metrics.tenant_summary()
    rows.append(row(
        "mt_paper_mix_fair_share", wall,
        ";".join(f"{t}_makespan_s={s[t]['makespan']:.1f}" for t in sorted(s))))
    return rows
