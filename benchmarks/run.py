"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. us_per_call is real wall time
of the benchmark harness; the paper's (virtual-clock) seconds live in
the derived field next to the published numbers they reproduce.

  PYTHONPATH=src python -m benchmarks.run [--only <substr>]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    import importlib

    # modules are imported lazily so e.g. `--only multi_tenant` (pure
    # control-plane DES) never imports the jax-dependent kernel benches
    modules = [
        ("consistency", "bench_consistency"),
        ("task_exec", "bench_task_exec"),
        ("lifecycle", "bench_lifecycle"),
        ("resource_usage", "bench_resource_usage"),
        ("engine_micro", "bench_engine_micro"),
        ("schedulers", "bench_schedulers"),
        ("multi_tenant", "bench_multi_tenant"),
        ("scale", "bench_scale"),
        ("kernels", "bench_kernels"),
        ("roofline", "roofline"),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, modname in modules:
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{modname}")
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue
            failed += 1
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
