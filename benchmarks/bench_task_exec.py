"""Paper Fig 7: average task-pod execution time, 3 engines x 4 workflows.

The paper's headline: KubeAdaptor 12.82/12.49/12.67/12.84 s and
24.45/47.57/23.72/24.65 % reductions vs Argo."""
import time

from benchmarks.common import ALL_WF, ENGINES, PAPER, row, wf
from repro.core.runner import run_experiment

REPEATS = 20


def run():
    rows = []
    for name in ALL_WF:
        w = wf(name)
        ex = {}
        wall = 0.0
        for eng in ENGINES:
            t0 = time.perf_counter()
            res = run_experiment(eng, w, repeats=REPEATS, seed=5)
            wall += (time.perf_counter() - t0) * 1e6
            ex[eng] = res.metrics.avg_pod_exec_time(name)
        red = 1 - ex["kubeadaptor"] / ex["argo"]
        rows.append(row(
            f"fig7_task_exec_{name}", wall / len(ENGINES),
            f"kube_s={ex['kubeadaptor']:.2f};batch_s={ex['batchjob']:.2f};"
            f"argo_s={ex['argo']:.2f};paper_kube_s={PAPER['exec_kube'][name]};"
            f"reduction_vs_argo={red:.4f};"
            f"paper_reduction={PAPER['exec_reduction_vs_argo'][name]}"))
    return rows
