"""Scale-out stress tier: 1000 workflows / 100 nodes through the
multi-tenant ControlPlane (ROADMAP's "1000-workflow stress scenario").

Eight streams (two tenants per paper topology) drive the full
KubeAdaptor stack — gateway, admission arbiter, informers, disordered
scheduler — on a synthetic ``PaperCluster`` scaled to ``--nodes``.
Each topology contributes a closed-loop "prod" tenant (concurrent
arrivals, priority 10, fair-share weight 3) and an open-loop "batch"
tenant (Poisson surge, the whole queue arriving in the first ~minute),
so the admission backlog grows to thousands of pending requests while
interactive load keeps flowing — the arrival-trace regime the ROADMAP
targets. Per admission policy the run records real wall-clock, sim
events/sec, peak pending depths (admission queue + unbound pods),
per-tenant makespan, and peak RSS, then writes everything to
``BENCH_scale.json`` (schema: benchmarks/README.md).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_scale \
        [--workflows 1000] [--nodes 100] [--seed 42] \
        [--policies fifo,priority,fair-share] [--out BENCH_scale.json] \
        [--budget-s 0]

``--budget-s`` exits non-zero when total wall time exceeds the budget —
the CI smoke job uses it to fail the build on event-core regressions.
The module's ``run()`` (for ``benchmarks.run``) executes a reduced
50-workflow/20-node smoke variant of the same scenario.

The script runs unmodified against the pre-optimization core (counters
it introduced are read via getattr) so speedups can be measured by
checking out two revisions and comparing ``wall_s``.
"""
import argparse
import inspect
import json
import platform
import resource
import sys
import time

from benchmarks.common import row
from repro.configs.workflows import get_workflow_spec
from repro.core import calibration as cal
from repro.core.dag import make_workflow
from repro.core.runner import ControlPlane

TOPOLOGIES = ("montage", "epigenomics", "cybershake", "ligo")
POLICIES = ("fifo", "priority", "fair-share")
SCHEMA = "bench_scale/v1"


def _plane_kwargs():
    """Knobs that only the optimized core understands."""
    params = inspect.signature(ControlPlane.__init__).parameters
    kw = {}
    if "sample_mode" in params:
        kw["sample_mode"] = "streaming"
    if "retain_pod_log" in params:
        kw["retain_pod_log"] = False
    return kw


def build_plane(policy, n_workflows, n_nodes, seed):
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=cal.PaperCluster(n_nodes=n_nodes),
                         seed=seed, **_plane_kwargs())
    n_streams = 2 * len(TOPOLOGIES)
    per, rem = divmod(n_workflows, n_streams)
    # enough closed-loop concurrency to keep ~666 pod slots/100 nodes busy
    conc = max(2, (n_nodes * 7) // (n_streams * 4))
    i = 0
    for topo in TOPOLOGIES:
        wf = make_workflow(topo, get_workflow_spec(topo))
        for klass, prio, weight in (("prod", 10, 3.0), ("batch", 0, 1.0)):
            repeats = per + (1 if i < rem else 0)
            if klass == "prod":     # closed-loop interactive tenant
                plane.add_stream(wf, repeats=repeats,
                                 tenant=f"{topo}-{klass}",
                                 arrival="concurrent", concurrency=conc,
                                 priority=prio, weight=weight)
            else:                   # open-loop surge: deep pending queue
                plane.add_stream(wf, repeats=repeats,
                                 tenant=f"{topo}-{klass}",
                                 arrival="poisson", rate=0.5, burst=2,
                                 priority=prio, weight=weight)
            i += 1
    return plane


def run_policy(policy, n_workflows, n_nodes, seed, horizon_s=400_000.0):
    plane = build_plane(policy, n_workflows, n_nodes, seed)
    t0 = time.perf_counter()
    res = plane.run(horizon_s=horizon_s)
    wall = time.perf_counter() - t0
    m = res.metrics
    completed = sum(1 for r in m.workflows.values() if r.ns_deleted > 0)
    events = getattr(res.sim, "events_processed", None)
    rec = {
        "policy": policy,
        "wall_s": round(wall, 3),
        "sim_makespan_s": round(res.sim.t, 2),
        "events": events,
        "events_per_sec": (round(events / wall) if events else None),
        "peak_pending_admission": getattr(res.arbiter, "max_pending", None),
        "peak_pending_pods": getattr(res.cluster, "max_pending_pods", None),
        "completed_workflows": completed,
        "api_calls": res.cluster.api_calls,
        "admitted": res.arbiter.admitted,
        "deferrals": res.arbiter.deferrals,
        "peak_rss_mib": round(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024, 1),
        "tenant_makespan_s": {
            t: round(s["makespan"], 2)
            for t, s in m.tenant_summary().items()},
    }
    cpu_stat = getattr(m, "cpu_stat", None)
    if cpu_stat is not None and cpu_stat.count:
        cpu_a, _ = res.cluster.allocatable()
        rec["cpu_usage"] = {"samples": cpu_stat.count,
                            "mean_rate": round(cpu_stat.mean / cpu_a, 4),
                            "peak_rate": round(cpu_stat.max / cpu_a, 4),
                            "p95_rate": round(
                                cpu_stat.percentile(95) / cpu_a, 4)}
    exec_stat = getattr(res.cluster, "exec_stat", None)
    if exec_stat is not None and exec_stat.count:
        rec["pod_exec_s"] = {"count": exec_stat.count,
                             "mean": round(exec_stat.mean, 2),
                             "max": round(exec_stat.max, 2),
                             "p95": round(exec_stat.percentile(95), 2)}
    return rec


def run_scenario(n_workflows, n_nodes, seed, policies):
    runs = [run_policy(p, n_workflows, n_nodes, seed) for p in policies]
    return {
        "schema": SCHEMA,
        "scenario": {"workflows": n_workflows, "nodes": n_nodes,
                     "node_cpu_m": cal.PaperCluster.node_cpu_m,
                     "node_mem_mi": cal.PaperCluster.node_mem_mi,
                     "seed": seed, "topologies": list(TOPOLOGIES),
                     "streams": 2 * len(TOPOLOGIES)},
        "host": {"python": platform.python_version(),
                 "platform": platform.platform()},
        "runs": runs,
        "total_wall_s": round(sum(r["wall_s"] for r in runs), 3),
    }


def run():
    """benchmarks.run entry: reduced smoke variant of the stress tier."""
    report = run_scenario(50, 20, seed=42, policies=("fifo", "fair-share"))
    rows = []
    for r in report["runs"]:
        rows.append(row(
            f"scale_smoke_50wf_20n_{r['policy']}", r["wall_s"] * 1e6,
            f"makespan_s={r['sim_makespan_s']};"
            f"events_per_sec={r['events_per_sec']};"
            f"peak_pending={r['peak_pending_admission']};"
            f"completed={r['completed_workflows']}"))
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--workflows", type=int, default=1000)
    ap.add_argument("--nodes", type=int, default=100)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--policies", default=",".join(POLICIES))
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument("--budget-s", type=float, default=0.0,
                    help="fail (exit 2) if total wall time exceeds this")
    args = ap.parse_args()

    policies = [p for p in args.policies.split(",") if p]
    report = run_scenario(args.workflows, args.nodes, args.seed, policies)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    for r in report["runs"]:
        print(f"{r['policy']:>11}: wall={r['wall_s']:.1f}s "
              f"makespan={r['sim_makespan_s']:.0f}s "
              f"events/s={r['events_per_sec']} "
              f"completed={r['completed_workflows']}", flush=True)
    print(f"total wall: {report['total_wall_s']:.1f}s -> {args.out}")
    if args.budget_s and report["total_wall_s"] > args.budget_s:
        print(f"BUDGET EXCEEDED: {report['total_wall_s']:.1f}s "
              f"> {args.budget_s:.1f}s", file=sys.stderr)
        raise SystemExit(2)


if __name__ == "__main__":
    main()
