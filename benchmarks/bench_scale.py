"""Scale-out stress tiers: 1000-wf/100-node, 10k-wf/1000-node and
100k-wf/1000-node runs through the multi-tenant ControlPlane (ROADMAP
scale track).

Eight streams (two tenants per paper topology) drive the full
KubeAdaptor stack — gateway, admission arbiter, informers, disordered
scheduler — on a synthetic ``PaperCluster`` scaled to ``--nodes``.
Each topology contributes a closed-loop "prod" tenant (concurrent
arrivals, priority 10, fair-share weight 3) and an open-loop "batch"
tenant (Poisson surge, the whole queue arriving in the first ~minute),
so the admission backlog grows to thousands of pending requests while
interactive load keeps flowing. Per admission policy the run records
real wall-clock, sim events/sec, *events per pod* (the 10k-tier
bottleneck ISSUE 3 attacks), queue backend, usage-accounting mode,
peak pending depths, per-tenant makespan, and peak RSS, then writes
everything to ``BENCH_scale.json`` (``bench_scale/v2`` schema:
benchmarks/README.md).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_scale \
        [--workflows 1000] [--nodes 100] [--workers 1] \
        [--tiers 1000x100,10000x1000,100000x1000,1000000x8000x8] \
        [--seed 42] [--policies fifo,priority,fair-share,drf,quota,preempt] \
        [--queue calendar|heap] [--usage-mode event|sampled] \
        [--lifecycle fast|chained] [--trace examples/trace_mixed.json] \
        [--out BENCH_scale.json] [--budget-s 0] [--profile] \
        [--min-events-per-sec 0] [--max-events-per-pod 0] \
        [--max-peak-rss-mib 0] [--max-shard-rss-mib 0] [--shard-procs 0] \
        [--chaos-node-kill-interval 0] [--chaos-drain-interval 0] \
        [--chaos-node-downtime 0] [--chaos-api-fault-rate 0] \
        [--chaos-task-crash-rate 0] [--chaos-start-after 0] \
        [--chaos-seed 0] [--require-complete] [--append] \
        [--placement first-fit|scored-spread|scored-pack] \
        [--node-mix uniform|big-small|cpu-mem-skew] \
        [--deschedule-interval 0] [--deschedule-threshold 0.9] \
        [--deschedule-victim youngest|largest-request] \
        [--autoscale-interval 0] [--autoscale-pending-threshold 1] \
        [--autoscale-sustain 30] [--autoscale-idle 60] \
        [--autoscale-min-frac 0.25] [--autoscale-scale-step 1] \
        [--autoscale-start-after 0] \
        [--gateway-max-pending 0] [--gateway-per-tenant-cap 0] \
        [--gateway-shed reject-newest|shed-oldest|fair-shed] \
        [--gateway-retry-after 5] [--gateway-max-retries 8] \
        [--gateway-wal-dir DIR] [--chaos-gateway-drop-rate 0] \
        [--chaos-gateway-dup-rate 0] [--min-complete-frac 0]

``--budget-s`` exits 2 when total wall time exceeds the budget;
``--min-events-per-sec`` / ``--max-events-per-pod`` /
``--max-peak-rss-mib`` exit 2 when any run breaches the floor/ceiling
— the ``bench-scale-smoke`` CI job uses them so event-core and memory
regressions fail the build (``peak_rss_mib`` is a process-lifetime
high-water mark, so the RSS gate budgets the whole sweep).
``--profile`` wraps each policy run in cProfile and prints the top-20
cumulative-time hotspots, so perf PRs can cite before/after profiles
instead of guessing. ``--trace`` replays a recorded arrival trace
(see ``arrival_trace/v1`` in benchmarks/README.md) instead of the
synthetic streams. The module's ``run()`` (for ``benchmarks.run``)
executes a reduced 50-workflow/20-node smoke variant of the synthetic
scenario.

Throughput accounting (ISSUE 5): ``events_per_sec`` divides by the
sim's event-loop wall time (``Sim.run_wall_s``, which ends at
``last_event_t``'s event), not the full ``plane.run`` wall — plane
setup, result assembly and post-completion drain no longer understate
throughput on short tiers or pollute cross-tier comparisons.
``wall_s`` stays the full run wall (the budget gate's basis).

Sharded control plane (ISSUE 6): ``--workers N`` (or a third tier
component, ``WFxNODESxWORKERS``) partitions the scenario's tenants
across N arbiter shards (``repro.core.shard``): 2·topologies streams
*per worker* (tenants ``{topo}-{klass}{j}``, which the crc32 partition
spreads evenly), each shard owning a disjoint node slice and running
its own event loop in a forked worker process.  Sharded rows report
``workers``, per-shard ``shards[]`` rows, per-shard self-reported
``peak_rss_mib`` and the fork-proof ``total_peak_rss_mib`` (parent
RSS + Σ shard self-reports — the ``--max-peak-rss-mib`` gate reads
this, so forking cannot hide memory; ``--max-shard-rss-mib`` gates
each shard's own peak).  ``events_per_sec`` on a sharded row is
Σ shard events / max shard loop-wall with at most ``--shard-procs``
loops running concurrently (weak-scaling aggregate — see
benchmarks/README.md); ``wall_s`` stays the true end-to-end wall and
``loop_cpu_s`` the CPU-second basis.  ``--profile`` collects each
shard's own cProfile and prints the top-20 labeled by shard.
``workers=1`` takes the unsharded in-process path, byte-identical to
v3 behavior.

Admission-pipeline policies (ISSUE 4): ``--policies`` also accepts
``drf`` (dominant-resource fair share), ``quota`` (fifo ordering with
hard per-tenant CPU caps — prod 20% / batch 10% of the cluster, so the
caps genuinely bind), and ``preempt`` (priority ordering + starvation
eviction).  Every stream carries an SLO deadline (prod 180 s / batch
3600 s — metrics only); runs report per-tenant deadline hit-rates plus
preemption and quota-reject counts.

Chaos tier (ISSUE 7): the ``--chaos-*`` flags arm a seeded
``ChaosSchedule`` (repro.core.chaos) on every policy run — node
kills/drains on exponential timers with seeded downtime, transient
apiserver faults absorbed by the engine's capped
exponential-backoff-with-jitter retry, and mid-run task crashes that
ride the ordinary retry budget.  Chaos draws come from their own
sha256-spawned stream, so runs without the flags are bit-identical to
``bench_scale/v4`` behavior and a fixed ``--chaos-seed`` replays
exactly (sharded runs spawn per-shard sub-streams).  Chaos rows add
``"chaos"`` (injection counters: node kills/drains/restores, pods
lost, api faults, task crashes, cumulative node downtime) and
``"recovery"`` (node_lost vs preempted eviction split,
time-to-reschedule percentiles).  ``--require-complete`` exits 2
unless every run completes all workflows with zero failures — the
``chaos-smoke`` CI job uses it to assert full recovery under faults
across all six policies.  ``--append`` merges the new tiers into an
existing ``--out`` report instead of overwriting it, so the chaos
tier can ride alongside previously recorded tiers.

Heterogeneous placement tier (ISSUE 8): ``--node-mix`` swaps the
uniform ``PaperCluster`` for a ``hetero_cluster`` preset
(``big-small`` or ``cpu-mem-skew`` — weighted node-class cycles whose
per-node average equals the paper node, so total allocatable stays
comparable), and ``--placement`` picks the node-selection mode:
``first-fit`` (default, bit-identical to every pinned v5 binding
hash) or the utilization-scored ``scored-spread`` /
``scored-pack`` modes fused into the native scheduler cycle.  Scored
placement consumes the identical shuffle word stream as first-fit —
only the pick among feasible nodes changes.  ``--deschedule-interval``
/ ``--deschedule-threshold`` arm the periodic descheduler daemon
(repro.core.descheduler): pods evicted off hot nodes requeue through
the recovery machinery with no retry-budget charge.  v6 rows add
``placement``, ``node_hotspot`` (per-node peak-utilization
mean/max/min/variance — the hotspot-variance comparison between
first-fit and scored-spread is the tier's headline), ``rebalances``,
``descheduler`` counters (when armed) and a ``p99`` tail in
``pod_exec_s``; hetero scenarios record ``node_mix`` +
``node_classes``.  ``--append`` refuses (exit 2) to merge tiers into
a report written under a different schema version.

Elastic autoscaling tier (ISSUE 9): ``--autoscale-interval`` arms the
deterministic node-pool autoscaler (repro.core.autoscaler) on every
policy run — the full roster is materialized (fixed native-mirror
indices) but each node class starts at a ``--autoscale-min-frac``
floor, scales up by ``--autoscale-scale-step`` nodes per tick while
pending depth stays >= ``--autoscale-pending-threshold`` for
``--autoscale-sustain`` seconds, and drains nodes idle for
``--autoscale-idle`` seconds back down when the queues are empty.
The daemon draws zero RNG words, so runs without the flags stay
bit-identical to ``bench_scale/v6`` behavior.  v7 rows always add
``"cost"`` (``Cluster.cost_summary``: provisioned node/cpu/mem
seconds, time-weighted utilization over *provisioned* capacity, and
provisioning peak/low/flips — flat provisioning on fixed rosters, so
fixed-vs-autoscaled comparisons read straight off the report), plus
``"autoscaler"`` counters when the daemon was armed; autoscaled
scenarios echo the knobs under ``scenario["autoscale"]`` and
descheduler scenarios gain the ``victim`` eviction-order echo
(``--deschedule-victim``).  Sharded runs slice explicit pool bounds
across shards and merge cost exactly (areas/flips sum, ratios
recomputed from pooled areas).

Durable front door tier (ISSUE 10): ``--gateway-max-pending`` arms the
``DurableGateway`` (repro.core.gateway) on every policy run — a
per-shard append-only submission WAL plus admission backpressure: at
most ``max-pending`` submissions admitted-but-unfinished per shard,
rejects carrying deterministic retry-after timers from a dedicated
sha256-spawned stream, and ``--gateway-shed`` picking the overload
victim (``reject-newest`` / ``shed-oldest`` / ``fair-shed``).
``--gateway-wal-dir`` arms the crash-durable file sink
(``shard-{i}.wal``), so a shard killed mid-run (REPRO_SHARD_KILL) and
restarted replays its log with exactly-once dedup.  An unsaturated
gateway performs zero draws and adds zero events, so runs without the
flags stay bit-identical to ``bench_scale/v7`` behavior.  v8 rows add
``"gateway"`` (the merged qstat snapshot: per-tenant
queued/admitted/running/done/rejected/retried/shed, peak pending /
waiting depths, retry horizon, transport-fault and WAL counters) plus
the arbiter's submission-edge counters, and two gates arm
automatically on every gateway row: peak pending must stay <=
max-pending (BACKPRESSURE BREACH) and admitted + shed must equal
submissions with an empty retry room at drain (GATEWAY ACCOUNTING).
``--require-complete`` on a gateway row asserts completed + shed ==
workflows instead of completed == workflows; ``--min-complete-frac``
sets the eventual-completion floor for the overload tier (e.g. 0.99).
``--chaos-gateway-drop-rate`` / ``--chaos-gateway-dup-rate`` extend
the chaos plane to the gate->arbiter hop: dropped submissions are
redelivered from the WAL, duplicates are suppressed by the dedup set.

The script still runs against the pre-optimization core (counters it
introduced are read via getattr) so speedups can be measured by
checking out two revisions and comparing ``wall_s``.
"""
import argparse
import cProfile
import inspect
import json
import platform
import pstats
import resource
import sys
import time

from benchmarks.common import row
from repro.configs.workflows import get_workflow_spec
from repro.core import calibration as cal
from repro.core.dag import make_workflow
from repro.core.runner import ControlPlane

TOPOLOGIES = ("montage", "epigenomics", "cybershake", "ligo")
POLICIES = ("fifo", "priority", "fair-share")
# pipeline policies (ISSUE 4) accepted by --policies next to the three
# legacy names: drf ordering, hard quota caps, priority preemption
PIPELINE_POLICIES = ("drf", "quota", "preempt")
# per-stream SLO deadlines (reported as deadline hit-rates; pure
# metrics — legacy-policy scheduling is unaffected)
PROD_DEADLINE_S = 180.0
BATCH_DEADLINE_S = 3600.0
# under --policies quota: per-tenant caps as fractions of cluster CPU
# (sum over the 8 streams = 120%, so caps genuinely bind under load)
PROD_QUOTA_FRAC = 0.20
BATCH_QUOTA_FRAC = 0.10
SCHEMA = "bench_scale/v8"


def _plane_kwargs(usage_mode, queue, lifecycle, placement="first-fit",
                  deschedule=None, autoscale=None, gateway=None):
    """Knobs that only the optimized core understands."""
    params = inspect.signature(ControlPlane.__init__).parameters
    kw = {}
    if "sample_mode" in params:
        kw["sample_mode"] = "streaming"
    if "retain_pod_log" in params:
        kw["retain_pod_log"] = False
    if "usage_mode" in params:
        kw["usage_mode"] = usage_mode
    if "queue" in params and queue:
        kw["queue"] = queue
    if "lifecycle" in params and lifecycle:
        kw["lifecycle"] = lifecycle
    if "placement" in params and placement != "first-fit":
        kw["placement"] = placement
    if "deschedule" in params and deschedule is not None:
        kw["deschedule"] = deschedule
    if "autoscale" in params and autoscale is not None:
        kw["autoscale"] = autoscale
    if "gateway" in params and gateway is not None:
        kw["gateway"] = gateway
    return kw


def _cluster_cfg(n_nodes, node_mix="uniform"):
    """The tier's cluster config: the paper's uniform nodes, or a
    heterogeneous node-class mix (ISSUE 8)."""
    if node_mix and node_mix != "uniform":
        return cal.hetero_cluster(n_nodes, node_mix)
    return cal.PaperCluster(n_nodes=n_nodes)


def build_plane(policy, n_workflows, n_nodes, seed, usage_mode="event",
                queue=None, lifecycle=None, trace=None, workers=1,
                shard_procs=None, processes=True, profile=False,
                chaos=None, placement="first-fit", node_mix="uniform",
                deschedule=None, autoscale=None, gateway=None,
                wal_dir=None):
    cfg = _cluster_cfg(n_nodes, node_mix)
    if workers > 1:
        from repro.core.shard import ShardedControlPlane
        extra = {}
        if gateway is not None and wal_dir:
            extra["wal_dir"] = wal_dir
        plane = ShardedControlPlane(
            workers, admission_policy=policy,
            cluster_cfg=cfg, seed=seed,
            fold_completed=True, capture_trace=False,
            shard_procs=shard_procs, processes=processes, profile=profile,
            chaos=chaos, **extra,
            **_plane_kwargs(usage_mode, queue, lifecycle,
                            placement, deschedule, autoscale, gateway))
    else:
        extra = {}
        if gateway is not None and wal_dir:
            import os as _os
            extra["wal_path"] = _os.path.join(wal_dir, "shard-0.wal")
        plane = ControlPlane("kubeadaptor", admission_policy=policy,
                             cluster_cfg=cfg,
                             seed=seed, chaos=chaos, **extra,
                             **_plane_kwargs(usage_mode, queue, lifecycle,
                                             placement, deschedule,
                                             autoscale, gateway))
    if trace is not None:
        plane.add_trace(trace.get("arrivals", []),
                        tenants=trace.get("tenants"))
        return plane
    # sharded scenarios scale the stream count with the shard count —
    # 2·topologies streams per worker, tenant names "{topo}-{klass}{j}"
    # (the crc32 partition spreads each such family across all shards
    # exactly evenly, so every shard sees the full topology/class mix)
    n_streams = 2 * len(TOPOLOGIES) * (workers if workers > 1 else 1)
    per, rem = divmod(n_workflows, n_streams)
    # enough closed-loop concurrency to keep ~666 pod slots/100 nodes busy
    conc = max(2, (n_nodes * 7) // (n_streams * 4))
    # allocatable CPU from the actual node list: identical to
    # n_nodes * node_cpu_m on the uniform cluster, and the true sum
    # over the class cycle on a heterogeneous mix
    total_cpu_m = sum(cpu for _, cpu, _ in cfg.nodes())
    # quota caps bind against what a stream's arbiter can actually see:
    # its own shard's slice of the cluster (= the whole cluster at
    # workers=1), keeping per-shard contention geometry tier-invariant
    quota_cpu_m = total_cpu_m // workers if workers > 1 else total_cpu_m
    quotas = {"prod": 0, "batch": 0}
    if policy == "quota":           # caps only bind under the quota preset
        quotas = {"prod": int(PROD_QUOTA_FRAC * quota_cpu_m),
                  "batch": int(BATCH_QUOTA_FRAC * quota_cpu_m)}
    deadlines = {"prod": PROD_DEADLINE_S, "batch": BATCH_DEADLINE_S}
    i = 0
    for topo in TOPOLOGIES:
        wf = make_workflow(topo, get_workflow_spec(topo))
        for klass, prio, weight in (("prod", 10, 3.0), ("batch", 0, 1.0)):
            for j in range(workers if workers > 1 else 1):
                tenant = (f"{topo}-{klass}{j}" if workers > 1
                          else f"{topo}-{klass}")
                repeats = per + (1 if i < rem else 0)
                extra = {}
                if quotas[klass]:
                    extra["quota_cpu_m"] = quotas[klass]
                if _add_stream_accepts("deadline_s"):
                    extra["deadline_s"] = deadlines[klass]
                if klass == "prod":     # closed-loop interactive tenant
                    plane.add_stream(wf, repeats=repeats, tenant=tenant,
                                     arrival="concurrent", concurrency=conc,
                                     priority=prio, weight=weight, **extra)
                else:                   # open-loop surge: deep pending queue
                    plane.add_stream(wf, repeats=repeats, tenant=tenant,
                                     arrival="poisson", rate=0.5, burst=2,
                                     priority=prio, weight=weight, **extra)
                i += 1
    return plane


def _add_stream_accepts(name):
    return name in inspect.signature(ControlPlane.add_stream).parameters


def _round_gateway(snap):
    """Round the snapshot's float fields for the report (counters and
    gauges stay exact ints)."""
    out = dict(snap)
    out["retry_horizon_t"] = round(snap.get("retry_horizon_t", 0.0), 2)
    if "wal" in out:
        out["wal"] = {k: v for k, v in out["wal"].items() if k != "chain"}
    return out


def run_policy(policy, n_workflows, n_nodes, seed, horizon_s=400_000.0,
               usage_mode="event", queue=None, lifecycle=None, trace=None,
               profile=False, workers=1, shard_procs=None, chaos=None,
               placement="first-fit", node_mix="uniform", deschedule=None,
               autoscale=None, gateway=None, wal_dir=None):
    if wal_dir:
        # one WAL namespace per (policy, tier) run: a later run must
        # never replay a previous policy's log as its own durable prefix
        import os as _os
        wal_dir = _os.path.join(
            wal_dir, f"{policy}-{n_workflows}wf-{n_nodes}n")
    if workers > 1:
        return _run_policy_sharded(
            policy, n_workflows, n_nodes, seed, horizon_s=horizon_s,
            usage_mode=usage_mode, queue=queue, lifecycle=lifecycle,
            trace=trace, profile=profile, workers=workers,
            shard_procs=shard_procs, chaos=chaos, placement=placement,
            node_mix=node_mix, deschedule=deschedule, autoscale=autoscale,
            gateway=gateway, wal_dir=wal_dir)
    plane = build_plane(policy, n_workflows, n_nodes, seed,
                        usage_mode=usage_mode, queue=queue,
                        lifecycle=lifecycle, trace=trace, chaos=chaos,
                        placement=placement, node_mix=node_mix,
                        deschedule=deschedule, autoscale=autoscale,
                        gateway=gateway, wal_dir=wal_dir)
    try:
        import repro.core.cluster as _cluster_mod
        copies0 = _cluster_mod.SNAPSHOTS_MADE
    except AttributeError:            # pre-zero-copy core
        _cluster_mod, copies0 = None, 0
    profiler = None
    if profile:
        profiler = cProfile.Profile()
        profiler.enable()
    t0 = time.perf_counter()
    res = plane.run(horizon_s=horizon_s)
    wall = time.perf_counter() - t0
    if profiler is not None:
        profiler.disable()
        print(f"--- profile [{n_workflows}wf/{n_nodes}n {policy}] "
              f"top-20 by cumulative time ---", flush=True)
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(20)
    m = res.metrics
    completed = sum(1 for r in m.workflows.values()
                    if r.ns_deleted > 0 and not r.failed)
    failed = sum(1 for r in m.workflows.values() if r.failed)
    events = getattr(res.sim, "events_processed", None)
    pods = getattr(res.cluster, "pods_created", None)
    summary_by_tenant = m.tenant_summary()
    # pre-optimization cores leave sim.t at the drain time; the current
    # core parks it at the horizon and keeps the drain in last_event_t
    makespan = getattr(res.sim, "last_event_t", res.sim.t)
    # throughput over the event loop's own wall (ends at last_event_t's
    # event): excludes setup/epilogue/drain — see module docstring
    loop_wall = getattr(res.sim, "run_wall_s", 0.0) or wall
    rec = {
        "policy": policy,
        "wall_s": round(wall, 3),
        "loop_wall_s": round(loop_wall, 3),
        "sim_makespan_s": round(makespan, 2),
        "events": events,
        "events_per_sec": (round(events / loop_wall) if events else None),
        "pods_created": pods,
        "events_per_pod": (round(events / pods, 2)
                           if events and pods else None),
        "queue": getattr(res.sim, "queue_name", "heap"),
        "usage_mode": getattr(m, "usage_mode", "sampled"),
        "lifecycle": getattr(res.cluster, "lifecycle", "chained"),
        "peak_pending_admission": getattr(res.arbiter, "max_pending", None),
        "peak_pending_pods": getattr(res.cluster, "max_pending_pods", None),
        "completed_workflows": completed,
        "failed_workflows": failed,
        "api_calls": res.cluster.api_calls,
        "admitted": res.arbiter.admitted,
        "deferrals": res.arbiter.deferrals,
        "peak_rss_mib": round(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024, 1),
        # fork-proof memory accounting (ISSUE 6): even an unsharded run
        # reports the children's high-water mark, so work moved into
        # forked processes can never slip past the --max-peak-rss-mib
        # gate (total = parent + reaped-children peak; 0 children here)
        "rusage_children_mib": round(
            resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss / 1024, 1),
        "total_peak_rss_mib": round(
            (resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
             + resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss)
            / 1024, 1),
        "tenant_makespan_s": {
            t: round(s["makespan"], 2)
            for t, s in summary_by_tenant.items()},
    }
    # admission-pipeline observables (ISSUE 4): zero/absent on cores
    # that predate them; always emitted by the pipeline core so the
    # quota/preempt sweeps land in the same schema
    rec["preemptions"] = getattr(res.arbiter, "preemptions", None)
    rec["quota_rejects"] = getattr(res.arbiter, "quota_rejects", None)
    # scale observables (ISSUE 5): multi-grant admission rounds and the
    # object copies the zero-copy informer views actually materialized
    rec["grant_batches"] = getattr(res.arbiter, "grant_batches", None)
    if _cluster_mod is not None:
        rec["informer_copies"] = _cluster_mod.SNAPSHOTS_MADE - copies0
    slo = {t: {"deadline_s": s["deadline_s"],
               "hit_rate": (round(s["deadline_hit_rate"], 4)
                            if s["deadline_hit_rate"] == s["deadline_hit_rate"]
                            else None)}
           for t, s in summary_by_tenant.items() if "deadline_s" in s}
    if slo:
        rec["slo"] = slo
    summary = getattr(m, "usage_summary", None)
    if summary is not None:
        cpu = summary().get("cpu")
        if cpu:
            rec["cpu_usage"] = {
                k: (round(v, 4) if isinstance(v, float) else v)
                for k, v in cpu.items()}
    else:                          # pre-optimization fallback
        cpu_stat = getattr(m, "cpu_stat", None)
        if cpu_stat is not None and cpu_stat.count:
            cpu_a, _ = res.cluster.allocatable()
            rec["cpu_usage"] = {"samples": cpu_stat.count,
                                "mean_rate": round(cpu_stat.mean / cpu_a, 4),
                                "peak_rate": round(cpu_stat.max / cpu_a, 4),
                                "p95_rate": round(
                                    cpu_stat.percentile(95) / cpu_a, 4)}
    exec_stat = getattr(res.cluster, "exec_stat", None)
    if exec_stat is not None and exec_stat.count:
        rec["pod_exec_s"] = {"count": exec_stat.count,
                             "mean": round(exec_stat.mean, 2),
                             "max": round(exec_stat.max, 2),
                             "p95": round(exec_stat.percentile(95), 2),
                             "p99": round(exec_stat.percentile(99), 2)}
    # placement observables (ISSUE 8): per-node peak-utilization
    # profile (the first-fit vs scored hotspot comparison), the active
    # placement mode, and descheduler accounting when the daemon ran
    rec["placement"] = getattr(res.cluster, "placement", "first-fit")
    hotspot = getattr(res.cluster, "hotspot_summary", None)
    if hotspot is not None:
        rec["node_hotspot"] = {
            k: (round(v, 6) if isinstance(v, float) else v)
            for k, v in hotspot().items()}
    rec["rebalances"] = getattr(res.cluster, "rebalances", 0)
    desched = getattr(res, "descheduler", None)
    if desched is not None:
        rec["descheduler"] = desched.counters()
    # cost accounting (ISSUE 9): always emitted — fixed rosters report
    # flat provisioning, so cost-vs-makespan comparisons between fixed
    # and autoscaled rows read straight off the report
    cost = getattr(res.cluster, "cost_summary", None)
    if cost is not None:
        rec["cost"] = {k: (round(v, 4) if isinstance(v, float) else v)
                       for k, v in cost().items()}
    autoscaler = getattr(res, "autoscaler", None)
    if autoscaler is not None:
        rec["autoscaler"] = autoscaler.counters()
    # chaos/recovery observables (ISSUE 7): only emitted when a chaos
    # schedule was armed — chaos-free rows keep the exact v4 key set
    chaos_inj = getattr(res, "chaos", None)
    if chaos_inj is not None:
        rec["chaos"] = chaos_inj.counters()
        rec["recovery"] = {
            k: (round(v, 4) if isinstance(v, float) else v)
            for k, v in m.export_partial().recovery_summary().items()}
    # durable front door observables (ISSUE 10): only emitted when the
    # gateway was armed — gateway-free rows keep the pre-v8 key set.
    # The submission-edge counters come off the arbiter (satellite:
    # counters() exposes them), not gateway internals.
    gate = getattr(res, "gate", None)
    if gate is not None:
        rec["gateway"] = _round_gateway(gate.snapshot())
        rec["gateway_rejects"] = getattr(res.arbiter, "gateway_rejects", 0)
        rec["gateway_retries"] = getattr(res.arbiter, "gateway_retries", 0)
        rec["gateway_shed"] = getattr(res.arbiter, "gateway_shed", 0)
    return rec


def _run_policy_sharded(policy, n_workflows, n_nodes, seed,
                        horizon_s=400_000.0, usage_mode="event", queue=None,
                        lifecycle=None, trace=None, profile=False,
                        workers=2, shard_procs=None, chaos=None,
                        placement="first-fit", node_mix="uniform",
                        deschedule=None, autoscale=None, gateway=None,
                        wal_dir=None):
    """One policy run through the tenant-partitioned control plane
    (repro.core.shard): same row schema as the unsharded path plus
    ``workers`` / ``shards[]`` / fork-proof RSS totals."""
    import os as _os

    plane = build_plane(policy, n_workflows, n_nodes, seed,
                        usage_mode=usage_mode, queue=queue,
                        lifecycle=lifecycle, trace=trace, workers=workers,
                        shard_procs=shard_procs, profile=profile,
                        chaos=chaos, placement=placement, node_mix=node_mix,
                        deschedule=deschedule, autoscale=autoscale,
                        gateway=gateway, wal_dir=wal_dir)
    t0 = time.perf_counter()
    res = plane.run(horizon_s=horizon_s)
    wall = time.perf_counter() - t0
    if profile:
        for s in res.shards:
            if s["profile"]:
                print(f"--- profile [{n_workflows}wf/{n_nodes}n {policy} "
                      f"shard {s['shard']}] top-20 by cumulative time ---",
                      flush=True)
                print(s["profile"], flush=True)
    summary_by_tenant = res.tenant_summary()
    arb = res.arbiter_totals()
    parent_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    children_rss = \
        resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss / 1024
    # the gate-bearing total: parent + every shard's own self-reported
    # peak (RUSAGE_CHILDREN only keeps the max over reaped children and
    # accumulates across the sweep — reported for cross-checking)
    total_rss = parent_rss + sum(s["peak_rss_mib"] for s in res.shards)
    events = res.events
    pods = res.pods_created
    loop_wall = res.loop_wall_s
    loop_cpu = res.loop_cpu_s
    rec = {
        "policy": policy,
        "workers": workers,
        "shard_procs": min(shard_procs or _os.cpu_count() or 1, workers),
        "wall_s": round(wall, 3),
        "loop_wall_s": round(loop_wall, 3),
        "loop_cpu_s": round(loop_cpu, 3),
        "sim_makespan_s": round(res.sim_makespan_s, 2),
        "events": events,
        # weak-scaling aggregate: sum of shard events over the slowest
        # shard's loop wall, each loop unoversubscribed (shard_procs
        # waves) — see benchmarks/README.md; wall_s is end-to-end truth
        "events_per_sec": (round(events / loop_wall)
                           if events and loop_wall else None),
        "events_per_cpu_sec": (round(events / loop_cpu)
                               if events and loop_cpu else None),
        "pods_created": pods,
        "events_per_pod": (round(events / pods, 2)
                           if events and pods else None),
        "queue": res.shards[0]["queue"],
        "usage_mode": res.shards[0]["usage_mode"],
        "lifecycle": res.shards[0]["lifecycle"],
        "peak_pending_admission": res.peak_pending_admission,
        "peak_pending_pods": res.peak_pending_pods,
        "completed_workflows": res.completed_workflows,
        "failed_workflows": res.failed_workflows,
        "api_calls": res.api_calls,
        "admitted": arb.get("admitted", 0),
        "deferrals": arb.get("deferrals", 0),
        "peak_rss_mib": round(parent_rss, 1),
        "rusage_children_mib": round(children_rss, 1),
        "total_peak_rss_mib": round(total_rss, 1),
        "peak_shard_rss_mib": round(res.peak_shard_rss_mib, 1),
        "tenant_makespan_s": {
            t: round(s["makespan"], 2)
            for t, s in summary_by_tenant.items()},
        "preemptions": arb.get("preemptions", 0),
        "quota_rejects": arb.get("quota_rejects", 0),
        "grant_batches": arb.get("grant_batches", 0),
        "informer_copies": res.informer_copies,
        "shards": [{
            "shard": s["shard"],
            "nodes": s["nodes"],
            "seed": s["seed"],
            "tenants": len(s["tenants"]),
            "wall_s": round(s["wall_s"], 3),
            "loop_wall_s": round(s["loop_wall_s"], 3),
            "loop_cpu_s": round(s["loop_cpu_s"], 3),
            "sim_makespan_s": round(s["last_event_t"], 2),
            "events": s["events"],
            "events_per_sec": (round(s["events"] / s["loop_wall_s"])
                               if s["loop_wall_s"] else None),
            "pods_created": s["pods_created"],
            "completed_workflows": s["completed_workflows"],
            "failed_workflows": s["failed_workflows"],
            "peak_pending_admission": s["arbiter"].get("max_pending", 0),
            "peak_pending_pods": s["peak_pending_pods"],
            "peak_rss_mib": round(s["peak_rss_mib"], 1),
            **({"gateway_peak_pending": s["gateway"]["peak_pending"],
                "wal_records": s["gateway"]["wal"]["records"],
                "wal_replayed": s["gateway"]["wal"]["replayed"]}
               if s.get("gateway") else {}),
        } for s in res.shards],
    }
    slo = {t: {"deadline_s": s["deadline_s"],
               "hit_rate": (round(s["deadline_hit_rate"], 4)
                            if s["deadline_hit_rate"] == s["deadline_hit_rate"]
                            else None)}
           for t, s in summary_by_tenant.items() if "deadline_s" in s}
    if slo:
        rec["slo"] = slo
    cpu = res.usage_summary().get("cpu")
    if cpu:
        # merged across shard slices: rates normalized per slice, so
        # mean is the time-weighted mean slice utilization and peak the
        # max per-slice peak (basis "event" + merged shard windows)
        rec["cpu_usage"] = {k: (round(v, 4) if isinstance(v, float) else v)
                            for k, v in cpu.items()}
    if res.exec_stat is not None and res.exec_stat.count:
        rec["pod_exec_s"] = {"count": res.exec_stat.count,
                             "mean": round(res.exec_stat.mean, 2),
                             "max": round(res.exec_stat.max, 2),
                             "p95": round(res.exec_stat.percentile(95), 2),
                             "p99": round(res.exec_stat.percentile(99), 2)}
    # placement observables (ISSUE 8): hotspot profiles merge exactly
    # across disjoint shard node slices
    rec["placement"] = placement
    rec["node_hotspot"] = {
        k: (round(v, 6) if isinstance(v, float) else v)
        for k, v in res.hotspot_summary().items()}
    rec["rebalances"] = res.rebalances
    desched_counters = res.descheduler_counters()
    if desched_counters:
        rec["descheduler"] = desched_counters
    # cost accounting (ISSUE 9): exact pooled merge over the disjoint
    # shard slices (areas/flips sum; ratios recomputed from the sums)
    cost = res.cost_summary()
    if cost:
        rec["cost"] = {k: (round(v, 4) if isinstance(v, float) else v)
                       for k, v in cost.items()}
    autoscaler_counters = res.autoscaler_counters()
    if autoscaler_counters:
        rec["autoscaler"] = autoscaler_counters
    # chaos/recovery observables (ISSUE 7): per-shard counters summed
    # by ShardedRunResult.chaos_counters; recovery merges exactly
    # across shards (node_lost/preempted are sums, resched percentiles
    # come from the merged StreamingStat)
    if chaos is not None:
        rec["chaos"] = res.chaos_counters()
        rec["recovery"] = {
            k: (round(v, 4) if isinstance(v, float) else v)
            for k, v in res.recovery_summary().items()}
        if res.degraded:
            rec["degraded"] = True
            rec["shard_failures"] = res.failures
    # durable front door observables (ISSUE 10): merged qstat snapshot
    # (counters/gauges sum over the disjoint tenant partition, peaks
    # max) plus the summed arbiter submission-edge counters
    gw = res.gateway_summary()
    if gw:
        rec["gateway"] = _round_gateway(gw)
        rec["gateway_rejects"] = arb.get("gateway_rejects", 0)
        rec["gateway_retries"] = arb.get("gateway_retries", 0)
        rec["gateway_shed"] = arb.get("gateway_shed", 0)
    return rec


def run_scenario(n_workflows, n_nodes, seed, policies, usage_mode="event",
                 queue=None, lifecycle=None, trace=None, trace_path=None,
                 profile=False, workers=1, shard_procs=None, chaos=None,
                 placement="first-fit", node_mix="uniform", deschedule=None,
                 autoscale=None, gateway=None, wal_dir=None):
    runs = [run_policy(p, n_workflows, n_nodes, seed, usage_mode=usage_mode,
                       queue=queue, lifecycle=lifecycle, trace=trace,
                       profile=profile, workers=workers,
                       shard_procs=shard_procs, chaos=chaos,
                       placement=placement, node_mix=node_mix,
                       deschedule=deschedule, autoscale=autoscale,
                       gateway=gateway, wal_dir=wal_dir)
            for p in policies]
    scenario = {"workflows": n_workflows, "nodes": n_nodes,
                "node_cpu_m": cal.PaperCluster.node_cpu_m,
                "node_mem_mi": cal.PaperCluster.node_mem_mi,
                "seed": seed, "topologies": list(TOPOLOGIES),
                "streams": 2 * len(TOPOLOGIES) * max(1, workers)}
    if placement != "first-fit":
        scenario["placement"] = placement
    if node_mix and node_mix != "uniform":
        cfg = _cluster_cfg(n_nodes, node_mix)
        scenario["node_mix"] = node_mix
        scenario["node_classes"] = [
            {"name": c.name, "cpu_m": c.cpu_m, "mem_mi": c.mem_mi,
             "weight": c.weight} for c in cfg.classes]
    if deschedule is not None:
        scenario["deschedule"] = {
            "interval_s": deschedule.interval_s,
            "util_threshold": deschedule.util_threshold,
            "max_evict_per_node": deschedule.max_evict_per_node,
            "victim": getattr(deschedule, "victim", "youngest")}
    if autoscale is not None:
        scenario["autoscale"] = {
            "interval_s": autoscale.interval_s,
            "pending_threshold": autoscale.pending_threshold,
            "sustain_s": autoscale.sustain_s,
            "idle_s": autoscale.idle_s,
            "min_frac": autoscale.min_frac,
            "scale_step": autoscale.scale_step,
            "start_after_s": autoscale.start_after_s}
    if workers > 1:
        scenario["workers"] = workers
    if gateway is not None:
        scenario["gateway"] = {
            "max_pending": gateway.max_pending,
            "per_tenant_cap": gateway.per_tenant_cap,
            "shed": gateway.shed,
            "retry_after_s": gateway.retry_after_s,
            "max_client_retries": gateway.max_client_retries,
            "wal_dir": wal_dir or None}
    if chaos is not None:
        scenario["chaos"] = {
            "seed": chaos.seed,
            "node_kill_interval_s": chaos.node_kill_interval_s,
            "node_drain_interval_s": chaos.node_drain_interval_s,
            "node_downtime_s": chaos.node_downtime_s,
            "api_fault_rate": chaos.api_fault_rate,
            "task_crash_rate": chaos.task_crash_rate,
            "gateway_drop_rate": chaos.gateway_drop_rate,
            "gateway_dup_rate": chaos.gateway_dup_rate,
            "start_after_s": chaos.start_after_s}
    if trace is not None:
        arrivals = trace.get("arrivals", [])
        scenario.update({"trace": trace_path,
                         "workflows": len(arrivals),
                         "streams": 1, "topologies": sorted(
                             {a["topology"] for a in arrivals})})
    return {
        "scenario": scenario,
        "runs": runs,
        "total_wall_s": round(sum(r["wall_s"] for r in runs), 3),
    }


def run():
    """benchmarks.run entry: reduced smoke variant of the stress tier."""
    tier = run_scenario(50, 20, seed=42, policies=("fifo", "fair-share"))
    rows = []
    for r in tier["runs"]:
        rows.append(row(
            f"scale_smoke_50wf_20n_{r['policy']}", r["wall_s"] * 1e6,
            f"makespan_s={r['sim_makespan_s']};"
            f"events_per_sec={r['events_per_sec']};"
            f"peak_pending={r['peak_pending_admission']};"
            f"completed={r['completed_workflows']}"))
    return rows


def _parse_tiers(args):
    if args.tiers:
        out = []
        for part in args.tiers.split(","):
            fields = part.split("x")
            if len(fields) not in (2, 3):
                raise SystemExit(f"bad tier {part!r}: want WFxNODES or "
                                 f"WFxNODESxWORKERS")
            wf, nodes = int(fields[0]), int(fields[1])
            workers = int(fields[2]) if len(fields) == 3 else args.workers
            out.append((wf, nodes, workers))
        return out
    return [(args.workflows, args.nodes, args.workers)]


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--workflows", type=int, default=1000)
    ap.add_argument("--nodes", type=int, default=100)
    ap.add_argument("--tiers", default="",
                    help="comma list of WFxNODES or WFxNODESxWORKERS "
                         "(e.g. 1000x100,10000x1000,1000000x8000x8); "
                         "overrides --workflows/--nodes/--workers")
    ap.add_argument("--workers", type=int, default=1,
                    help="tenant-partitioned arbiter shards (forked "
                         "worker processes); 1 = unsharded legacy path")
    ap.add_argument("--shard-procs", type=int, default=0,
                    help="max shard processes running at once (default "
                         "cpu count): shards run in unoversubscribed "
                         "waves — see README on events_per_sec")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--policies", default=",".join(POLICIES))
    ap.add_argument("--queue", default="",
                    choices=("", "calendar", "heap"))
    ap.add_argument("--usage-mode", default="event",
                    choices=("event", "sampled"))
    ap.add_argument("--lifecycle", default="",
                    choices=("", "fast", "chained"))
    ap.add_argument("--trace", default="",
                    help="arrival_trace/v1 JSON to replay instead of the "
                         "synthetic streams")
    ap.add_argument("--out", default="BENCH_scale.json")
    ap.add_argument("--budget-s", type=float, default=0.0,
                    help="fail (exit 2) if total wall time exceeds this")
    ap.add_argument("--min-events-per-sec", type=float, default=0.0,
                    help="fail (exit 2) if any run throughput drops below")
    ap.add_argument("--max-events-per-pod", type=float, default=0.0,
                    help="fail (exit 2) if any run exceeds this event cost")
    ap.add_argument("--max-peak-rss-mib", type=float, default=0.0,
                    help="fail (exit 2) if any run's peak RSS exceeds this "
                         "(process-lifetime high-water mark: budget the "
                         "whole sweep; sharded runs are gated on "
                         "total_peak_rss_mib = parent + all shards)")
    ap.add_argument("--max-shard-rss-mib", type=float, default=0.0,
                    help="fail (exit 2) if any single shard's "
                         "self-reported peak RSS exceeds this")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile each policy run and print the top-20 "
                         "cumulative-time hotspots")
    ap.add_argument("--chaos-node-kill-interval", type=float, default=0.0,
                    help="mean seconds between node kills (exponential "
                         "stream; 0 = off)")
    ap.add_argument("--chaos-drain-interval", type=float, default=0.0,
                    help="mean seconds between node drains (graceful "
                         "spot-reclaim; 0 = off)")
    ap.add_argument("--chaos-node-downtime", type=float, default=0.0,
                    help="seconds until a killed/drained node rejoins "
                         "(0 = permanent loss)")
    ap.add_argument("--chaos-api-fault-rate", type=float, default=0.0,
                    help="probability each create/delete call returns a "
                         "retryable apiserver fault")
    ap.add_argument("--chaos-task-crash-rate", type=float, default=0.0,
                    help="probability a running task crashes mid-execution "
                         "(charges the ordinary retry budget)")
    ap.add_argument("--chaos-start-after", type=float, default=0.0,
                    help="sim seconds of calm before the first node event")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="chaos stream seed (sha256-spawned; independent "
                         "of --seed)")
    ap.add_argument("--require-complete", action="store_true",
                    help="fail (exit 2) unless every run completes all "
                         "workflows with zero failures (the chaos-smoke "
                         "recovery gate)")
    ap.add_argument("--append", action="store_true",
                    help="merge the new tiers into an existing --out "
                         "report instead of overwriting it (refuses — "
                         "exit 2 — when the existing report was written "
                         "under a different schema version)")
    ap.add_argument("--placement", default="first-fit",
                    choices=("first-fit", "scored-spread", "scored-pack"),
                    help="node-selection mode: first-fit (bit-identical "
                         "to v5 behavior) or utilization-scored "
                         "spread/pack (same shuffle word stream)")
    ap.add_argument("--node-mix", default="uniform",
                    choices=("uniform", "big-small", "cpu-mem-skew"),
                    help="cluster composition: the paper's uniform nodes "
                         "or a heterogeneous node-class preset (per-node "
                         "average equals the paper node)")
    ap.add_argument("--deschedule-interval", type=float, default=0.0,
                    help="descheduler daemon period in sim seconds "
                         "(0 = daemon off)")
    ap.add_argument("--deschedule-threshold", type=float, default=0.9,
                    help="node utilization fraction above which the "
                         "descheduler evicts (requeued pods are not "
                         "charged retry budget)")
    ap.add_argument("--deschedule-victim", default="youngest",
                    choices=("youngest", "largest-request"),
                    help="eviction order on a hot node: youngest "
                         "(least sunk work) or largest-request "
                         "(most utilization relief per eviction)")
    ap.add_argument("--autoscale-interval", type=float, default=0.0,
                    help="autoscaler daemon period in sim seconds "
                         "(0 = daemon off: full roster, bit-identical "
                         "to v6 behavior)")
    ap.add_argument("--autoscale-pending-threshold", type=int, default=1,
                    help="pending depth (admission queue + unbound "
                         "pods) that counts as scale-up pressure")
    ap.add_argument("--autoscale-sustain", type=float, default=30.0,
                    help="seconds the pending depth must stay above "
                         "the threshold before the first scale-up")
    ap.add_argument("--autoscale-idle", type=float, default=60.0,
                    help="seconds a node must hold zero bound pods "
                         "before idle scale-down drains it")
    ap.add_argument("--autoscale-min-frac", type=float, default=0.25,
                    help="per-node-class provisioned floor as a "
                         "fraction of the class population")
    ap.add_argument("--autoscale-scale-step", type=int, default=1,
                    help="nodes provisioned per sustained-pressure tick")
    ap.add_argument("--autoscale-start-after", type=float, default=0.0,
                    help="sim seconds of calm before the first "
                         "autoscaler tick")
    ap.add_argument("--gateway-max-pending", type=int, default=0,
                    help="durable front-door admission bound: max "
                         "in-flight (admitted, not yet done) workflows "
                         "per shard (0 = gateway off, bit-identical to "
                         "v7 behavior)")
    ap.add_argument("--gateway-per-tenant-cap", type=int, default=0,
                    help="per-tenant slice of the pending bound "
                         "(0 = no per-tenant cap)")
    ap.add_argument("--gateway-shed", default="reject-newest",
                    choices=("reject-newest", "shed-oldest", "fair-shed"),
                    help="overload shed discipline at the gate")
    ap.add_argument("--gateway-retry-after", type=float, default=5.0,
                    help="base client retry-after horizon in sim "
                         "seconds (jittered from the gate stream)")
    ap.add_argument("--gateway-max-retries", type=int, default=8,
                    help="client retry budget before a rejected "
                         "submission is shed for good")
    ap.add_argument("--gateway-wal-dir", default="",
                    help="directory for per-shard submission WAL files "
                         "(empty = in-memory segments only)")
    ap.add_argument("--chaos-gateway-drop-rate", type=float, default=0.0,
                    help="per-admitted-submission probability the "
                         "gate->engine hop drops it (WAL redelivers)")
    ap.add_argument("--chaos-gateway-dup-rate", type=float, default=0.0,
                    help="per-admitted-submission probability of a "
                         "duplicate delivery (dedup suppresses it)")
    ap.add_argument("--min-complete-frac", type=float, default=0.0,
                    help="gate: fail unless completed workflows >= this "
                         "fraction of submissions on every row (0 = off; "
                         "the overload tier uses 0.99)")
    args = ap.parse_args()

    policies = [p for p in args.policies.split(",") if p]
    trace = None
    if args.trace:
        with open(args.trace) as f:
            trace = json.load(f)
    chaos = None
    if (args.chaos_node_kill_interval or args.chaos_drain_interval
            or args.chaos_api_fault_rate or args.chaos_task_crash_rate
            or args.chaos_gateway_drop_rate or args.chaos_gateway_dup_rate):
        from repro.core.chaos import ChaosSchedule
        chaos = ChaosSchedule(
            seed=args.chaos_seed,
            node_kill_interval_s=args.chaos_node_kill_interval,
            node_drain_interval_s=args.chaos_drain_interval,
            node_downtime_s=args.chaos_node_downtime,
            api_fault_rate=args.chaos_api_fault_rate,
            task_crash_rate=args.chaos_task_crash_rate,
            gateway_drop_rate=args.chaos_gateway_drop_rate,
            gateway_dup_rate=args.chaos_gateway_dup_rate,
            start_after_s=args.chaos_start_after)
    gateway = None
    if args.gateway_max_pending > 0:
        from repro.core.gateway import BackpressurePolicy
        gateway = BackpressurePolicy(
            max_pending=args.gateway_max_pending,
            per_tenant_cap=args.gateway_per_tenant_cap,
            shed=args.gateway_shed,
            retry_after_s=args.gateway_retry_after,
            max_client_retries=args.gateway_max_retries)
    elif (args.chaos_gateway_drop_rate or args.chaos_gateway_dup_rate
          or args.gateway_wal_dir):
        print("--chaos-gateway-*-rate / --gateway-wal-dir require "
              "--gateway-max-pending > 0", file=sys.stderr)
        raise SystemExit(2)
    deschedule = None
    if args.deschedule_interval > 0.0:
        from repro.core.descheduler import DeschedulePolicy
        deschedule = DeschedulePolicy(
            interval_s=args.deschedule_interval,
            util_threshold=args.deschedule_threshold,
            victim=args.deschedule_victim)
    autoscale = None
    if args.autoscale_interval > 0.0:
        from repro.core.autoscaler import AutoscalePolicy
        autoscale = AutoscalePolicy(
            interval_s=args.autoscale_interval,
            pending_threshold=args.autoscale_pending_threshold,
            sustain_s=args.autoscale_sustain,
            idle_s=args.autoscale_idle,
            min_frac=args.autoscale_min_frac,
            scale_step=args.autoscale_scale_step,
            start_after_s=args.autoscale_start_after)
    tiers = []
    for n_wf, n_nodes, n_workers in _parse_tiers(args):
        tier = run_scenario(n_wf, n_nodes, args.seed, policies,
                            usage_mode=args.usage_mode,
                            queue=args.queue or None,
                            lifecycle=args.lifecycle or None,
                            trace=trace, trace_path=args.trace or None,
                            profile=args.profile, workers=n_workers,
                            shard_procs=args.shard_procs or None,
                            chaos=chaos, placement=args.placement,
                            node_mix=args.node_mix, deschedule=deschedule,
                            autoscale=autoscale, gateway=gateway,
                            wal_dir=args.gateway_wal_dir or None)
        tiers.append(tier)
        n_wf = tier["scenario"]["workflows"]
        shard_tag = f"/{n_workers}w" if n_workers > 1 else ""
        for r in tier["runs"]:
            print(f"[{n_wf}wf/{n_nodes}n{shard_tag}] {r['policy']:>11}: "
                  f"wall={r['wall_s']:.1f}s "
                  f"makespan={r['sim_makespan_s']:.0f}s "
                  f"events/s={r['events_per_sec']} "
                  f"events/pod={r['events_per_pod']} "
                  f"completed={r['completed_workflows']}", flush=True)
        if trace is not None:
            break                     # a trace defines its own workload

    out_tiers = tiers
    if args.append:
        try:
            with open(args.out) as f:
                prior = json.load(f)
        except FileNotFoundError:
            prior = None
        if prior is not None:
            # never splice rows across schema versions: a merged report
            # must be interpretable under exactly one field contract
            prior_schema = prior.get("schema")
            if prior_schema != SCHEMA:
                print(f"--append refused: {args.out} has schema "
                      f"{prior_schema!r}, this build writes {SCHEMA!r}; "
                      f"regenerate the report (or move it aside) instead "
                      f"of mixing schema versions", file=sys.stderr)
                raise SystemExit(2)
            out_tiers = prior.get("tiers", []) + tiers
    report = {
        "schema": SCHEMA,
        "host": {"python": platform.python_version(),
                 "platform": platform.platform()},
        "tiers": out_tiers,
        "total_wall_s": round(sum(t["total_wall_s"] for t in out_tiers), 3),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"total wall: {report['total_wall_s']:.1f}s -> {args.out}")

    failures = []
    # gates apply to the tiers run NOW (under --append, prior tiers in
    # the merged report are not re-gated)
    new_wall = round(sum(t["total_wall_s"] for t in tiers), 3)
    if args.budget_s and new_wall > args.budget_s:
        failures.append(f"BUDGET EXCEEDED: {new_wall:.1f}s "
                        f"> {args.budget_s:.1f}s")
    for tier in tiers:
        for r in tier["runs"]:
            label = (f"{tier['scenario']['workflows']}wf/"
                     f"{tier['scenario']['nodes']}n {r['policy']}")
            gw = r.get("gateway")
            if gw is not None:
                # automatic gates on every gateway row: the admission
                # bound must actually hold, and the ledger must balance
                # exactly (nothing lost, nothing stuck in the gate)
                tot = gw["totals"]
                if gw["peak_pending"] > gateway.max_pending:
                    failures.append(
                        f"BACKPRESSURE BREACH: {label} peak pending "
                        f"{gw['peak_pending']} > {gateway.max_pending}")
                if (tot["admitted"] + tot["shed"] != tot["submissions"]
                        or tot["queued"]):
                    failures.append(
                        f"GATEWAY ACCOUNTING: {label} admitted "
                        f"{tot['admitted']} + shed {tot['shed']} != "
                        f"submissions {tot['submissions']} "
                        f"(queued {tot['queued']})")
            if args.require_complete:
                want = tier["scenario"]["workflows"]
                if gw is not None:
                    # under backpressure some submissions are shed by
                    # design; everything admitted must still complete
                    done, shed = r["completed_workflows"], gw["totals"]["shed"]
                    if done + shed != want or r["failed_workflows"]:
                        failures.append(
                            f"INCOMPLETE RECOVERY: {label} completed "
                            f"{done} + shed {shed} != {want}, failed "
                            f"{r['failed_workflows']}")
                elif (r["completed_workflows"] != want
                        or r["failed_workflows"]):
                    failures.append(
                        f"INCOMPLETE RECOVERY: {label} completed "
                        f"{r['completed_workflows']}/{want}, failed "
                        f"{r['failed_workflows']}")
                if r.get("degraded"):
                    failures.append(
                        f"DEGRADED RESULT: {label} dropped shards "
                        f"{[s['shard'] for s in r['shard_failures']]}")
            if args.min_complete_frac:
                want = tier["scenario"]["workflows"]
                frac = r["completed_workflows"] / want if want else 1.0
                if frac < args.min_complete_frac:
                    failures.append(
                        f"COMPLETION FLOOR: {label} completed "
                        f"{r['completed_workflows']}/{want} "
                        f"({frac:.3f} < {args.min_complete_frac:.3f})")
            if (args.min_events_per_sec and r["events_per_sec"]
                    and r["events_per_sec"] < args.min_events_per_sec):
                failures.append(
                    f"THROUGHPUT FLOOR: {label} {r['events_per_sec']}/s "
                    f"< {args.min_events_per_sec:.0f}/s")
            if (args.max_events_per_pod and r["events_per_pod"]
                    and r["events_per_pod"] > args.max_events_per_pod):
                failures.append(
                    f"EVENT-COST CEILING: {label} {r['events_per_pod']} "
                    f"events/pod > {args.max_events_per_pod:.1f}")
            gate_rss = r.get("total_peak_rss_mib") or r["peak_rss_mib"]
            if (args.max_peak_rss_mib and gate_rss
                    and gate_rss > args.max_peak_rss_mib):
                failures.append(
                    f"RSS CEILING: {label} {gate_rss} MiB "
                    f"> {args.max_peak_rss_mib:.0f} MiB")
            if args.max_shard_rss_mib:
                for s in r.get("shards", []):
                    if s["peak_rss_mib"] > args.max_shard_rss_mib:
                        failures.append(
                            f"SHARD RSS CEILING: {label} shard "
                            f"{s['shard']} {s['peak_rss_mib']} MiB "
                            f"> {args.max_shard_rss_mib:.0f} MiB")
    if failures:
        for msg in failures:
            print(msg, file=sys.stderr)
        raise SystemExit(2)


if __name__ == "__main__":
    main()
