"""Paper Figs 9-14: CPU/memory usage-rate curves + first-lifecycle
averages. Dumps the full 0.5s-sampled series (the Fig 9/10 curves) to
artifacts/resource_usage/ and reports the Fig 13/14 averages."""
import json
import time
from pathlib import Path

from benchmarks.common import ALL_WF, ENGINES, row, wf
from repro.core.runner import run_experiment

REPEATS = 20
OUT = Path("artifacts/resource_usage")


def run():
    rows = []
    OUT.mkdir(parents=True, exist_ok=True)
    for name in ALL_WF:
        w = wf(name)
        rates = {}
        peaks = {}
        wall = 0.0
        for eng in ENGINES:
            t0 = time.perf_counter()
            res = run_experiment(eng, w, repeats=REPEATS, seed=6)
            wall += (time.perf_counter() - t0) * 1e6
            rates[eng] = res.metrics.first_lifecycle_usage(name)
            cpu_peak = max((c for _, c, _ in res.metrics.samples), default=0)
            mem_peak = max((m for _, _, m in res.metrics.samples), default=0)
            peaks[eng] = (cpu_peak, mem_peak)
            series = [{"t": t, "cpu_m": c, "mem_mi": m}
                      for t, c, m in res.metrics.samples[:2000]]
            (OUT / f"{name}_{eng}.json").write_text(json.dumps(series))
        k, b, a = rates["kubeadaptor"], rates["batchjob"], rates["argo"]
        rows.append(row(
            f"fig13_cpu_usage_rate_{name}", wall / len(ENGINES),
            f"kube={k[0]:.4f};batch={b[0]:.4f};argo={a[0]:.4f};"
            f"ordering_ok={k[0] > b[0] > a[0]}"))
        rows.append(row(
            f"fig14_mem_usage_rate_{name}", wall / len(ENGINES),
            f"kube={k[1]:.4f};batch={b[1]:.4f};argo={a[1]:.4f}"))
        rows.append(row(
            f"fig9_10_peak_usage_{name}", wall / len(ENGINES),
            f"cpu_peak_m={peaks['kubeadaptor'][0]};"
            f"mem_peak_mi={peaks['kubeadaptor'][1]};allocatable=48000m/91872Mi"))
    return rows
