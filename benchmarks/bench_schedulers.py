"""Two-level scheme in action: swap the level-1 scheduling algorithm and
verify the level-2 execution follows it (the docking framework is
algorithm-agnostic — §4.1). Longest-path-first shortens makespan on a
resource-CONSTRAINED cluster where ready tasks must queue."""
import time

from benchmarks.common import row
from repro.core import calibration as cal
from repro.core.cluster import Cluster
from repro.core.dag import Task, Workflow, add_virtual_entry_exit
from repro.core.engine import KubeAdaptorEngine
from repro.core.events import EventRegistry
from repro.core.informer import InformerSet
from repro.core.injector import WorkflowInjector
from repro.core.metrics import MetricsCollector
from repro.core.schedulers import SCHEDULERS
from repro.core.sim import Sim
from repro.core.volumes import VolumeManager


def _imbalanced_wf() -> Workflow:
    """One long chain + a wide bush: priority order matters under a
    2-slot cluster (longest-path should start the chain first)."""
    tasks = {}
    for i in range(6):                      # the bush (independent) FIRST —
        tasks[f"bush{i}"] = Task(id=f"bush{i}", duration_s=10.0)
    prev = None                             # so plain topological order
    for i in range(6):                      # schedules it before the chain
        t = Task(id=f"chain{i}", inputs=[prev] if prev else [],
                 duration_s=10.0)
        if prev:
            tasks[prev].outputs.append(t.id)
        tasks[t.id] = t
        prev = t.id
    return Workflow("imbalanced", add_virtual_entry_exit(tasks))


def run():
    rows = []
    wf = _imbalanced_wf()
    small = cal.PaperCluster(n_nodes=1, node_cpu_m=2500, node_mem_mi=4000)
    results = {}
    for name, cls in SCHEDULERS.items():
        t0 = time.perf_counter()
        sim = Sim()
        cluster = Cluster(sim, cluster_cfg=small, seed=3)
        engine = KubeAdaptorEngine(
            sim, cluster, InformerSet(sim, cluster), EventRegistry(sim),
            VolumeManager(sim, cluster), MetricsCollector(sim, cluster),
            scheduler_cls=cls)
        inj = WorkflowInjector(sim, engine.submit)
        engine.on_workflow_done = inj.request_next
        inj.load([wf.with_instance(0)])
        inj.start()
        sim.run(until=100_000)
        rec = engine.metrics.wf_record(wf.with_instance(0))
        ok = engine.metrics.order_consistent(wf.with_instance(0))
        results[name] = rec.lifecycle
        rows.append(row(
            f"two_level_scheduler_{name}",
            (time.perf_counter() - t0) * 1e6,
            f"lifecycle_s={rec.lifecycle:.1f};consistent={ok}"))
    gain = 1 - results["longest-path"] / results["topological"]
    rows.append(row("two_level_scheduler_gain", 0.0,
                    f"longest_path_vs_topo={gain:.3f};"
                    "note=level-1 algorithm swapped, level-2 docking unchanged"))
    return rows
