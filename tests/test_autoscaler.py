"""Elastic node autoscaler (ISSUE 9).

Pins for the node-pool autoscaling plane:

* ``autoscale=None`` (the default) reproduces the pinned
  binding-sequence hashes bit-for-bit — the provisioning code path
  must be invisible unless opted into;
* an armed autoscaler consumes ZERO RNG words (scheduler stream state
  identical to a daemon-free run) and a fixed seed replays exactly;
* scale-up answers sustained pending depth, scale-down drains idle
  nodes without ever stranding a pending pod, and the autoscaled run
  pays materially fewer node-seconds than the fixed roster at equal
  completion;
* autoscaler + descheduler + chaos daemon timers never keep a drained
  sim alive (liveness under all six policies, fast walks == generic);
* chaos only victimizes provisioned nodes and a chaos rejoin cannot
  resurrect a node the autoscaler deprovisioned while it was down;
* sharded cost/autoscaler metrics merge exactly (forked == inline).
"""
import hashlib
import math

import pytest

from repro.configs.workflows import get_workflow_spec, wide_fanout
from repro.core import calibration as cal
from repro.core.autoscaler import Autoscaler, AutoscalePolicy, NodePool
from repro.core.chaos import ChaosSchedule
from repro.core.dag import make_workflow
from repro.core.descheduler import DeschedulePolicy
from repro.core.runner import ControlPlane
from repro.core.shard import ShardedControlPlane

from tests.test_scale_core import PINNED, _binding_sequence

POLICIES = ("fifo", "priority", "fair-share", "drf", "quota", "preempt")

MONTAGE = make_workflow("montage", get_workflow_spec("montage"))


def _plane(policy="fifo", n_nodes=20, seed=42, autoscale=None, **kw):
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=cal.PaperCluster(n_nodes=n_nodes),
                         seed=seed, usage_mode="event",
                         autoscale=autoscale, **kw)

    def load(p):
        p.add_stream(MONTAGE, repeats=8, tenant="a", arrival="concurrent",
                     concurrency=4, priority=10, weight=3.0)
        p.add_stream(MONTAGE, repeats=8, tenant="b", arrival="concurrent",
                     concurrency=4, priority=0, weight=1.0)
    return plane, load


def _elastic_policy(**kw):
    base = dict(min_frac=0.2, interval_s=10.0, sustain_s=10.0,
                idle_s=30.0, scale_step=2)
    base.update(kw)
    return AutoscalePolicy(**base)


# ---------------------------------------------------------------------------
# policy validation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [dict(interval_s=0.0),
                                dict(pending_threshold=0),
                                dict(sustain_s=-1.0),
                                dict(idle_s=-1.0),
                                dict(scale_step=0),
                                dict(min_frac=0.0),
                                dict(min_frac=1.5),
                                dict(start_after_s=-1.0)])
def test_bad_policy_rejected(kw):
    plane, _ = _plane()
    with pytest.raises(ValueError):
        Autoscaler(plane.sim, plane.cluster, AutoscalePolicy(**kw))


def test_unknown_pool_class_rejected():
    with pytest.raises(ValueError):
        _plane(autoscale=AutoscalePolicy(
            pools=(NodePool("no-such-class", 1, 4),)))


def test_unknown_descheduler_victim_rejected():
    with pytest.raises(ValueError):
        _plane(deschedule=DeschedulePolicy(victim="no-such-order"))


# ---------------------------------------------------------------------------
# disabled => bit-identical; armed-but-inert => zero draws
# ---------------------------------------------------------------------------
def test_disabled_matches_pinned_hash():
    """The provisioning plumbing must be invisible without a policy:
    the PR-2 pinned binding hash still holds."""
    plane = ControlPlane("kubeadaptor", seed=7)
    seq = _binding_sequence(
        plane, lambda p: p.gateway.load([MONTAGE.with_instance(i)
                                         for i in range(2)]))
    digest = hashlib.sha256("\n".join(seq).encode()).hexdigest()
    want_digest, want_n = PINNED["paper"]
    assert (len(seq), digest) == (want_n, want_digest)


def test_full_floor_autoscaler_is_inert_and_drawless():
    """min_frac=1.0 keeps the whole roster provisioned: the armed
    daemon must change nothing — identical bindings AND an identical
    scheduler RNG state (zero words drawn by the daemon)."""
    base, load_a = _plane()
    seq_a = _binding_sequence(base, load_a)
    armed, load_b = _plane(autoscale=_elastic_policy(min_frac=1.0))
    seq_b = _binding_sequence(armed, load_b)
    assert seq_a == seq_b
    assert base.cluster.rng.getstate() == armed.cluster.rng.getstate()
    assert armed.autoscaler.ticks > 0
    assert armed.cluster.provision_flips == 0


def test_enabled_replays_exactly():
    runs = []
    for _ in range(2):
        plane, load = _plane(autoscale=_elastic_policy())
        seq = _binding_sequence(plane, load)
        runs.append((seq, plane.sim.last_event_t,
                     plane.cluster.cost_summary(),
                     plane.autoscaler.counters()))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# cost accounting
# ---------------------------------------------------------------------------
def test_fixed_roster_cost_is_flat_provisioning():
    plane, load = _plane(n_nodes=10)
    load(plane)
    res = plane.run()
    cost = res.cluster.cost_summary()
    span = res.sim.last_event_t
    assert cost["node_seconds"] == pytest.approx(10 * span)
    assert cost["cpu_mcore_seconds"] == pytest.approx(
        10 * cal.PaperCluster.node_cpu_m * span)
    assert cost["provision_flips"] == 0
    assert cost["provisioned_peak_nodes"] == 10
    assert cost["provisioned_low_nodes"] == 10
    assert 0.0 < cost["cpu_util_over_provisioned"] <= 1.0


def test_autoscaled_run_saves_node_seconds_at_equal_completion():
    fixed, load = _plane()
    load(fixed)
    rf = fixed.run()
    elastic, load = _plane(autoscale=_elastic_policy())
    load(elastic)
    re_ = elastic.run()
    done = lambda r: sum(1 for w in r.metrics.workflows.values()
                         if w.ns_deleted > 0 and not w.failed)
    assert done(rf) == done(re_) == 16
    cf = rf.cluster.cost_summary()
    ce = re_.cluster.cost_summary()
    assert ce["node_seconds"] < 0.8 * cf["node_seconds"]
    # paying less capacity means using it better
    assert ce["cpu_util_over_provisioned"] > cf["cpu_util_over_provisioned"]


def test_scale_up_under_sustained_backlog():
    """A deep open-loop surge must grow the roster from the floor."""
    pol = _elastic_policy(min_frac=0.1, scale_step=4)
    plane = ControlPlane("kubeadaptor", admission_policy="fifo",
                         cluster_cfg=cal.PaperCluster(n_nodes=20),
                         seed=3, usage_mode="event", autoscale=pol)
    plane.add_stream(MONTAGE, repeats=40, tenant="surge",
                     arrival="concurrent", concurrency=20)
    res = plane.run()
    ac = res.autoscaler.counters()
    assert ac["scale_up_events"] > 0
    assert ac["nodes_provisioned"] > 0
    cost = res.cluster.cost_summary()
    assert cost["provisioned_peak_nodes"] > cost["provisioned_low_nodes"]
    done = sum(1 for w in res.metrics.workflows.values()
               if w.ns_deleted > 0 and not w.failed)
    assert done == 40


def test_scale_down_drains_idle_nodes():
    """Two bursts separated by a long idle valley: the roster must
    shrink in the valley (scale_down events with zero pods disrupted
    — only idle nodes drain) and still finish the second burst."""
    pol = _elastic_policy(min_frac=0.1, interval_s=5.0, sustain_s=5.0,
                          idle_s=10.0, scale_step=4)
    plane = ControlPlane("kubeadaptor", admission_policy="fifo",
                         cluster_cfg=cal.PaperCluster(n_nodes=16),
                         seed=5, usage_mode="event", autoscale=pol)
    plane.add_stream(MONTAGE, repeats=12, tenant="burst1",
                     arrival="concurrent", concurrency=12)
    plane.add_stream(MONTAGE, repeats=4, tenant="trickle",
                     arrival="poisson", rate=0.005, burst=1)
    res = plane.run()
    ac = res.autoscaler.counters()
    assert ac["scale_down_events"] > 0
    assert ac["nodes_deprovisioned"] > 0
    assert ac["pods_drained"] == 0          # only idle nodes drained
    done = sum(1 for w in res.metrics.workflows.values()
               if w.ns_deleted > 0 and not w.failed)
    assert done == 16
    assert sum(1 for w in res.metrics.workflows.values() if w.failed) == 0


# ---------------------------------------------------------------------------
# daemon interplay: liveness under all six policies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_all_daemons_never_keep_sim_alive(policy):
    """Autoscaler + descheduler + chaos timers are all daemons: the
    sim must drain at the workload's end, every workflow completing
    (scale-down never strands a pending pod)."""
    chaos = ChaosSchedule(seed=9, node_kill_interval_s=200.0,
                          node_downtime_s=60.0, start_after_s=30.0)
    plane, load = _plane(policy=policy,
                         autoscale=_elastic_policy(),
                         deschedule=DeschedulePolicy(interval_s=20.0,
                                                     util_threshold=0.85),
                         chaos=chaos)
    load(plane)
    res = plane.run(horizon_s=500_000)
    assert res.sim.last_event_t < 100_000       # drained, not horizon-parked
    done = sum(1 for w in res.metrics.workflows.values()
               if w.ns_deleted > 0 and not w.failed)
    assert done == 16
    assert res.autoscaler.ticks > 0


def test_fast_walks_match_generic_under_autoscaling():
    import repro.core.resources as rs

    def run(fast):
        grants = []
        orig_init = rs.AdmissionArbiter.__init__
        orig_ck = rs.AdmissionArbiter._create_bookkeep

        def pinit(self, *a, **k):
            orig_init(self, *a, **k)
            self._fast = fast

        def pck(self, req):
            grants.append((self.inf.pods.sim.now(), req.namespace,
                           req.task.id))
            return orig_ck(self, req)

        rs.AdmissionArbiter.__init__ = pinit
        rs.AdmissionArbiter._create_bookkeep = pck
        try:
            plane, load = _plane(policy="drf",
                                 autoscale=_elastic_policy())
            seq = _binding_sequence(plane, load)
            return (grants, seq, plane.arbiter.deferrals,
                    plane.arbiter.admitted)
        finally:
            rs.AdmissionArbiter.__init__ = orig_init
            rs.AdmissionArbiter._create_bookkeep = orig_ck

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# chaos interplay
# ---------------------------------------------------------------------------
def test_chaos_only_victimizes_provisioned_nodes():
    plane, load = _plane(n_nodes=10,
                         autoscale=_elastic_policy(min_frac=0.3))
    cluster = plane.cluster
    # deprovisioned floor: chaos victim candidates exclude those nodes
    candidates = [n.name for n in cluster._node_seq
                  if n.ready and n.provisioned]
    assert len(candidates) == 3
    assert all(cluster.nodes[n].provisioned for n in candidates)


def test_chaos_rejoin_cannot_resurrect_deprovisioned_node():
    """kill -> autoscaler deprovisions while down -> the scheduled
    chaos restore must be a no-op; only provision_node revives."""
    plane, _ = _plane(n_nodes=4)
    cluster = plane.cluster
    cluster.kill_node("node2")
    assert not cluster.nodes["node2"].ready
    cluster.deprovision_node("node2")
    cluster.restore_node("node2")               # late chaos rejoin
    assert not cluster.nodes["node2"].ready     # stayed down
    assert cluster._prov_nodes == 3
    cluster.provision_node("node2")             # the only way back
    assert cluster.nodes["node2"].ready
    assert cluster._prov_nodes == 4
    assert cluster.provision_flips == 2


def test_deprovision_drains_residents_through_requeue():
    """Deprovisioning a busy node reuses the PR-7 drain path: the
    residents requeue and the run still completes everything."""
    pol = AutoscalePolicy(min_frac=1.0, interval_s=5.0, sustain_s=5.0,
                          idle_s=1e9)  # inert daemon; manual flips below
    plane, load = _plane(n_nodes=6, autoscale=pol)
    load(plane)
    plane.sim.after(40.0, lambda: plane.cluster.deprovision_node("node3"),
                    note="test-deprovision")
    plane.sim.after(90.0, lambda: plane.cluster.provision_node("node3"),
                    note="test-provision")
    res = plane.run()
    done = sum(1 for w in res.metrics.workflows.values()
               if w.ns_deleted > 0 and not w.failed)
    assert done == 16
    assert sum(1 for w in res.metrics.workflows.values() if w.failed) == 0
    assert res.cluster.provision_flips == 2


# ---------------------------------------------------------------------------
# pools and sharding
# ---------------------------------------------------------------------------
def test_derived_pools_respect_hetero_classes():
    pol = _elastic_policy(min_frac=0.5)
    plane = ControlPlane("kubeadaptor",
                         cluster_cfg=cal.hetero_cluster(12, "big-small"),
                         seed=1, autoscale=pol)
    pools = {p.node_class: (len(p.names), p.min_n)
             for p in plane.autoscaler._pools}
    # big-small cycle: 1x big + 2x small per 3 nodes
    assert pools == {"big": (4, 2), "small": (8, 4)}
    assert plane.cluster._prov_nodes == 6


def test_explicit_pool_spawn_partitions_like_nodes():
    pol = AutoscalePolicy(pools=(NodePool("node", 3, 7),))
    slices = [pol.spawn(i, 2).pools[0] for i in range(2)]
    assert [(p.min, p.max) for p in slices] == [(2, 4), (1, 3)]
    # derived pools pass through unchanged
    derived = _elastic_policy()
    assert derived.spawn(0, 4) is derived


def test_sharded_cost_merge_exact():
    pol = _elastic_policy()

    def run(processes):
        sp = ShardedControlPlane(
            2, cluster_cfg=cal.PaperCluster(n_nodes=12), seed=11,
            autoscale=pol, processes=processes, usage_mode="event",
            fold_completed=True, capture_trace=False)
        for i in range(4):
            sp.add_stream(MONTAGE, repeats=4, tenant=f"t{i}",
                          arrival="concurrent", concurrency=2)
        res = sp.run()
        return (res.cost_summary(), res.autoscaler_counters(),
                res.completed_workflows)

    inline = run(False)
    forked = run(True)
    assert inline == forked
    cost, counters, completed = inline
    assert completed == 16
    assert cost["node_seconds"] > 0
    assert counters["managed_nodes"] == 12
    # merged ratio is recomputed from pooled areas
    assert cost["cpu_util_over_provisioned"] == pytest.approx(
        cost["used_cpu_mcore_seconds"] / cost["cpu_mcore_seconds"])


def test_sharded_fixed_roster_cost_unchanged_and_flat():
    """No autoscaler: the always-on cost record must show flat
    provisioning on every shard and merge to n_nodes * makespan'ish
    totals without touching any behavioral field."""
    sp = ShardedControlPlane(
        2, cluster_cfg=cal.PaperCluster(n_nodes=8), seed=11,
        processes=False, usage_mode="event",
        fold_completed=True, capture_trace=False)
    for i in range(4):
        sp.add_stream(MONTAGE, repeats=2, tenant=f"t{i}",
                      arrival="concurrent", concurrency=2)
    res = sp.run()
    cost = res.cost_summary()
    assert cost["provision_flips"] == 0
    assert cost["provisioned_peak_nodes"] == 8
    want = sum(s["cost"]["node_seconds"] for s in res.shards)
    assert cost["node_seconds"] == pytest.approx(want)
    assert res.autoscaler_counters() == {}


# ---------------------------------------------------------------------------
# descheduler victim policies (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("victim", ("youngest", "largest-request"))
def test_descheduler_victim_policies_run_clean(victim):
    plane, load = _plane(
        deschedule=DeschedulePolicy(interval_s=15.0, util_threshold=0.7,
                                    victim=victim))
    load(plane)
    res = plane.run()
    assert res.descheduler.counters()["victim"] == victim
    done = sum(1 for w in res.metrics.workflows.values()
               if w.ns_deleted > 0 and not w.failed)
    assert done == 16


def test_largest_request_evicts_biggest_pod_first():
    """On a synthetic hot node the two victim orders pick different
    pods: youngest takes the latest-started, largest-request takes
    the biggest ask."""
    from repro.core.descheduler import Descheduler

    class _Pod:
        def __init__(self, name, started, cpu_m, mem_mi):
            self.name, self.started = name, started
            self.cpu_m, self.mem_mi = cpu_m, mem_mi

    pods = [_Pod("old-big", 1.0, 4000, 4000),
            _Pod("new-small", 9.0, 500, 500)]
    young = sorted(pods, key=lambda p: (-p.started, p.name))
    large = sorted(pods, key=lambda p: (-p.cpu_m, -p.mem_mi, p.name))
    assert young[0].name == "new-small"
    assert large[0].name == "old-big"
