"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests
must see the real single CPU device; multi-device tests go through
subprocesses (see tests/util.py run_subprocess)."""
import os
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def paper_numbers():
    return {
        "lifecycle": {
            "montage": {"kubeadaptor": 129.85, "batchjob": 169.83, "argo": 229.57},
            "epigenomics": {"kubeadaptor": 111.12, "batchjob": 162.34, "argo": 197.18},
            "cybershake": {"kubeadaptor": 83.36, "batchjob": 125.44, "argo": 151.19},
            "ligo": {"kubeadaptor": 92.46, "batchjob": 143.80, "argo": 181.22},
        },
        "exec": {"montage": 12.82, "epigenomics": 12.49,
                 "cybershake": 12.67, "ligo": 12.84},
    }
