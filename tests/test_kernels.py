"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel body in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan

ATTN_SHAPES = [
    # (B, S, H, hd, block_q, block_k)
    (1, 128, 1, 64, 64, 64),
    (2, 256, 4, 64, 128, 128),
    (1, 256, 2, 128, 64, 128),
    (2, 128, 3, 32, 32, 64),
    (1, 512, 2, 64, 128, 64),
]


@pytest.mark.parametrize("B,S,H,hd,bq,bk", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(B, S, H, hd, bq, bk, dtype, causal):
    key = jax.random.PRNGKey(hash((B, S, H, hd)) % 2**31)
    dt = jnp.dtype(dtype)
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), dt)
               for kk in jax.random.split(key, 3))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


SSD_SHAPES = [
    # (b, s, h, p, n, chunk)
    (1, 64, 2, 8, 16, 16),
    (2, 128, 4, 16, 32, 32),
    (1, 128, 8, 32, 64, 64),
    (2, 96, 2, 16, 16, 32),   # s not multiple of chunk -> clamp path
]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_ssd_scan_vs_ref(b, s, h, p, n, chunk, dtype):
    if s % chunk != 0:
        chunk = s // 2 if s % (s // 2) == 0 else s
    key = jax.random.PRNGKey(hash((b, s, h, p, n)) % 2**31)
    ks = jax.random.split(key, 5)
    dt_ = jnp.dtype(dtype)
    x = jax.random.normal(ks[0], (b, s, h, p), dt_)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.5
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32) * 0.5
    y, st = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_ref, st_ref = ref.ssd_ref(x, dt, A, B, C)
    tol = 2e-3 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=tol, rtol=tol)


def test_ssd_kernel_matches_production_path():
    """Pallas kernel == models/ssm.ssd_chunked (the pjit production path)."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    b, s, h, p, n, chunk = 2, 128, 4, 16, 32, 32
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y1, st1 = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y2, st2 = ssd_chunked(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=2e-4,
                               rtol=2e-4)


def test_flash_kernel_matches_production_chunked():
    from repro.models.attention import chunked_attention
    key = jax.random.PRNGKey(3)
    B, S, H, hd = 1, 256, 2, 64
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32)
               for kk in jax.random.split(key, 3))
    a = flash_attention(q, k, v, causal=True, interpret=True)
    b_ = chunked_attention(q, k, v, chunk=64, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5,
                               rtol=2e-5)


def test_ops_dispatch():
    from repro.kernels import ops
    key = jax.random.PRNGKey(1)
    q = k = v = jax.random.normal(key, (1, 64, 2, 32), jnp.float32)
    o_jnp = ops.attention(q, k, v, impl="jnp")
    o_int = ops.attention(q, k, v, impl="interpret", block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_int),
                               atol=2e-5, rtol=2e-5)
