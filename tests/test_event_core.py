"""10k-workflow event core (ISSUE 3): exactness pins + satellite fixes.

The pod-lifecycle fast path, the calendar event queue, and event-driven
usage accounting must not move a single scheduling decision.  These
tests pin:

* calendar-queue vs heap pop-order equivalence (property test + a
  deterministic mixed workload), and full-scenario binding equivalence
  across queue backends;
* fast vs chained lifecycle: identical binding sequences, workflow
  records, and watch-visible timestamps;
* ``events_per_pod`` <= 7 on the smoke stress scenario (the 10k-tier
  budget; the fast path actually lands near 2);
* ``Sim.run(until=...)`` parks the clock at the horizon even when the
  queue drains early, while ``last_event_t`` keeps the drain time;
* exact O(1) ``used()`` totals vs the node scan, and event-driven
  usage accounting agreeing with the 0.5 s sampler;
* ``on_retry_exhausted="fail-workflow"`` quarantining one poisoned
  workflow instead of tearing down the run;
* exact arrival-trace replay through the gateway and ControlPlane.
"""
import itertools
import json
import random
from pathlib import Path

import pytest

from repro.configs.workflows import get_workflow_spec, wide_fanout
from repro.core import calibration as cal
from repro.core.cluster import RUNNING, Cluster, PodObj
from repro.core.dag import make_workflow
from repro.core.runner import ControlPlane
from repro.core.sim import CalendarQueue, Event, HeapQueue, Sim
from repro.core.stats import StepAccumulator

EXAMPLE_TRACE = Path(__file__).resolve().parent.parent / "examples" / \
    "trace_mixed.json"


# ---------------------------------------------------------------------------
# queue backends: exact (t, seq) pop order
# ---------------------------------------------------------------------------
def _drive(delays, pop_every=3):
    """Feed both backends the same push/pop schedule; return pop logs."""
    hq, cq = HeapQueue(), CalendarQueue()
    seq = itertools.count()
    ev = Event(lambda: None, (), "", False)
    now = 0.0
    out_h, out_c = [], []

    def pop_one(until=None):
        nonlocal now
        a, b = hq.pop_due(until), cq.pop_due(until)
        assert (a is None) == (b is None)
        if a is not None:
            assert a[:2] == b[:2]
            now = a[0]
            out_h.append(a[:2])
            out_c.append(b[:2])

    for i, d in enumerate(delays):
        t, s = now + d, next(seq)
        hq.push(t, s, ev)
        cq.push(t, s, ev)
        if i % pop_every == 0:
            pop_one()
        if i % 17 == 0:
            pop_one(until=now + d / 2)    # horizon peek: may return None
    while len(hq):
        assert len(hq) == len(cq)
        pop_one()
    assert len(cq) == 0
    return out_h, out_c


def test_queue_backends_identical_deterministic():
    rng = random.Random(0)
    # the sim's bimodal mix: same-instant batches, control-plane
    # latencies, pod durations, far-future daemons
    choices = [0.0, 0.0, 0.02, 0.05, 0.08, 0.25, 1.15, 1.2, 10.0, 13.4,
               30.0, 64.5, 500.0, 5000.0]
    delays = [rng.choice(choices) for _ in range(5000)]
    out_h, out_c = _drive(delays)
    assert out_h == out_c and len(out_h) == 5000


def test_queue_backends_identical_property():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=200, deadline=None)
    @hypothesis.given(st.lists(st.one_of(
        st.just(0.0),
        st.floats(min_value=0.0, max_value=0.5),
        st.floats(min_value=0.0, max_value=3000.0)),
        min_size=1, max_size=300))
    def check(delays):
        out_h, out_c = _drive(delays)
        assert out_h == out_c and len(out_h) == len(delays)

    check()


def test_sim_queue_selection():
    assert Sim(queue="heap").queue_name == "heap"
    assert Sim(queue="calendar").queue_name == "calendar"
    with pytest.raises(ValueError):
        Sim(queue="wat")


@pytest.mark.parametrize("backend", ["calendar", "heap"])
def test_declined_horizon_pop_leaves_queue_exact(backend):
    """A bounded run that pops nothing must not disturb pop order for
    events pushed afterwards below the peeked time (regression: the
    calendar cursor used to commit its advance on a declined peek)."""
    sim = Sim(queue=backend)
    order = []
    sim.after(500.0, lambda: order.append(("a", sim.t)))
    sim.run(until=10.0)              # peeks t=500, pops nothing
    assert sim.t == 10.0 and sim.events_processed == 0
    sim.after(30.0, lambda: order.append(("b", sim.t)))   # below the peek
    sim.after(505.0, lambda: order.append(("c", sim.t)))
    sim.run()
    assert order == [("b", 40.0), ("a", 500.0), ("c", 515.0)]
    assert sim.last_event_t == 515.0


def test_sim_run_parks_clock_at_horizon_on_drain():
    """Satellite: run(until=...) sets t = until even when the queue
    drains before the horizon; last_event_t keeps the drain time."""
    sim = Sim()
    sim.after(3.0, lambda: None)
    sim.run(until=100.0)
    assert sim.t == 100.0
    assert sim.last_event_t == 3.0
    # horizon hit: pending event survives, clock stops at the horizon
    sim2 = Sim()
    sim2.after(50.0, lambda: None)
    sim2.run(until=10.0)
    assert sim2.t == 10.0 and sim2.events_processed == 0
    sim2.run(until=60.0)
    assert sim2.last_event_t == 50.0 and sim2.events_processed == 1
    # no horizon: clock stays on the last event
    sim3 = Sim()
    sim3.after(2.0, lambda: None)
    sim3.run()
    assert sim3.t == 2.0


# ---------------------------------------------------------------------------
# cross-layer equivalence: fast vs chained lifecycle, calendar vs heap
# ---------------------------------------------------------------------------
def _stress_plane(**kw):
    plane = ControlPlane("kubeadaptor", admission_policy="fair-share",
                         cluster_cfg=cal.PaperCluster(n_nodes=3), seed=11,
                         **kw)
    mont = make_workflow("montage", get_workflow_spec("montage"))
    fan = make_workflow("fan", wide_fanout(width=12))
    plane.add_stream(mont, repeats=2, tenant="a", arrival="concurrent",
                     concurrency=2, weight=2.0)
    plane.add_stream(fan, repeats=3, tenant="b", arrival="poisson",
                     rate=0.2, burst=2, weight=1.0)
    return plane


def _run_traced(plane):
    seq = []
    orig = plane.cluster._bind

    def record(pod, node):
        seq.append(f"{pod.namespace}/{pod.name}->{node.name}"
                   f"@{plane.sim.now():.4f}")
        orig(pod, node)

    plane.cluster._bind = record
    res = plane.run(horizon_s=500_000)
    records = {k: (r.ns_created, r.ns_deleted, sorted(r.starts),
                   sorted(r.finishes.items()), r.retries)
               for k, r in res.metrics.workflows.items()}
    return seq, records, res


@pytest.mark.parametrize("kw", [
    {"lifecycle": "chained"},
    {"queue": "heap"},
    {"queue": "heap", "lifecycle": "chained"},
])
def test_fast_calendar_run_matches_fallback_modes(kw):
    """The fast lifecycle on the calendar queue must reproduce the
    chained/heap run event for event: same binding sequence, same
    workflow records (watch-visible timestamps included)."""
    seq_fast, rec_fast, _ = _run_traced(_stress_plane())
    seq_ref, rec_ref, _ = _run_traced(_stress_plane(**kw))
    assert seq_fast == seq_ref
    assert rec_fast == rec_ref


def test_chained_lifecycle_costs_more_events():
    """The fast path must actually collapse events, not just relabel
    them: the same scenario costs strictly fewer sim events."""
    _, _, res_fast = _run_traced(_stress_plane())
    _, _, res_ref = _run_traced(_stress_plane(lifecycle="chained"))
    assert res_fast.cluster.pods_created == res_ref.cluster.pods_created
    # sparse scenario, so amortization is modest here; the dense-tier
    # budget is pinned by test_events_per_pod_smoke_regression
    assert res_fast.sim.events_processed < 0.8 * res_ref.sim.events_processed


def test_events_per_pod_smoke_regression():
    """ISSUE 3 budget: <= 7 sim events per pod on the smoke stress
    scenario (pre-fast-path cost was ~8-15)."""
    bench_scale = pytest.importorskip("benchmarks.bench_scale")
    rec = bench_scale.run_policy("fifo", 50, 20, seed=42)
    assert rec["completed_workflows"] == 50
    assert rec["events_per_pod"] is not None
    assert rec["events_per_pod"] <= 7.0, rec


# ---------------------------------------------------------------------------
# event-driven usage accounting
# ---------------------------------------------------------------------------
def test_step_accumulator_exact():
    acc = StepAccumulator(t0=0.0)
    acc.set(1.0, 100)     # level 0 for [0,1)
    acc.set(3.0, 300)     # level 100 for [1,3)
    acc.set(4.0, 0)       # level 300 for [3,4)
    acc.close(10.0)       # level 0 for [4,10)
    assert acc.total_time == 10.0
    assert acc.mean() == pytest.approx((0 + 100 * 2 + 300 * 1 + 0 * 6) / 10.0)
    assert acc.peak == 300
    assert acc.changes == 3
    # time-weighted percentiles: 70% of the run sits at level 0
    assert acc.percentile(50) == 0
    assert acc.percentile(75) == 100
    assert acc.percentile(99) == 300
    acc.close(10.0)       # idempotent
    assert acc.total_time == 10.0


def test_used_totals_match_node_scan():
    plane = ControlPlane("kubeadaptor", seed=3)
    wf = make_workflow("ligo", get_workflow_spec("ligo"))
    checks = []

    def probe():
        checks.append(plane.cluster.used() == plane.cluster.used_scan())
        if plane.sim.now() < 120:
            plane.sim.after(2.5, probe, daemon=True)

    plane.sim.after(1.0, probe, daemon=True)
    plane.gateway.load([wf.with_instance(0)])
    plane.run(horizon_s=500_000)
    assert len(checks) > 20 and all(checks)
    assert plane.cluster.used() == (0, 0)


def test_usage_event_mode_matches_sampler():
    def run(usage_mode):
        plane = ControlPlane("kubeadaptor", seed=6, usage_mode=usage_mode)
        wf = make_workflow("montage", get_workflow_spec("montage"))
        plane.gateway.load([wf.with_instance(i) for i in range(3)])
        return plane.run(horizon_s=500_000)

    sampled = run("sampled")
    event = run("event")
    # removing the 0.5s polling daemon must not move any decision
    assert {k: r.ns_deleted for k, r in sampled.metrics.workflows.items()} \
        == {k: r.ns_deleted for k, r in event.metrics.workflows.items()}
    # ... but it must remove the daemon's events
    assert event.sim.events_processed < sampled.sim.events_processed
    s_cpu, s_mem = sampled.metrics.overall_usage()
    e_cpu, e_mem = event.metrics.overall_usage()
    assert e_cpu == pytest.approx(s_cpu, rel=0.05)
    assert e_mem == pytest.approx(s_mem, rel=0.05)
    summary = event.metrics.usage_summary()
    assert summary["cpu"]["basis"] == "event"
    assert summary["cpu"]["peak_rate"] == pytest.approx(
        sampled.metrics.usage_summary()["cpu"]["peak_rate"], rel=0.05)
    # per-tenant step accumulators carry the bound-cpu breakdown
    assert "default" in event.metrics.tenant_cpu_accs
    assert event.metrics.tenant_cpu_accs["default"].peak > 0


def test_usage_event_mode_unaffected_by_parked_horizon():
    """Regression: with sample_resources=False nothing calls
    stop_sampling, and the accumulators used to be closed at the run
    horizon (sim.t) instead of the drain time — diluting the mean by
    horizon/makespan."""
    def run(sample_resources):
        plane = ControlPlane("kubeadaptor", seed=6, usage_mode="event",
                             sample_resources=sample_resources)
        wf = make_workflow("montage", get_workflow_spec("montage"))
        plane.gateway.load([wf.with_instance(0)])
        return plane.run(horizon_s=500_000)

    wired = run(True)       # stop_sampling freezes at gateway drain
    bare = run(False)       # closed lazily on read, at last_event_t —
    #                         a few cleanup events past the drain callback
    assert bare.sim.t == 500_000.0
    b_cpu, b_mem = bare.metrics.overall_usage()
    w_cpu, w_mem = wired.metrics.overall_usage()
    assert b_cpu == pytest.approx(w_cpu, rel=1e-2)
    assert b_mem == pytest.approx(w_mem, rel=1e-2)
    assert b_cpu > 0.01     # was ~1300x diluted before the fix


# ---------------------------------------------------------------------------
# retry exhaustion: fail one workflow, not the whole run
# ---------------------------------------------------------------------------
def _poisoned_plane(on_exhausted):
    params = cal.ClusterParams(on_retry_exhausted=on_exhausted)
    plane = ControlPlane("kubeadaptor", params=params, seed=9)
    wf = make_workflow("fan", wide_fanout(width=4))
    plane.add_stream(wf, repeats=2, tenant="t", arrival="concurrent",
                     concurrency=2)
    doomed = wf.with_tenant("t").with_instance(0).namespace()

    def sabotage(pod):
        # kill every incarnation of the doomed workflow's pods
        if pod.namespace == doomed and pod.phase == RUNNING:
            plane.cluster.fail_pod(pod.namespace, pod.name)

    plane.informers.pods.add_handlers(on_update=sabotage)
    return plane, wf, doomed


def test_retry_exhausted_default_raises():
    plane, _wf, _doomed = _poisoned_plane("raise")
    with pytest.raises(RuntimeError, match="exceeded retries"):
        plane.run(horizon_s=500_000)


def test_retry_exhausted_fail_workflow_quarantines():
    plane, wf, doomed = _poisoned_plane("fail-workflow")
    res = plane.run(horizon_s=500_000)
    m = res.metrics
    recs = list(m.workflows.values())
    failed = [r for r in recs if r.failed]
    ok = [r for r in recs if not r.failed]
    assert len(failed) == 1 and "exceeded" in failed[0].failure
    assert len(ok) == 1 and ok[0].ns_deleted > 0        # sibling finished
    assert failed[0].ns_deleted > 0                     # namespace cleaned
    assert doomed not in res.cluster.namespaces
    assert not any(ns == doomed for ns, _ in res.cluster.pods)
    summary = m.tenant_summary()["t"]
    assert summary["failed"] == 1.0 and summary["completed"] == 1.0
    assert res.gateway.pending() == 0                   # gateway not stuck


# ---------------------------------------------------------------------------
# arrival-trace replay
# ---------------------------------------------------------------------------
def test_gateway_trace_replays_exactly():
    from repro.core.injector import GRPC_LATENCY, WorkflowGateway

    sim = Sim()
    got = []
    gw = WorkflowGateway(sim, lambda wf: got.append(
        (round(sim.now(), 4), wf.tenant, wf.name, wf.instance)))
    records = [
        {"t": 5.0, "tenant": "b", "topology": "w"},
        {"t": 0.5, "tenant": "a", "topology": "w"},
        {"t": 5.0, "tenant": "a", "topology": "w"},   # tie: file order
    ]
    wf = make_workflow("w", wide_fanout(width=2))
    gw.load_trace(records, make=lambda topo: wf)
    gw.start()
    sim.run(until=100.0)
    lat = round(GRPC_LATENCY, 4)
    assert got == [(round(0.5 + lat, 4), "a", "w", 0),
                   (round(5.0 + lat, 4), "b", "w", 1),
                   (round(5.0 + lat, 4), "a", "w", 2)]


def test_control_plane_trace_end_to_end():
    trace = json.loads(EXAMPLE_TRACE.read_text())
    plane = ControlPlane("kubeadaptor", admission_policy="priority",
                         cluster_cfg=cal.PaperCluster(n_nodes=3), seed=1,
                         usage_mode="event", sample_mode="streaming")
    plane.add_trace(trace["arrivals"], tenants=trace.get("tenants"))
    res = plane.run(horizon_s=500_000)
    n = len(trace["arrivals"])
    done = [r for r in res.metrics.workflows.values() if r.ns_deleted > 0]
    assert len(done) == n
    # tenant shares from the trace header registered on the arbiter
    assert res.arbiter.tenants["sci"].priority == 5
    assert res.arbiter.tenants["adhoc"].weight == 1.0
    # open-loop replay: submission times equal the recorded arrivals
    arrivals = sorted(float(a["t"]) for a in trace["arrivals"])
    submitted = sorted(r.submitted_at for r in done)
    from repro.core.injector import GRPC_LATENCY
    for t_rec, t_sub in zip(arrivals, submitted):
        assert t_sub == pytest.approx(t_rec + GRPC_LATENCY, abs=1e-9)
