"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment req (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, get_config, list_configs
from repro.models import RunConfig, build
from repro.optim.adamw import OptConfig
from repro.runtime.train import TrainRunConfig, build_train_step

ARCHS = list_configs()


def _batch(cfg, B=2, S=32, seed=1):
    key = jax.random.PRNGKey(seed)
    batch = {}
    if cfg.frontend == "audio":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        if cfg.frontend == "vision":
            batch["img_embeds"] = jax.random.normal(
                key, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    families = {REGISTRY[a].family for a in ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg, RunConfig())
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux, _ = model.apply(params, batch)
    B, S = 2, 32
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_descends_and_finite(arch):
    cfg = get_config(arch).reduced()
    step, state_sds, _, _, _, model = build_train_step(
        cfg, None, B=2, S=32,
        trc=TrainRunConfig(opt=OptConfig(lr=1e-3, warmup_steps=1,
                                         total_steps=10)))
    from repro.optim.adamw import init_state
    state = init_state(model.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)   # same batch twice -> loss must drop
    assert bool(jnp.isfinite(m1["loss"])) and bool(jnp.isfinite(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])
    assert int(state.step) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_accumulation_matches_full_batch(arch):
    """grad_accum=2 over the same data == single big batch (to fp tolerance)."""
    cfg = get_config(arch).reduced()
    trc1 = TrainRunConfig(opt=OptConfig(lr=1e-3), grad_accum=1)
    trc2 = TrainRunConfig(opt=OptConfig(lr=1e-3), grad_accum=2)
    step1, *_, model = build_train_step(cfg, None, B=4, S=16, trc=trc1)
    step2, *_ = build_train_step(cfg, None, B=4, S=16, trc=trc2)
    from repro.optim.adamw import init_state
    batch = _batch(cfg, B=4, S=16)
    # NOTE: the step donates its input state — build a fresh one per call
    _, ma = step1(init_state(model.init(jax.random.PRNGKey(0))), batch)
    _, mb = step2(init_state(model.init(jax.random.PRNGKey(0))), batch)
    assert float(ma["loss"]) == pytest.approx(float(mb["loss"]), rel=2e-2)


def test_param_counts_match_published_sizes():
    # analytic totals should be in the right ballpark of the model names
    expect = {
        "mamba2-2.7b": (2.4e9, 3.1e9),
        "zamba2-1.2b": (1.0e9, 1.4e9),
        "llama4-scout-17b-a16e": (95e9, 115e9),   # 109B total published
        "qwen2-moe-a2.7b": (13e9, 15.5e9),        # 14.3B total published
        "qwen2-1.5b": (1.3e9, 1.8e9),
        "gemma-7b": (7.8e9, 9.5e9),
        "deepseek-67b": (64e9, 70e9),
        "qwen2-0.5b": (0.4e9, 0.65e9),
        "musicgen-medium": (1.3e9, 2.1e9),
        "llama-3.2-vision-11b": (9e9, 11e9),      # minus the vision stub
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_below_total():
    for arch in ("llama4-scout-17b-a16e", "qwen2-moe-a2.7b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()
