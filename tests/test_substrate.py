"""Substrate unit tests: optimizer, data pipeline, checkpoint, compression."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.optim.adamw import (OptConfig, TrainState, apply_updates,
                               global_norm, init_state, schedule)


# -- optimizer ---------------------------------------------------------------
def test_adamw_descends_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = init_state(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    for _ in range(100):
        grads = {"w": 2 * state.params["w"]}      # d/dw of w^2
        state, _ = apply_updates(state, grads, cfg)
    assert float(jnp.abs(state.params["w"]).max()) < 0.2


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(jnp.asarray(0), cfg)) == 0.0
    assert float(schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0, abs=1e-3)
    assert float(schedule(jnp.asarray(100), cfg)) == pytest.approx(0.1, abs=1e-3)
    mid = float(schedule(jnp.asarray(55), cfg))
    assert 0.1 < mid < 1.0


def test_gradient_clipping_bounds_update():
    params = {"w": jnp.zeros((4, 4))}
    state = init_state(params)
    cfg = OptConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    huge = {"w": jnp.full((4, 4), 1e6)}
    state, m = apply_updates(state, huge, cfg)
    assert float(m["grad_norm"]) > 1e6          # reported pre-clip
    assert float(jnp.abs(state.params["w"]).max()) < 1.0


# -- data ---------------------------------------------------------------------
def test_synthetic_data_deterministic_and_shaped():
    cfg = DataConfig(batch=4, seq_len=16, vocab_size=100, seed=3)
    a = next(iter(SyntheticLM(cfg)))
    b = next(iter(SyntheticLM(cfg)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert a["labels"].shape == (4, 16)
    assert a["tokens"].max() < 100 and a["tokens"].min() >= 0


def test_prefetcher_preserves_order():
    cfg = DataConfig(batch=2, seq_len=8, vocab_size=50, seed=0)
    raw = SyntheticLM(cfg)
    seq = [next(raw) for _ in range(5)]
    pf = Prefetcher(iter(seq), depth=2)
    got = list(pf)
    assert len(got) == 5
    for a, b in zip(seq, got):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


# -- checkpoint -----------------------------------------------------------------
def test_checkpoint_roundtrip_and_retention():
    from repro.checkpoint.checkpointer import Checkpointer
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3):
            ck.save(tree, s, blocking=True)
        assert ck.steps() == [2, 3]              # retention
        sds = jax.eval_shape(lambda: tree)
        out = ck.restore(sds, step=3)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert out["nested"]["b"].dtype == jnp.bfloat16
        assert int(out["step"]) == 7


def test_checkpoint_shape_mismatch_raises():
    from repro.checkpoint.checkpointer import Checkpointer
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save({"a": jnp.zeros((2, 2))}, 1, blocking=True)
        bad = jax.eval_shape(lambda: {"a": jnp.zeros((3, 3))})
        with pytest.raises(ValueError):
            ck.restore(bad)


def test_checkpoint_train_state_roundtrip():
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs import get_config
    from repro.models import build
    cfg = get_config("qwen2-0.5b").reduced()
    model = build(cfg)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(state, 5, blocking=True)
        sds = jax.eval_shape(lambda: state)
        out = ck.restore(sds)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- gradient compression ---------------------------------------------------------
def test_int8_compression_error_bounded():
    from repro.parallel.compression import quantize_dequantize_int8
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    dq = quantize_dequantize_int8(g)
    err = jnp.abs(dq["w"] - g["w"]).max()
    scale = jnp.abs(g["w"]).max() / 127
    assert float(err) <= float(scale) * 0.51 + 1e-6


def test_error_feedback_residual_bounded_over_steps():
    from repro.parallel.compression import ef_compress, init_residual
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (32, 32))}
    res = init_residual(g)
    for i in range(20):
        gi = {"w": g["w"] * (1.0 + 0.01 * i)}
        dq, res = ef_compress(gi, res)
    # EF residual stays bounded by one quantization step's worth of error
    scale = float(jnp.abs(g["w"]).max() * 1.2 / 127)
    assert float(jnp.abs(res["w"]).max()) < 2 * scale


def test_compressed_training_still_descends():
    from repro.configs import get_config
    from repro.optim.adamw import init_state
    from repro.runtime.train import TrainRunConfig, build_train_step
    cfg = get_config("qwen2-0.5b").reduced()
    step, *_, model = build_train_step(
        cfg, None, B=2, S=16,
        trc=TrainRunConfig(opt=OptConfig(lr=1e-3, warmup_steps=1),
                           compression="int8"))
    state = init_state(model.init(jax.random.PRNGKey(0)))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
