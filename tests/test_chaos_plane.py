"""Deterministic chaos plane (ISSUE 7): seeded faults, exact replay,
bit-identity with chaos off, and shard-worker failure recovery.

Guarantee layers:

* stream spawning: chaos seeds are sha256-spawned, decorrelated from
  the scheduler/shard seeds, reproducible, and per-shard distinct;
* bit-identity: ``chaos=None`` and an inactive ``ChaosSchedule()``
  perform zero draws — binding sequences match the chaos-free run
  exactly (the PR-6 pinned hashes in test_shard_plane.py run against
  this same code, so the pin is transitive);
* exact replay: a fixed chaos seed reproduces identical binding
  sequences, injection counters, and recovery metrics;
* recovery semantics: node kill/drain removes capacity and fails
  resident pods as ``node_lost`` (re-admitted with NO retry-budget
  charge), restore returns the capacity, transient apiserver faults
  are absorbed by the backoff path, task crashes DO charge the §4.5
  retry budget, and a mid-run node kill still completes 100% of
  workflows under every admission policy;
* teardown race (satellite): a pod evicted in the same instant its
  workflow fails must not re-enter the dead workflow's ready pool;
* sharded plane: fail-workflow counts and recovery metrics merge
  exactly across shards, and a dead shard worker is detected and
  handled per ``on_shard_failure`` (raise / restart / degrade)
  instead of hanging the parent forever.
"""
import os
from dataclasses import replace

import pytest

from repro.configs.workflows import get_workflow_spec
from repro.core import calibration as cal
from repro.core.chaos import (ChaosSchedule, chaos_shard_seed,
                              chaos_stream_seed)
from repro.core.cluster import PENDING, RUNNING
from repro.core.dag import make_workflow
from repro.core.runner import ControlPlane
from repro.core.shard import ShardedControlPlane, ShardFailure, shard_seed

MONTAGE = make_workflow("montage", get_workflow_spec("montage"))
EPIGENOMICS = make_workflow("epigenomics", get_workflow_spec("epigenomics"))


def _canon(obj):
    """NaN-tolerant deep compare form (NaN != NaN breaks dict ==)."""
    if isinstance(obj, dict):
        return {k: _canon(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, float) and obj != obj:
        return "nan"
    return obj


# --------------------------------------------------------------------------
# stream spawning
# --------------------------------------------------------------------------
def test_chaos_seed_spawning():
    assert chaos_stream_seed(42) == chaos_stream_seed(42)
    assert chaos_stream_seed(42) != chaos_stream_seed(43)
    # decorrelated from the shard-seed spawn of the same root
    assert chaos_stream_seed(42) != shard_seed(42, 0)
    per_shard = [chaos_shard_seed(42, i) for i in range(16)]
    assert len(set(per_shard)) == 16
    assert per_shard == [chaos_shard_seed(42, i) for i in range(16)]


def test_schedule_spawn_and_active():
    sched = ChaosSchedule(seed=5, node_kill_interval_s=100.0)
    assert sched.active
    assert not ChaosSchedule().active
    child = sched.spawn(3)
    assert child.seed == chaos_shard_seed(5, 3)
    assert child.node_kill_interval_s == 100.0


# --------------------------------------------------------------------------
# single-plane runs
# --------------------------------------------------------------------------
def _run_single(chaos, seed=7, policy="fair-share", params=None,
                n_nodes=8, repeats=5):
    plane = ControlPlane(
        "kubeadaptor", admission_policy=policy, seed=seed,
        params=params or cal.DEFAULT_PARAMS,
        cluster_cfg=cal.PaperCluster(n_nodes=n_nodes),
        sample_mode="streaming", usage_mode="event",
        retain_pod_log=False, lifecycle="fast", chaos=chaos)
    bindings = []
    inner = plane.cluster._bind

    def recording_bind(pod, node):
        bindings.append(f"{pod.namespace}/{pod.name}->{node.name}"
                        f"@{plane.sim.now():.4f}")
        return inner(pod, node)

    plane.cluster._bind = recording_bind
    plane.add_stream(MONTAGE, repeats=repeats, tenant="prod",
                     arrival="concurrent", concurrency=2, priority=10,
                     weight=3.0, deadline_s=1800.0)
    plane.add_stream(EPIGENOMICS, repeats=repeats, tenant="batch",
                     arrival="poisson", rate=0.5, burst=2,
                     deadline_s=3600.0)
    res = plane.run()
    return res, bindings


def test_inactive_schedule_is_bit_identical_to_chaos_none():
    res_none, b_none = _run_single(None)
    res_idle, b_idle = _run_single(ChaosSchedule(seed=99))
    assert b_idle == b_none
    assert res_idle.sim.events_processed == res_none.sim.events_processed
    assert _canon(res_idle.metrics.tenant_summary()) == \
        _canon(res_none.metrics.tenant_summary())
    # the injector is armed but performed zero draws
    assert res_idle.chaos is not None
    assert all(v == 0 for v in res_idle.chaos.counters().values())
    assert res_none.chaos is None


CHAOS = ChaosSchedule(seed=3, node_kill_interval_s=150.0,
                      node_drain_interval_s=400.0, node_downtime_s=60.0,
                      api_fault_rate=0.05, task_crash_rate=0.02,
                      start_after_s=30.0)


def test_fixed_chaos_seed_replays_exactly():
    res1, b1 = _run_single(CHAOS)
    res2, b2 = _run_single(CHAOS)
    assert b1 == b2
    assert res1.chaos.counters() == res2.chaos.counters()
    p1 = res1.metrics.export_partial()
    p2 = res2.metrics.export_partial()
    assert _canon(p1.recovery_summary()) == _canon(p2.recovery_summary())
    assert _canon(p1.tenant_summary()) == _canon(p2.tenant_summary())
    # a different chaos seed draws a different fault sequence
    res3, _ = _run_single(replace(CHAOS, seed=4))
    assert res3.chaos.counters() != res1.chaos.counters() or \
        res3.metrics.tenant_summary() != res1.metrics.tenant_summary()


def test_chaos_run_recovers_completely():
    res, _ = _run_single(CHAOS)
    c = res.chaos.counters()
    assert c["node_kills"] + c["node_drains"] >= 1
    assert c["api_faults"] >= 1
    p = res.metrics.export_partial()
    # every workflow completed despite the injected faults
    assert p.completed == 10
    assert p.failed == 0
    rec = p.recovery_summary()
    assert rec["node_lost"] == c["pods_lost"]
    # every disrupted (non-twin) task was re-created, with latency stats
    assert rec["rescheduled"] == rec["node_lost"]
    if rec["rescheduled"]:
        assert rec["resched_mean_s"] > 0.0
        assert rec["resched_p95_s"] >= rec["resched_p50_s"]


def test_scripted_kill_restore_and_no_retry_charge():
    # a scripted mid-run node kill: pods on node2 fail as node_lost and
    # are re-admitted WITHOUT charging the §4.5 retry budget; the
    # scripted restore returns the capacity and accounts the downtime
    sched = ChaosSchedule(seed=1, events=((60.0, "kill", "node2"),
                                          (220.0, "restore", "node2")))
    res, _ = _run_single(sched)
    c = res.chaos.counters()
    assert c["node_kills"] == 1
    assert c["node_restores"] == 1
    assert c["node_downtime_s"] == pytest.approx(160.0)
    assert c["pods_lost"] >= 1
    assert res.cluster.nodes["node2"].ready       # restored
    summary = res.metrics.tenant_summary()
    assert sum(row["node_lost"] for row in summary.values()) == \
        c["pods_lost"]
    part = res.metrics.export_partial()
    # node loss is disruption, not failure: zero retry-budget charges
    assert sum(a.retries for a in part.tenant_aggs.values()) == 0
    assert part.completed == 10


def test_drain_charges_api_calls_but_not_evictions():
    kill = ChaosSchedule(seed=1, events=((60.0, "kill", "node2"),))
    drain = ChaosSchedule(seed=1, events=((60.0, "drain", "node2"),))
    res_k, _ = _run_single(kill)
    res_d, _ = _run_single(drain)
    assert res_k.chaos.counters()["node_kills"] == 1
    assert res_d.chaos.counters()["node_drains"] == 1
    lost = res_d.chaos.counters()["pods_lost"]
    assert lost >= 1
    # the graceful drain pays one apiserver round-trip per resident pod
    # (everything else about the two runs is identical: same seed, same
    # victim, same instant)
    assert res_d.cluster.api_calls == res_k.cluster.api_calls + lost
    # neither path counts as arbiter preemption
    assert res_d.cluster.evictions == res_k.cluster.evictions


def test_transient_api_faults_absorbed():
    sched = ChaosSchedule(seed=11, api_fault_rate=0.25)
    res, _ = _run_single(sched)
    c = res.chaos.counters()
    assert c["api_faults"] > 10           # faults actually fired...
    p = res.metrics.export_partial()
    assert p.completed == 10              # ...and were all absorbed
    assert p.failed == 0


def test_task_crashes_charge_retry_budget():
    sched = ChaosSchedule(seed=13, task_crash_rate=0.10)
    res, _ = _run_single(sched)
    c = res.chaos.counters()
    assert c["task_crashes"] >= 1
    part = res.metrics.export_partial()
    # unlike node loss, a crash is a real failure: retries were charged
    assert sum(a.retries for a in part.tenant_aggs.values()) == \
        c["task_crashes"]
    assert part.completed == 10


def test_mid_run_node_kill_completes_under_every_policy():
    sched = ChaosSchedule(seed=7, node_kill_interval_s=120.0,
                          node_downtime_s=60.0, start_after_s=30.0)
    kills = 0
    for policy in ("fifo", "priority", "fair-share", "drf", "quota",
                   "preempt"):
        res, _ = _run_single(sched, policy=policy)
        p = res.metrics.export_partial()
        assert p.completed == 10, f"{policy}: {p.completed}/10"
        assert p.failed == 0, f"{policy} failed workflows"
        kills += res.chaos.counters()["node_kills"]
    assert kills >= 6                     # the kills genuinely happened


# --------------------------------------------------------------------------
# teardown race (satellite): evict during workflow failure
# --------------------------------------------------------------------------
def test_evict_during_teardown_does_not_requeue():
    plane = ControlPlane(
        "kubeadaptor", admission_policy="fair-share", seed=7,
        cluster_cfg=cal.PaperCluster(n_nodes=6),
        sample_mode="streaming", usage_mode="event",
        retain_pod_log=False, lifecycle="fast")
    plane.add_stream(MONTAGE, repeats=3, tenant="prod",
                     arrival="concurrent", concurrency=3)
    plane.gateway.start()
    plane.sim.run(until=20.0)             # mid-flight
    eng = plane.engine
    target = None
    for ns, ws in eng._ws.items():
        if ws.done:
            continue
        running = [p for p in plane.cluster.pods.values()
                   if p.namespace == ns and p.phase == RUNNING
                   and not p.evicted]
        if running:
            target = (ws, running[0])
            break
    assert target is not None, "no running pod at t=40 (workload shape?)"
    ws, pod = target
    tid = pod.task_id
    # same sim instant: the workflow starts tearing down AND the pod is
    # evicted — the pod's FAILED event lands after ws.done is set
    eng._fail_workflow(ws, "test: teardown race")
    assert ws.done
    assert plane.cluster.evict_pod(pod.namespace, pod.name)
    plane.sim.run(until=500_000.0)
    # the regression: the evicted task must NOT re-enter the dead
    # workflow's ready pool (double-count into a torn-down run)
    assert tid not in ws.ready_pool
    # and nothing was resurrected in the dead namespace
    assert not any(p.namespace == ws.ns and p.phase in (PENDING, RUNNING)
                   for p in plane.cluster.pods.values())
    # the other two workflows finished normally
    p = plane.metrics.export_partial()
    assert p.completed == 2
    assert p.failed == 1


# --------------------------------------------------------------------------
# sharded plane: chaos + fail-workflow merge exactness
# --------------------------------------------------------------------------
def _sharded(processes, chaos=None, params=None, **kw):
    plane = ShardedControlPlane(
        2, admission_policy="fair-share", seed=42,
        params=params or cal.DEFAULT_PARAMS,
        cluster_cfg=cal.PaperCluster(n_nodes=8),
        sample_mode="streaming", usage_mode="event", retain_pod_log=False,
        lifecycle="fast", processes=processes, chaos=chaos,
        heartbeat_s=0.2, **kw)
    # tenant names chosen to span both shards under the crc32 partition:
    # batch-a/alpha -> shard 0, prod-a/gamma -> shard 1
    for tenant in ("batch-a", "prod-a"):
        plane.add_stream(MONTAGE, repeats=4, tenant=tenant,
                         arrival="concurrent", concurrency=2, priority=10,
                         weight=3.0, deadline_s=180.0)
    for tenant in ("alpha", "gamma"):
        plane.add_stream(EPIGENOMICS, repeats=4, tenant=tenant,
                         arrival="poisson", rate=0.5, burst=2,
                         deadline_s=3600.0)
    return plane


def test_fail_workflow_counts_merge_exactly_across_shards():
    # task-crash chaos + a tight retry budget + fail-workflow: failed
    # counts and SLO rates must merge exactly (sum over shards == the
    # single-process run), with the workload still quarantined per
    # workflow
    params = replace(cal.DEFAULT_PARAMS, max_retries=1,
                     on_retry_exhausted="fail-workflow")
    chaos = ChaosSchedule(seed=9, task_crash_rate=0.30)
    r_in = _sharded(processes=False, chaos=chaos, params=params).run()
    r_mp = _sharded(processes=True, chaos=chaos, params=params).run()
    assert r_in.failed_workflows > 0      # the scenario genuinely fails
    assert r_in.completed_workflows + r_in.failed_workflows == 16
    assert r_mp.failed_workflows == r_in.failed_workflows
    assert _canon(r_mp.tenant_summary()) == _canon(r_in.tenant_summary())
    assert r_mp.chaos_counters() == r_in.chaos_counters()
    # merged failed == sum of per-shard partials
    assert sum(s["failed_workflows"] for s in r_in.shards) == \
        r_in.failed_workflows
    assert r_in.metrics.failed == r_in.failed_workflows


def test_recovery_metrics_merge_exactly_across_shards():
    chaos = ChaosSchedule(seed=5, node_kill_interval_s=120.0,
                          node_downtime_s=60.0, start_after_s=20.0)
    r_in = _sharded(processes=False, chaos=chaos).run()
    r_mp = _sharded(processes=True, chaos=chaos).run()
    assert r_in.chaos_counters() == r_mp.chaos_counters()
    assert _canon(r_in.recovery_summary()) == _canon(r_mp.recovery_summary())
    assert _canon(r_in.tenant_summary()) == _canon(r_mp.tenant_summary())
    c = r_in.chaos_counters()
    assert c.get("node_kills", 0) >= 1
    assert r_in.recovery_summary()["node_lost"] == c["pods_lost"]
    # per-shard counters sum to the merged view
    per_shard = [s["chaos"] for s in r_in.shards if s["chaos"]]
    assert sum(d["node_kills"] for d in per_shard) == c["node_kills"]
    assert r_in.completed_workflows == 16
    assert r_in.failed_workflows == 0


# --------------------------------------------------------------------------
# shard-worker failure recovery (satellite: no more silent hang)
# --------------------------------------------------------------------------
def test_dead_shard_raises_structured_failure(monkeypatch):
    monkeypatch.setenv("REPRO_SHARD_KILL", "1")
    with pytest.raises(ShardFailure) as exc:
        _sharded(processes=True, on_shard_failure="raise").run()
    assert exc.value.shard == 1
    assert exc.value.tenants          # the stranded tenants are named
    assert "died" in exc.value.reason


def test_dead_shard_restart_reproduces_healthy_result(monkeypatch):
    healthy = _sharded(processes=True).run()
    monkeypatch.setenv("REPRO_SHARD_KILL", "1")
    restarted = _sharded(processes=True, on_shard_failure="restart").run()
    # the respawned shard re-runs the identical spec (same tenant
    # partition + spawned seed), so the merged result is unchanged
    assert not restarted.degraded
    assert _canon(restarted.tenant_summary()) == \
        _canon(healthy.tenant_summary())
    assert restarted.completed_workflows == healthy.completed_workflows


def test_dead_shard_degrade_merges_survivors(monkeypatch):
    monkeypatch.setenv("REPRO_SHARD_KILL", "0")
    res = _sharded(processes=True, on_shard_failure="degrade").run()
    assert res.degraded
    assert [f["shard"] for f in res.failures] == [0]
    assert res.failures[0]["tenants"]
    # the surviving shard's results are intact
    surviving = {t for s in res.shards for t in s["tenants"]}
    assert surviving == set(res.tenant_summary())
    assert res.completed_workflows == \
        sum(s["completed_workflows"] for s in res.shards)


def test_inline_worker_exception_maps_to_policy():
    # in-process mode applies the same policy: a shard raising maps to
    # ShardFailure under "raise" and to a degraded merge under
    # "degrade" (strict horizon => unfinished workflows raise)
    def tiny(on_shard_failure):
        plane = _sharded(processes=False,
                         on_shard_failure=on_shard_failure)
        return plane.run(horizon_s=5.0)   # nothing can finish in 5s

    with pytest.raises(ShardFailure):
        tiny("raise")
    res = tiny("degrade")
    assert res.degraded
    assert len(res.failures) == 2
    assert res.completed_workflows == 0
