"""Multi-device tests (subprocess: XLA_FLAGS must precede jax import)."""
import json

import pytest

from tests.util import run_subprocess


def test_ring_all_reduce_matches_psum():
    out = run_subprocess("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.overlap import ring_all_reduce
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("x",))
xs = jax.random.normal(jax.random.PRNGKey(0), (64, 5))
ring = jax.jit(jax.shard_map(lambda x: ring_all_reduce(x, "x"),
               mesh=mesh, in_specs=P("x"), out_specs=P("x")))(xs)
ref = jax.jit(jax.shard_map(lambda x: jax.lax.psum(x, "x"),
              mesh=mesh, in_specs=P("x"), out_specs=P("x")))(xs)
err = float(jnp.abs(ring - ref).max())
assert err < 1e-5, err
print("RING_OK", err)
""", devices=8)
    assert "RING_OK" in out


def test_sharded_train_step_matches_single_device():
    """The distributed train step must be numerically equivalent to the
    single-device step (data-parallel + TP correctness)."""
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.optim.adamw import init_state, OptConfig
from repro.runtime.train import build_train_step, TrainRunConfig
from repro.data.pipeline import shard_batch

cfg = get_config("qwen2-0.5b").reduced()
trc = TrainRunConfig(opt=OptConfig(lr=1e-3, warmup_steps=0))
B, S = 8, 32
key = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

# single device
rc32 = None
step1, *_ , model1 = build_train_step(cfg, None, B=B, S=S, trc=trc)
s1 = init_state(model1.init(jax.random.PRNGKey(0)))
s1b, m1 = step1(s1, batch)

# (4, 2) mesh
mesh = make_mesh((4, 2), ("data", "model"))
step2, state_sds, _, st_sh, b_sh, model2 = build_train_step(
    cfg, mesh, B=B, S=S, trc=trc)
from repro.runtime.train import init_sharded_state
s2 = init_sharded_state(model2, mesh, st_sh)
db = shard_batch(batch, mesh, jax.tree.map(lambda s: s.spec, b_sh))
s2b, m2 = step2(s2, db)
l1, l2 = float(m1["loss"]), float(m2["loss"])
assert abs(l1 - l2) / abs(l1) < 2e-2, (l1, l2)
# params after one step agree
w1 = np.asarray(jax.device_get(s1b.params["final_norm"]))
w2 = np.asarray(jax.device_get(s2b.params["final_norm"]))
np.testing.assert_allclose(w1, w2, atol=5e-3)
print("DIST_TRAIN_OK", l1, l2)
""", devices=8)
    assert "DIST_TRAIN_OK" in out


def test_elastic_shrink_and_restore():
    out = run_subprocess("""
import tempfile, jax
from repro.configs import get_config
from repro.runtime.elastic import ElasticRunner
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import OptConfig
from repro.runtime.train import TrainRunConfig

cfg = get_config("qwen2-0.5b").reduced()
B, S = 8, 32
data = iter(SyntheticLM(DataConfig(batch=B, seq_len=S, vocab_size=cfg.vocab_size)))
with tempfile.TemporaryDirectory() as d:
    r = ElasticRunner(cfg, B, S, d, ckpt_every=5,
                      trc=TrainRunConfig(opt=OptConfig(warmup_steps=2, total_steps=30)))
    out = r.run(data, steps=14, fail_at=8, fail_devices=4)
    assert any("device failure" in e for e in out["events"]), out["events"]
    assert any("restored" in e for e in out["events"]), out["events"]
    assert out["losses"][-1] < out["losses"][0]
    print("ELASTIC_OK")
""", devices=8)
    assert "ELASTIC_OK" in out


def test_dryrun_cell_on_reduced_mesh():
    """Lower+compile one real cell on an 8-device (4,2) mesh and verify
    the artifact pipeline (memory/cost/collectives) end to end."""
    out = run_subprocess("""
import jax, json
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
from repro.launch import hlo_analysis
from repro.launch.dryrun import build_cell, make_runconfig
import dataclasses

cfg = get_config("qwen2-0.5b")
shape = dataclasses.replace(SHAPES["train_4k"], global_batch=8, seq_len=512)
mesh = make_mesh((4, 2), ("data", "model"))
jitted, kwargs = build_cell(cfg, shape, mesh)
compiled = jitted.lower(*kwargs.values()).compile()
mem = compiled.memory_analysis()
cost = compiled.cost_analysis()
stats = hlo_analysis.analyze(compiled.as_text())
assert stats.flops > 0
assert stats.total_collective_bytes > 0
assert stats.n_while >= 1 and max(stats.trip_counts) >= cfg.n_layers // 2
assert mem.temp_size_in_bytes > 0
print("DRYRUN_CELL_OK", int(stats.flops), stats.n_while)
""", devices=8)
    assert "DRYRUN_CELL_OK" in out


def test_multipod_mesh_shape():
    out = run_subprocess("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert dict(m1.shape) == {"data": 16, "model": 16}
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("MESH_OK")
""", devices=512)
    assert "MESH_OK" in out
