"""HLO-analysis unit tests + paper-workflow structure + shape registry."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, list_configs, shape_applicable
from repro.configs.workflows import WORKFLOWS, get_workflow_spec
from repro.core.dag import make_workflow
from repro.launch import hlo_analysis as H


# -- hlo_analysis -------------------------------------------------------------
def test_shape_bytes_parsing():
    assert H._shape_bytes("f32[2,3]{1,0}") == 24
    assert H._shape_bytes("bf16[4,4]") == 32
    assert H._shape_bytes("(f32[2], s32[3])") == 8 + 12
    assert H._shape_bytes("pred[]") == 1
    assert H._shape_bytes("u8[10]") == 10


def test_analyze_counts_scan_trip_multiplier():
    def step(w, x):
        def body(h, ww):
            return jnp.tanh(h @ ww), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    compiled = jax.jit(jax.grad(step)).lower(
        jax.ShapeDtypeStruct((7, 16, 16), jnp.float32),
        jax.ShapeDtypeStruct((4, 16), jnp.float32)).compile()
    st = H.analyze(compiled.as_text())
    # fwd dot + 2 bwd dots per layer, 7 layers: 3 * 7 * 2*4*16*16
    assert st.flops == pytest.approx(3 * 7 * 2 * 4 * 16 * 16, rel=0.35)
    assert st.n_while >= 1
    assert max(st.trip_counts) == 7


def test_analyze_finds_no_collectives_single_device():
    compiled = jax.jit(lambda x: (x @ x).sum()).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    st = H.analyze(compiled.as_text())
    assert st.total_collective_bytes == 0
    assert st.flops > 0


# -- paper workflows ---------------------------------------------------------
PAPER_DEPTH = {"montage": 10, "epigenomics": 9, "cybershake": 6, "ligo": 7}


@pytest.mark.parametrize("name", sorted(WORKFLOWS))
def test_workflow_structure_matches_paper(name):
    wf = make_workflow(name, get_workflow_spec(name))
    assert 19 <= len(wf.tasks) <= 24          # "task size about 20"
    assert wf.critical_path_len() == PAPER_DEPTH[name]
    # single entry / single exit
    roots = [t for t in wf.tasks.values() if not t.inputs]
    leaves = [t for t in wf.tasks.values() if not t.outputs]
    assert len(roots) == 1 and len(leaves) == 1
    # every task is the paper's stress task
    for t in wf.tasks.values():
        assert t.cpu_m == 1200 and t.mem_mi == 1200
        assert t.duration_s == 10.0


def test_configmap_roundtrip_listing1_format():
    import json
    spec = get_workflow_spec("montage")
    wf = make_workflow("montage", json.dumps(spec))    # via JSON string
    assert wf.topo_order()[0] == "entry"


# -- shapes / registry ----------------------------------------------------------
def test_shape_registry():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_applicability_matrix():
    subq = {a for a in list_configs()
            if shape_applicable(get_config(a), SHAPES["long_500k"])}
    assert subq == {"mamba2-2.7b", "zamba2-1.2b"}
    for a in list_configs():  # every other shape applies to every arch
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])


def test_vocab_and_expert_padding():
    mamba = get_config("mamba2-2.7b")
    assert mamba.vocab_padded % 256 == 0 and mamba.vocab_padded >= 50280
    qmoe = get_config("qwen2-moe-a2.7b")
    assert qmoe.n_experts_padded == 64


# -- injector protocol -------------------------------------------------------
def test_injector_next_workflow_trigger():
    from repro.core.injector import WorkflowInjector
    from repro.core.sim import Sim
    sim = Sim()
    got = []
    inj = WorkflowInjector(sim, got.append)
    wf = make_workflow("montage", get_workflow_spec("montage"))
    inj.load([wf.with_instance(i) for i in range(3)])
    drained = []
    inj.on_drained = lambda: drained.append(True)
    inj.start()
    sim.run()
    assert len(got) == 1                       # one at a time (paper §4.4)
    inj.request_next()
    sim.run()
    assert len(got) == 2
    inj.request_next()
    inj.request_next()                         # queue exhausts -> drained
    sim.run()
    assert len(got) == 3 and drained
