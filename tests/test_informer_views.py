"""Zero-copy informer views + batched multi-grant admission (ISSUE 5).

The 100k-workflow tier replaced per-write object snapshots with
generation-stamped copy-on-write records, re-entrant per-grant
admission walks with one batched multi-grant pass, and the
getrandbits word pump with a native MT19937 core fused into the
scheduler cycle.  None of it may move a single scheduling decision.
These tests pin:

* the PR-2 snapshot guarantee under sharing: no handler or lister
  caller can EVER observe state written after its view was handed out
  (property-checked over a contended run with evictions and quota
  rejections in flight);
* copy-on-write actually shares: steady-state resyncs materialize
  ZERO copies, bump no generation, and keep cache identity;
* binding-sequence hashes for the preempt / quota / drf presets,
  recorded on the pre-views core (commit cf583ed), re-run with views,
  the batched walk and the fused native cycle enabled;
* native fused-cycle vs pure-Python cluster equivalence end-to-end;
* batched multi-grant == the generic re-sort loop on a deep backlog
  where single walk calls grant many requests, with and without the
  merge orders' dynamic ranking.
"""
import hashlib

import pytest

from repro.configs.workflows import get_workflow_spec, wide_fanout
from repro.core import calibration as cal
import repro.core.cluster as cluster_mod
from repro.core.cluster import Cluster, PodObj
from repro.core.dag import make_workflow
from repro.core.informer import InformerSet
from repro.core.runner import ControlPlane
from repro.core.sim import Sim

# sha256 over the binding sequence "ns/pod->node@t" for the contended
# scenario below, recorded on the pre-zero-copy core (commit cf583ed)
# — the shared views, the batched walk and the fused native cycle must
# not move a single binding
PINNED_PRE_VIEWS = {
    "preempt": ("e30b8c5ac24208619acd147ffb7338fcc9d9d8ee18ea920a7eef87e3a837a8db", 67),
    "quota": ("3654b76a03ede03d0323758873d7f7ca6f982056a478358519e6e6a381162045", 66),
    "drf": ("bbdd0e4cf84e2e21bba820f9bbb73adfd51470cc63b0bbdd3b158357a41f556d", 66),
}


def _views_plane(policy, seed=21):
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=cal.PaperCluster(n_nodes=2), seed=seed,
                         usage_mode="event")
    fan = make_workflow("fan", wide_fanout(width=10))
    mont = make_workflow("montage", get_workflow_spec("montage"))
    plane.add_stream(fan, repeats=2, tenant="hi", arrival="concurrent",
                     concurrency=2, priority=8, weight=2.0)
    plane.add_stream(mont, repeats=2, tenant="lo", arrival="poisson",
                     rate=0.4, burst=2, priority=0, weight=1.0)
    return plane


def _run_bindings(plane):
    seq = []
    orig = plane.cluster._bind

    def record(pod, node):
        seq.append(f"{pod.namespace}/{pod.name}->{node.name}"
                   f"@{plane.sim.now():.4f}")
        orig(pod, node)

    plane.cluster._bind = record
    res = plane.run(horizon_s=500_000)
    return seq, res


@pytest.mark.parametrize("policy", sorted(PINNED_PRE_VIEWS))
def test_binding_hashes_unmoved_by_views(policy):
    seq, _res = _run_bindings(_views_plane(policy))
    digest = hashlib.sha256("\n".join(seq).encode()).hexdigest()
    want_digest, want_n = PINNED_PRE_VIEWS[policy]
    assert len(seq) == want_n
    assert digest == want_digest, \
        f"zero-copy views moved the {policy!r} binding sequence"


# ---------------------------------------------------------------------------
# the snapshot guarantee under sharing
# ---------------------------------------------------------------------------
def _pod_fields(pod):
    return (pod.name, pod.namespace, pod.phase, pod.node, pod.created,
            pod.scheduled, pod.started, pod.finished, pod.deleted,
            pod.cpu_m, pod.mem_mi, pod.tenant, pod.evicted,
            pod.restarts)


def test_no_caller_observes_future_live_state():
    """Property: every object a handler or lister caller ever received
    reads EXACTLY as it did at delivery, even though the live objects
    kept mutating (binds, phase flips, evictions, deletions)."""
    plane = _views_plane("preempt")
    captured = []

    def grab(pod):
        captured.append((pod, _pod_fields(pod)))

    plane.informers.pods.add_handlers(on_add=grab, on_update=grab,
                                      on_delete=grab)

    def probe():
        for pod in plane.informers.pods.lister():
            captured.append((pod, _pod_fields(pod)))
        for node in plane.informers.nodes.lister():
            captured.append((node, (node.name, node.ready, node.cpu_used,
                                    node.mem_used)))
        if plane.sim.now() < 180.0:
            plane.sim.after(2.7, probe, daemon=True)

    plane.sim.after(1.0, probe, daemon=True)
    res = plane.run(horizon_s=500_000)
    assert res.arbiter.preemptions > 0          # live objects DID mutate
    assert len(captured) > 500
    seen_phases = {f[2] for _p, f in captured if isinstance(_p, PodObj)}
    assert {"Pending", "Running", "Succeeded"} <= seen_phases
    for obj, fields in captured:
        if isinstance(obj, PodObj):
            assert _pod_fields(obj) == fields, \
                "a handed-out pod view changed after delivery"
        else:
            assert (obj.name, obj.ready, obj.cpu_used, obj.mem_used) \
                == fields, "a handed-out node view changed after delivery"


def test_same_instant_transitions_deliver_distinct_views():
    """A duration-0 (virtual) pod goes Running and Succeeded at the
    same instant: the two MODIFIED events must carry two different
    frozen views, not one shared object showing the later phase."""
    plane = ControlPlane("kubeadaptor", seed=3)
    wf = make_workflow("montage", get_workflow_spec("montage"))  # has entry/exit
    phases = {}                                  # (ns, name) -> [phases]

    def on_update(pod):
        phases.setdefault((pod.namespace, pod.name), []).append(pod.phase)

    plane.informers.pods.add_handlers(on_update=on_update)
    plane.gateway.load([wf.with_instance(0)])
    plane.run(horizon_s=500_000)
    virt = [v for (ns, name), v in phases.items() if name in ("entry", "exit")]
    assert virt and all(v[:2] == ["Running", "Succeeded"] for v in virt)


# ---------------------------------------------------------------------------
# copy-on-write actually shares
# ---------------------------------------------------------------------------
def test_steady_state_resync_is_zero_copy():
    sim = Sim()
    cluster = Cluster(sim)
    informers = InformerSet(sim, cluster)
    cluster.create_namespace("ns1")
    sim.run()
    cluster.create_pod(PodObj(name="p0", namespace="ns1", task_id="p0",
                              workflow="w", cpu_m=100, mem_mi=100,
                              duration_s=1e9))
    # settle: bind + RUNNING transition + one resync materialize views
    interval = cal.DEFAULT_PARAMS.resync_interval
    sim.after(1.5 * interval, lambda: None)
    sim.run(until=sim.now() + 1.5 * interval)
    gen = informers.pods.generation
    node_gen = informers.nodes.generation
    ident = dict(informers.pods.cache)
    copies0 = cluster_mod.SNAPSHOTS_MADE
    # two more resync rounds with NOTHING changing
    sim.after(2.2 * interval, lambda: None)
    sim.run(until=sim.now() + 2.2 * interval)
    assert cluster_mod.SNAPSHOTS_MADE == copies0, \
        "steady-state resync materialized copies"
    assert informers.pods.generation == gen      # listers stay valid
    assert informers.nodes.generation == node_gen
    assert dict(informers.pods.cache) == ident
    for k, obj in informers.pods.cache.items():
        assert obj is ident[k], "resync replaced an unchanged view"
    # ... and the reconciler still works on top of the shared views
    assert informers.pods.nonterminal_cpu == 100


def test_views_share_between_watch_and_resync():
    """The cache entry, the lister row and a captured watch object are
    ONE object per (pod, revision) — that is the zero-copy claim."""
    sim = Sim()
    cluster = Cluster(sim)
    informers = InformerSet(sim, cluster)
    seen = []
    informers.pods.add_handlers(on_add=seen.append, on_update=seen.append)
    cluster.create_namespace("ns1")
    sim.run()
    cluster.create_pod(PodObj(name="p0", namespace="ns1", task_id="p0",
                              workflow="w", cpu_m=100, mem_mi=100,
                              duration_s=1e9))
    sim.run(until=sim.now() + 40.0)       # includes a resync
    assert seen
    cached = informers.pods.cache[("ns1", "p0")]
    assert cached is seen[-1]             # cache holds the delivered view
    assert cached in informers.pods.lister()
    assert cached is not cluster.pods[("ns1", "p0")]   # never the live obj


# ---------------------------------------------------------------------------
# fused native cycle == pure-Python cluster, end to end
# ---------------------------------------------------------------------------
def test_native_and_python_cluster_paths_identical():
    import repro.core.shuffle as shuffle_mod
    if shuffle_mod._load_native() is None:
        pytest.skip("no native backend on this host")

    def run_once():
        return _run_bindings(_views_plane("drf"))[0]

    native_seq = run_once()
    saved = (shuffle_mod._native_lib, shuffle_mod._native_tried)
    shuffle_mod._native_lib, shuffle_mod._native_tried = None, True
    try:
        python_seq = run_once()
    finally:
        shuffle_mod._native_lib, shuffle_mod._native_tried = saved
    assert native_seq == python_seq


# ---------------------------------------------------------------------------
# batched multi-grant admission
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["fair-share", "drf", "quota"])
def test_batched_walk_matches_generic_on_deep_backlog(policy):
    """One walk call grants MANY requests (wide fanouts, roomy
    cluster): the batched single-pass walk must reproduce the generic
    per-grant re-sort loop's grant sequence exactly."""
    import repro.core.resources as rs

    def run(fast):
        grants = []
        orig_init = rs.AdmissionArbiter.__init__
        orig_ck = rs.AdmissionArbiter._create_bookkeep

        def pinit(self, *a, **k):
            orig_init(self, *a, **k)
            self._fast = fast

        def pck(self, req):
            grants.append((self.inf.pods.sim.now(), req.namespace,
                           req.task.id))
            return orig_ck(self, req)

        rs.AdmissionArbiter.__init__ = pinit
        rs.AdmissionArbiter._create_bookkeep = pck
        try:
            plane = ControlPlane("kubeadaptor", admission_policy=policy,
                                 cluster_cfg=cal.PaperCluster(n_nodes=4),
                                 seed=17, usage_mode="event")
            fan = make_workflow("fan", wide_fanout(width=24))
            mont = make_workflow("montage", get_workflow_spec("montage"))
            plane.add_stream(fan, repeats=2, tenant="a",
                             arrival="concurrent", concurrency=2, weight=3.0)
            plane.add_stream(fan.with_tenant("b"), repeats=2, tenant="b",
                             arrival="concurrent", concurrency=2, weight=1.0)
            plane.add_stream(mont, repeats=2, tenant="c", arrival="poisson",
                             rate=0.5, burst=2, weight=2.0)
            res = plane.run(horizon_s=500_000)
            return (grants, res.arbiter.deferrals, res.arbiter.admitted,
                    res.arbiter.grant_batches)
        finally:
            rs.AdmissionArbiter.__init__ = orig_init
            rs.AdmissionArbiter._create_bookkeep = orig_ck

    fast = run(True)
    generic = run(False)
    # identical grant sequence / deferral / admit counts ...
    assert fast[:3] == generic[:3]
    # ... and the fast walk genuinely multi-grants: far fewer admission
    # rounds than grants (the generic loop re-enters per grant, so its
    # batch counter is only bounded by the evaluate count)
    assert 0 < fast[3] < fast[2]


def test_grant_batches_counts_multi_grant_rounds():
    plane = _views_plane("fifo")
    res = plane.run(horizon_s=500_000)
    arb = res.arbiter
    assert 0 < arb.grant_batches <= arb.admitted
    assert arb.admitted == sum(t.granted for t in arb.tenants.values())
