"""Durable submission front door (ISSUE 10): WAL, backpressure,
exactly-once shard crash recovery.

Guarantee layers:

* stream spawning: the gate's retry-jitter stream is sha256-spawned
  under its own tag — reproducible, per-shard distinct, decorrelated
  from the scheduler/chaos/shard seeds;
* WAL integrity: records are sha256-chained, the head hash detects any
  mutation, a torn tail line (crash mid-write) is truncated on load, a
  corrupt complete record or a diverging replay raises instead of
  silently double-running;
* bit-identity: an unsaturated gateway adds zero sim events and zero
  draws — binding sequences, event counts, and tenant summaries match
  the gateway-off run exactly (so every pre-existing pinned hash holds
  with the gate armed but idle);
* backpressure: ``peak_pending`` never exceeds ``max_pending``, the
  ledger balances exactly (admitted + shed == submissions, queued
  drains to 0), and the three shed modes differ in WHO is dropped but
  all preserve the accounting identity;
* exactly-once: chaos transport drops are recovered by WAL redelivery
  and duplicates are suppressed by the dedup set — every submission id
  reaches the engine at most once, and a crash between the WAL append
  and the engine submit delivers exactly once on restart;
* crash recovery (the tentpole pin): a mid-run shard kill + restart
  replays the WAL prefix under verification; merged behavioral metrics
  are bit-identical to a never-crashed same-seed run under all six
  admission policies, and the recovered WAL file is byte-identical to
  the clean run's.
"""
import json
import os

import pytest

from repro.configs.workflows import get_workflow_spec
from repro.core import calibration as cal
from repro.core.chaos import ChaosSchedule, chaos_stream_seed
from repro.core.dag import make_workflow
from repro.core.gateway import (WAL_GENESIS, BackpressurePolicy,
                                SubmissionWAL, WalReplayError,
                                gate_stream_seed, merge_gateway_snapshots,
                                workflow_digest)
from repro.core.runner import ControlPlane
from repro.core.shard import ShardedControlPlane, shard_seed

MONTAGE = make_workflow("montage", get_workflow_spec("montage"))
EPIGENOMICS = make_workflow("epigenomics", get_workflow_spec("epigenomics"))

ALL_POLICIES = ("fifo", "priority", "fair-share", "drf", "quota", "preempt")


def _canon(obj):
    """NaN-tolerant deep compare form (NaN != NaN breaks dict ==)."""
    if isinstance(obj, dict):
        return {k: _canon(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, float) and obj != obj:
        return "nan"
    return obj


# --------------------------------------------------------------------------
# policy validation + stream spawning
# --------------------------------------------------------------------------
def test_backpressure_policy_validation():
    p = BackpressurePolicy(max_pending=8, per_tenant_cap=2,
                           shed="fair-shed", retry_after_s=3.0,
                           max_client_retries=5)
    assert p.shed == "fair-shed"
    with pytest.raises(ValueError):
        BackpressurePolicy(max_pending=0)
    with pytest.raises(ValueError):
        BackpressurePolicy(per_tenant_cap=-1)
    with pytest.raises(ValueError):
        BackpressurePolicy(retry_after_s=0.0)
    with pytest.raises(ValueError):
        BackpressurePolicy(shed="drop-table")
    # frozen + picklable: it crosses the fork inside ShardSpec
    import pickle
    assert pickle.loads(pickle.dumps(p)) == p
    with pytest.raises(Exception):
        p.max_pending = 9


def test_gate_stream_seed_spawning():
    assert gate_stream_seed(42, 0) == gate_stream_seed(42, 0)
    assert gate_stream_seed(42, 0) != gate_stream_seed(42, 1)
    assert gate_stream_seed(42, 0) != gate_stream_seed(43, 0)
    # decorrelated from the other sha256-spawned consumers of the seed
    assert gate_stream_seed(42, 0) != shard_seed(42, 0)
    assert gate_stream_seed(42, 0) != chaos_stream_seed(42)
    per_shard = [gate_stream_seed(7, i) for i in range(16)]
    assert len(set(per_shard)) == 16


def test_workflow_digest_is_deterministic_and_keyed():
    d = workflow_digest("prod", "montage", 3)
    assert d == workflow_digest("prod", "montage", 3)
    assert len(d) == 16
    assert d != workflow_digest("prod", "montage", 4)
    assert d != workflow_digest("batch", "montage", 3)


# --------------------------------------------------------------------------
# WAL: chain integrity, file sink, torn tail, replay verification
# --------------------------------------------------------------------------
def test_wal_chain_and_segments():
    wal = SubmissionWAL(segment_size=3)
    for i in range(8):
        rec = wal.append(f"t{i % 2}", float(i), workflow_digest("t", "m", i))
        assert rec["id"] == i
    assert wal.count == 8
    assert len(wal.segments) == 3          # 3+3+2 under segment_size=3
    assert [r["id"] for r in wal.records()] == list(range(8))
    assert wal.chain != WAL_GENESIS
    assert wal.verify()
    # any in-place mutation breaks the running head hash
    wal.segments[1][0]["tenant"] = "evil"
    assert not wal.verify()
    with pytest.raises(ValueError):
        SubmissionWAL(segment_size=0)


def _fill(wal, n):
    for i in range(n):
        wal.append("prod", float(i), workflow_digest("prod", "montage", i))


def test_wal_file_sink_and_replay(tmp_path):
    path = str(tmp_path / "shard-0.wal")
    first = SubmissionWAL(path=path)
    _fill(first, 5)
    chain = first.chain
    first.close()
    assert len(open(path).read().splitlines()) == 5

    # a new incarnation replays the durable prefix: records verified
    # field-for-field, NOT rewritten, and the chain head matches
    second = SubmissionWAL(path=path)
    _fill(second, 5)
    assert second.replayed == 5
    assert second.chain == chain
    second.close()
    assert open(path).read() == open(path).read()  # idempotent on disk


def test_wal_truncates_torn_tail(tmp_path):
    path = str(tmp_path / "shard-0.wal")
    wal = SubmissionWAL(path=path)
    _fill(wal, 4)
    wal.close()
    whole = open(path).read()
    # crash mid-write: a partial last line with no terminating newline
    with open(path, "a") as f:
        f.write('{"id":4,"tenant":"pr')
    recovered = SubmissionWAL(path=path)
    _fill(recovered, 4)
    assert recovered.replayed == 4         # the valid prefix survived
    recovered.close()
    assert open(path).read() == whole      # the torn tail is gone


def test_wal_replay_divergence_and_corruption_raise(tmp_path):
    path = str(tmp_path / "shard-0.wal")
    wal = SubmissionWAL(path=path)
    _fill(wal, 3)
    wal.close()
    # regenerated arrivals that disagree with the log must never
    # silently double-run
    diverged = SubmissionWAL(path=path)
    diverged.append("prod", 0.0, workflow_digest("prod", "montage", 0))
    with pytest.raises(WalReplayError):
        diverged.append("prod", 99.0, workflow_digest("prod", "montage", 1))
    diverged.close()
    # a corrupt COMPLETE record (newline-terminated, mid-file) is not a
    # torn tail: fail loudly instead of truncating real history
    lines = open(path).read().splitlines()
    lines[1] = lines[1][:-2] + "!!"        # no longer valid JSON
    (tmp_path / "bad.wal").write_text("\n".join(lines) + "\n")
    with pytest.raises(WalReplayError):
        SubmissionWAL(path=str(tmp_path / "bad.wal"))
    # a value-level mutation survives load (the line is still
    # well-formed) but is caught the moment replay regenerates the
    # true record — the chain head is authoritative either way
    lines = open(path).read().splitlines()
    lines[1] = lines[1].replace('"tenant":"prod"', '"tenant":"evil"')
    (tmp_path / "mut.wal").write_text("\n".join(lines) + "\n")
    mutated = SubmissionWAL(path=str(tmp_path / "mut.wal"))
    mutated.append("prod", 0.0, workflow_digest("prod", "montage", 0))
    with pytest.raises(WalReplayError):
        mutated.append("prod", 1.0, workflow_digest("prod", "montage", 1))
    mutated.close()


# --------------------------------------------------------------------------
# single-plane runs: bit-identity off==idle, bounds, shed modes
# --------------------------------------------------------------------------
def _run_single(gateway=None, wal_path=None, chaos=None, seed=7,
                policy="fair-share", n_nodes=8, repeats=5, concurrency=2):
    plane = ControlPlane(
        "kubeadaptor", admission_policy=policy, seed=seed,
        cluster_cfg=cal.PaperCluster(n_nodes=n_nodes),
        sample_mode="streaming", usage_mode="event",
        retain_pod_log=False, lifecycle="fast", chaos=chaos,
        gateway=gateway, wal_path=wal_path)
    bindings = []
    inner = plane.cluster._bind

    def recording_bind(pod, node):
        bindings.append(f"{pod.namespace}/{pod.name}->{node.name}"
                        f"@{plane.sim.now():.4f}")
        return inner(pod, node)

    plane.cluster._bind = recording_bind
    plane.add_stream(MONTAGE, repeats=repeats, tenant="prod",
                     arrival="concurrent", concurrency=concurrency,
                     priority=10, weight=3.0, deadline_s=1800.0)
    plane.add_stream(EPIGENOMICS, repeats=repeats, tenant="batch",
                     arrival="poisson", rate=0.5, burst=2,
                     deadline_s=3600.0)
    res = plane.run()
    return res, bindings


def _strip_gateway(summary):
    """tenant_summary minus the gateway_* columns the armed run adds."""
    return {t: {k: v for k, v in row.items()
                if not k.startswith("gateway_")}
            for t, row in summary.items()}


def test_unsaturated_gateway_is_bit_identical_to_disabled():
    res_off, b_off = _run_single(gateway=None)
    res_idle, b_idle = _run_single(
        gateway=BackpressurePolicy(max_pending=10_000))
    # zero draws, zero extra events: the traces are identical
    assert b_idle == b_off
    assert res_idle.sim.events_processed == res_off.sim.events_processed
    assert _canon(_strip_gateway(res_idle.metrics.tenant_summary())) == \
        _canon(res_off.metrics.tenant_summary())
    snap = res_idle.gate.snapshot()
    assert snap["totals"]["rejected"] == 0
    assert snap["totals"]["shed"] == 0
    assert snap["totals"]["admitted"] == snap["totals"]["submissions"] == 10
    assert snap["totals"]["done"] == 10
    assert snap["wal"] == {"records": 10, "replayed": 0,
                           "chain": res_idle.gate.wal.chain}
    assert res_idle.gate.wal.verify()


def test_backpressure_bounds_and_exact_accounting():
    pol = BackpressurePolicy(max_pending=3, retry_after_s=10.0,
                             max_client_retries=40)
    res, _ = _run_single(gateway=pol, repeats=8, concurrency=4)
    snap = res.gate.snapshot()
    tot = snap["totals"]
    assert snap["peak_pending"] <= 3
    assert tot["rejected"] > 0             # the scenario genuinely saturates
    assert tot["queued"] == 0 and tot["running"] == 0   # fully drained
    assert tot["admitted"] + tot["shed"] == tot["submissions"] == 16
    assert tot["done"] == tot["admitted"]
    assert tot["retried"] > 0
    assert snap["retry_horizon_t"] > 0.0
    # per-tenant rows sum to the totals
    for key in ("submissions", "admitted", "rejected", "shed", "done"):
        assert sum(r[key] for r in snap["tenants"].values()) == tot[key]
    # satellite 6: the arbiter exposes the same counts
    arb = res.arbiter.counters()
    assert arb["gateway_rejects"] == tot["rejected"]
    assert arb["gateway_retries"] == tot["retried"]
    assert arb["gateway_shed"] == tot["shed"]
    # and the tenant summary reports them without gateway internals
    summary = res.metrics.tenant_summary()
    for tenant, row in snap["tenants"].items():
        assert summary[tenant]["gateway_rejects"] == float(row["rejected"])
        assert summary[tenant]["gateway_shed"] == float(row["shed"])


def test_per_tenant_cap_rejects_below_global_bound():
    pol = BackpressurePolicy(max_pending=1_000, per_tenant_cap=1,
                             retry_after_s=10.0, max_client_retries=40)
    res, _ = _run_single(gateway=pol, repeats=6, concurrency=3)
    snap = res.gate.snapshot()
    # the global bound was never under pressure — every rejection came
    # from the per-tenant slice
    assert snap["peak_pending"] < 1_000
    assert snap["totals"]["rejected"] > 0
    assert snap["totals"]["admitted"] + snap["totals"]["shed"] == \
        snap["totals"]["submissions"]


def test_shed_modes_bound_waiting_room_and_balance():
    seen = {}
    for shed in ("reject-newest", "shed-oldest", "fair-shed"):
        pol = BackpressurePolicy(max_pending=2, shed=shed,
                                 retry_after_s=50.0,
                                 max_client_retries=2)
        res, _ = _run_single(gateway=pol, repeats=8, concurrency=4)
        snap = res.gate.snapshot()
        tot = snap["totals"]
        assert snap["peak_pending"] <= 2
        assert tot["admitted"] + tot["shed"] == tot["submissions"]
        assert tot["queued"] == 0
        assert tot["shed"] > 0
        if shed != "reject-newest":
            # server-side eviction bounds the retry room itself
            assert snap["peak_waiting"] <= pol.max_pending
        seen[shed] = (tot["admitted"], tot["shed"],
                      {t: r["shed"] for t, r in snap["tenants"].items()})
    # the disciplines genuinely differ in who (or how many) gets dropped
    assert len({v[:2] for v in seen.values()}) > 1 or \
        len({tuple(sorted(v[2].items())) for v in seen.values()}) > 1
    # fair-shed targets the tenant hogging the retry room — here the
    # concurrent-burst tenant, not the trickling poisson one
    fair = seen["fair-shed"][2]
    assert fair.get("prod", 0) > 0


def test_same_seed_run_is_exactly_reproducible():
    pol = BackpressurePolicy(max_pending=3, retry_after_s=10.0,
                             max_client_retries=40)
    res_a, b_a = _run_single(gateway=pol, repeats=8, concurrency=4)
    res_b, b_b = _run_single(gateway=pol, repeats=8, concurrency=4)
    assert b_a == b_b
    assert res_a.gate.snapshot() == res_b.gate.snapshot()
    assert _canon(res_a.metrics.tenant_summary()) == \
        _canon(res_b.metrics.tenant_summary())
    assert res_a.gate.trace_events() == res_b.gate.trace_events()


# --------------------------------------------------------------------------
# exactly-once under chaos transport faults
# --------------------------------------------------------------------------
def test_chaos_drop_and_dup_are_recovered_exactly_once():
    pol = BackpressurePolicy(max_pending=10_000, retry_after_s=5.0)
    chaos = ChaosSchedule(seed=3, gateway_drop_rate=0.2,
                          gateway_dup_rate=0.2)
    res, _ = _run_single(gateway=pol, chaos=chaos, repeats=6)
    snap = res.gate.snapshot()
    f = snap["faults"]
    assert f["dropped"] > 0 and f["duplicated"] > 0
    # every duplicate was suppressed, every drop redelivered
    assert f["deduped"] == f["duplicated"]
    assert f["redelivered"] >= f["dropped"]
    assert snap["totals"]["done"] == snap["totals"]["submissions"] == 12
    assert res.metrics.export_partial().completed == 12
    assert res.chaos.counters()["gateway_drops"] == f["dropped"]
    assert res.chaos.counters()["gateway_dups"] == f["duplicated"]


def test_gateway_fault_draw_requires_armed_rates():
    # both rates zero => zero draws (the PR-7 chaos stream is untouched
    # by an armed-but-fault-free gateway)
    chaos = ChaosSchedule(seed=3)
    assert not chaos.active
    assert ChaosSchedule(seed=3, gateway_drop_rate=0.1).active


# --------------------------------------------------------------------------
# crash recovery: WAL replay on a fresh plane
# --------------------------------------------------------------------------
def test_wal_replay_after_crash_is_bit_identical(tmp_path):
    pol = BackpressurePolicy(max_pending=10_000)
    clean_path = str(tmp_path / "clean.wal")
    res_clean, b_clean = _run_single(gateway=pol, wal_path=clean_path)
    res_clean.gate.close()
    clean_bytes = open(clean_path, "rb").read()

    # simulate a crash that persisted only the first K submissions
    crash_path = str(tmp_path / "crashed.wal")
    lines = clean_bytes.decode().splitlines()
    with open(crash_path, "w") as f:
        f.write("\n".join(lines[:4]) + "\n")
    res_rec, b_rec = _run_single(gateway=pol, wal_path=crash_path)
    snap = res_rec.gate.snapshot()
    assert snap["wal"]["replayed"] == 4    # the prefix was verified
    assert snap["wal"]["records"] == len(lines)
    assert snap["wal"]["chain"] == res_clean.gate.wal.chain
    res_rec.gate.close()
    # the recovered log converges to the clean run's bytes, and the
    # behavioral result is bit-identical
    assert open(crash_path, "rb").read() == clean_bytes
    assert b_rec == b_clean
    assert _canon(res_rec.metrics.tenant_summary()) == \
        _canon(res_clean.metrics.tenant_summary())


def test_kill_between_wal_append_and_submit(tmp_path):
    """The nastiest window: the WAL holds a record the engine never saw
    (the worker died after append, before the arbiter submit).  On
    restart the regenerated arrival replays against the logged record
    and is delivered exactly once."""
    pol = BackpressurePolicy(max_pending=10_000)
    # build the one-record WAL the doomed incarnation left behind: the
    # first submission of the same seeded workload
    probe, _ = _run_single(gateway=pol)
    first = probe.gate.wal.records()[0]
    path = str(tmp_path / "shard-0.wal")
    orphan = SubmissionWAL(path=path)
    orphan.append(first["tenant"], first["t"], first["digest"])
    orphan.close()

    res, _ = _run_single(gateway=pol, wal_path=path)
    snap = res.gate.snapshot()
    assert snap["wal"]["replayed"] == 1
    assert snap["totals"]["done"] == snap["totals"]["submissions"] == 10
    assert snap["faults"]["deduped"] == 0  # delivered once, not twice
    assert res.metrics.export_partial().completed == 10
    res.gate.close()


# --------------------------------------------------------------------------
# sharded plane: merge exactness + the tentpole crash-recovery pin
# --------------------------------------------------------------------------
GATE = BackpressurePolicy(max_pending=64, retry_after_s=5.0,
                          max_client_retries=20)


def _sharded(processes, policy="fair-share", wal_dir=None, **kw):
    plane = ShardedControlPlane(
        2, admission_policy=policy, seed=42,
        cluster_cfg=cal.PaperCluster(n_nodes=8),
        sample_mode="streaming", usage_mode="event", retain_pod_log=False,
        lifecycle="fast", processes=processes, heartbeat_s=0.2,
        gateway=GATE, wal_dir=wal_dir, **kw)
    # tenant names span both shards under the crc32 partition:
    # batch-a/alpha -> shard 0, prod-a/gamma -> shard 1
    for tenant in ("batch-a", "prod-a"):
        plane.add_stream(MONTAGE, repeats=4, tenant=tenant,
                         arrival="concurrent", concurrency=2, priority=10,
                         weight=3.0, deadline_s=180.0)
    for tenant in ("alpha", "gamma"):
        plane.add_stream(EPIGENOMICS, repeats=4, tenant=tenant,
                         arrival="poisson", rate=0.5, burst=2,
                         deadline_s=3600.0)
    return plane


def test_sharded_inline_equals_forked_with_gateway():
    r_in = _sharded(processes=False).run()
    r_mp = _sharded(processes=True).run()
    assert _canon(r_in.tenant_summary()) == _canon(r_mp.tenant_summary())
    assert r_in.gateway_summary() == r_mp.gateway_summary()
    assert r_in.completed_workflows == r_mp.completed_workflows == 16
    gw = r_in.gateway_summary()
    assert gw["totals"]["submissions"] == 16
    assert gw["totals"]["done"] == 16
    assert r_in.peak_pending_gateway == max(
        s["gateway"]["peak_pending"] for s in r_in.shards)
    # per-shard tenants are disjoint, so the merged totals are exact
    assert sum(s["gateway"]["totals"]["submissions"]
               for s in r_in.shards) == 16


def test_merge_gateway_snapshots_sums_and_maxes():
    r = _sharded(processes=False).run()
    snaps = [s["gateway"] for s in r.shards]
    merged = merge_gateway_snapshots(snaps)
    assert merged == r.gateway_summary()
    for key in ("submissions", "admitted", "done"):
        assert merged["totals"][key] == \
            sum(s["totals"][key] for s in snaps)
    assert merged["peak_pending"] == max(s["peak_pending"] for s in snaps)
    assert merged["wal"]["records"] == sum(s["wal"]["records"]
                                           for s in snaps)
    assert "chain" not in merged.get("wal", {})   # per-log, never merged
    assert merge_gateway_snapshots([]) == {}
    assert merge_gateway_snapshots([None, snaps[0]])["totals"] == \
        snaps[0]["totals"]


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_midrun_kill_restart_is_bit_identical(policy, tmp_path,
                                              monkeypatch):
    """The tentpole pin: kill shard 1 mid-run (os._exit at a sim
    instant, after real submissions and WAL appends), restart it, and
    the merged behavioral metrics are bit-identical to a never-crashed
    same-seed run — under every admission policy.  The restarted
    shard's WAL replay is observable (replayed > 0) and its final log
    file is byte-identical to the clean run's."""
    clean_dir = str(tmp_path / "clean")
    clean = _sharded(processes=True, policy=policy, wal_dir=clean_dir).run()
    kill_t = clean.sim_makespan_s / 2.0
    crash_dir = str(tmp_path / "crash")
    monkeypatch.setenv("REPRO_SHARD_KILL", f"1@{kill_t}")
    crashed = _sharded(processes=True, policy=policy, wal_dir=crash_dir,
                       on_shard_failure="restart").run()
    assert not crashed.degraded
    gw = crashed.gateway_summary()
    assert gw["wal"]["replayed"] > 0       # the restart really replayed
    assert _canon(crashed.tenant_summary()) == _canon(clean.tenant_summary())
    assert crashed.completed_workflows == clean.completed_workflows == 16
    # gateway summaries agree on everything but the replay provenance
    gw_clean = clean.gateway_summary()
    assert gw_clean["wal"]["replayed"] == 0
    gw["wal"]["replayed"] = 0
    assert gw == gw_clean
    # the recovered logs converge to the clean run's bytes
    for i in range(2):
        a = open(os.path.join(clean_dir, f"shard-{i}.wal"), "rb").read()
        b = open(os.path.join(crash_dir, f"shard-{i}.wal"), "rb").read()
        assert a == b


def test_shard_restart_merge_has_no_double_count(monkeypatch):
    """Satellite 1 (PR-7 audit): a killed shard sends NO result record
    — only the restarted incarnation's record reaches the merge, so
    nothing is counted twice."""
    healthy = _sharded(processes=True).run()
    monkeypatch.setenv("REPRO_SHARD_KILL", "1")
    restarted = _sharded(processes=True, on_shard_failure="restart").run()
    assert not restarted.degraded
    # exactly one record per shard index in the merged result
    assert sorted(s["shard"] for s in restarted.shards) == [0, 1]
    assert _canon(restarted.tenant_summary()) == \
        _canon(healthy.tenant_summary())
    assert restarted.completed_workflows == healthy.completed_workflows
    assert restarted.events == healthy.events
    # per-shard event counts sum exactly once into the merged total
    assert sum(s["events"] for s in restarted.shards) == restarted.events


# --------------------------------------------------------------------------
# arrival_trace/v2 (satellite 2)
# --------------------------------------------------------------------------
def test_trace_v2_records_gateway_events_and_v1_still_loads(tmp_path):
    pol = BackpressurePolicy(max_pending=3, retry_after_s=10.0,
                             max_client_retries=40)
    plane = ControlPlane(
        "kubeadaptor", admission_policy="fair-share", seed=7,
        cluster_cfg=cal.PaperCluster(n_nodes=8),
        sample_mode="streaming", usage_mode="event",
        retain_pod_log=False, lifecycle="fast", gateway=pol)
    plane.add_stream(MONTAGE, repeats=8, tenant="prod",
                     arrival="concurrent", concurrency=4)
    res = plane.run()
    path = str(tmp_path / "trace.json")
    doc = plane.record_trace(path)
    assert doc["schema"] == "arrival_trace/v2"
    assert json.loads(open(path).read()) == doc
    assert doc["gateway"]["policy"]["max_pending"] == 3
    kinds = {e["event"] for e in doc["gateway"]["events"]}
    assert "reject" in kinds
    assert all(set(e) == {"t", "id", "tenant", "event"}
               for e in doc["gateway"]["events"])
    # a v2 doc replays through the v1 loader (arrivals are unchanged)
    replay = ControlPlane("kubeadaptor", seed=7)
    replay.add_trace(doc["arrivals"], tenants=doc["tenants"])
    res2 = replay.run()
    assert res2.metrics.export_partial().completed == \
        res.metrics.export_partial().completed
    # and a genuine v1 doc (no gateway) still loads — schema untouched
    v1 = json.load(open(os.path.join(
        os.path.dirname(__file__), "..", "examples", "trace_mixed.json")))
    assert v1["schema"] == "arrival_trace/v1"
    v1_plane = ControlPlane("kubeadaptor", seed=1)
    v1_plane.add_trace(v1["arrivals"], tenants=v1.get("tenants"))


# --------------------------------------------------------------------------
# arbiter exposure (satellite 6)
# --------------------------------------------------------------------------
def test_arbiter_counters_expose_gateway_pressure():
    arb = ControlPlane("kubeadaptor", admission_policy="fifo").arbiter
    c = arb.counters()
    assert c["gateway_rejects"] == 0
    assert c["gateway_retries"] == 0
    assert c["gateway_shed"] == 0
    arb.note_gateway("reject")
    arb.note_gateway("retry")
    arb.note_gateway("retry")
    arb.note_gateway("shed")
    c = arb.counters()
    assert (c["gateway_rejects"], c["gateway_retries"],
            c["gateway_shed"]) == (1, 2, 1)
    with pytest.raises(ValueError):
        arb.note_gateway("explode")


def test_runner_rejects_wal_without_gateway():
    with pytest.raises(ValueError):
        ControlPlane("kubeadaptor", wal_path="/tmp/nope.wal")
