"""Test helpers: subprocess runner for multi-host-device tests."""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def run_subprocess(code: str, devices: int = 8, timeout: int = 520) -> str:
    """Run a python snippet with N fake host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=str(ROOT))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout
