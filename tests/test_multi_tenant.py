"""Multi-tenant control plane: gateway streams, admission policies,
per-tenant metrics (beyond-paper; see core/runner.py architecture)."""
import math

import pytest

from repro.configs.workflows import (TENANT_SCENARIOS, get_workflow_spec,
                                     wide_fanout)
from repro.core.injector import StreamSpec
from repro.core import calibration as cal
from repro.core.dag import make_workflow
from repro.core.resources import ADMISSION_POLICIES
from repro.core.runner import ControlPlane, run_experiment


def _wf(name):
    return make_workflow(name, get_workflow_spec(name))


def _wide_wf(name):
    """Fan-out DAG that keeps many tasks ready at once — sustained
    admission pressure, unlike the paper DAGs' narrow phases."""
    return make_workflow(name, wide_fanout())


def _contended(policy, weights=(1.0, 1.0), priorities=(0, 0), seed=5):
    """Two tenants, fixed-concurrency streams, 2-node cluster (capacity
    ~13 task pods) so admission is the bottleneck."""
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=cal.PaperCluster(n_nodes=2), seed=seed)
    plane.add_stream(_wide_wf("wa"), repeats=4, tenant="alice",
                     arrival="concurrent", concurrency=2,
                     weight=weights[0], priority=priorities[0])
    plane.add_stream(_wide_wf("wb"), repeats=4, tenant="bob",
                     arrival="concurrent", concurrency=2,
                     weight=weights[1], priority=priorities[1])
    return plane.run(horizon_s=100_000)


def _contention_cpu(res, a="alice", b="bob"):
    """Time-averaged bound CPU per tenant while BOTH tenants hold pods."""
    avg = res.metrics.contended_cpu([a, b])
    assert avg, "tenants never contended — scenario too small"
    return avg[a], avg[b]


# --------------------------------------------------------------------------
# concurrent multi-tenant streams keep per-workflow order consistency
# --------------------------------------------------------------------------
def test_concurrent_tenants_order_consistent():
    plane = ControlPlane("kubeadaptor", seed=7)
    plane.add_stream(_wf("montage"), repeats=2, tenant="alice",
                     arrival="concurrent", concurrency=2)
    plane.add_stream(_wf("cybershake"), repeats=2, tenant="bob",
                     arrival="concurrent", concurrency=2)
    res = plane.run(horizon_s=100_000)
    assert len(res.metrics.workflows) == 4
    for rec in res.metrics.workflows.values():
        assert rec.ns_deleted > 0, (rec.name, rec.instance)
        base = _wf(rec.name).with_tenant(rec.tenant).with_instance(rec.instance)
        assert res.metrics.order_consistent(base), (rec.name, rec.instance)
    # both tenants really overlapped in time
    a = res.metrics.tenant_records("alice")
    b = res.metrics.tenant_records("bob")
    assert min(r.ns_created for r in a) < max(r.ns_deleted for r in b)
    assert min(r.ns_created for r in b) < max(r.ns_deleted for r in a)


def test_tenant_namespaces_never_collide():
    plane = ControlPlane("kubeadaptor", seed=1)
    plane.add_stream(_wf("montage"), repeats=2, tenant="alice")
    plane.add_stream(_wf("montage"), repeats=2, tenant="bob")
    res = plane.run(horizon_s=100_000)
    # 4 records: gateway allocates unique instances per workflow name
    assert len(res.metrics.workflows) == 4
    assert all(r.ns_deleted > 0 for r in res.metrics.workflows.values())
    tenants = sorted(r.tenant for r in res.metrics.workflows.values())
    assert tenants == ["alice", "alice", "bob", "bob"]


# --------------------------------------------------------------------------
# admission policies
# --------------------------------------------------------------------------
def test_fair_share_splits_headroom_by_weight():
    res = _contended("fair-share", weights=(3.0, 1.0))
    ra, rb = _contention_cpu(res)
    assert ra / rb > 1.5, (ra, rb)      # 3:1 weights -> alice dominates
    s = res.metrics.tenant_summary()
    assert s["alice"]["makespan"] < s["bob"]["makespan"]


def test_fair_share_equal_weights_is_balanced():
    res = _contended("fair-share", weights=(1.0, 1.0))
    ra, rb = _contention_cpu(res)
    assert 0.7 < ra / rb < 1.4, (ra, rb)


def test_fifo_ignores_weights():
    res = _contended("fifo", weights=(3.0, 1.0))
    ra, rb = _contention_cpu(res)
    assert 0.7 < ra / rb < 1.4, (ra, rb)


def test_priority_tenant_finishes_first():
    res = _contended("priority", priorities=(10, 0))
    s = res.metrics.tenant_summary()
    fifo = _contended("fifo").metrics.tenant_summary()
    assert s["alice"]["makespan"] < s["bob"]["makespan"]
    # priority must actually buy alice something vs neutral fifo
    assert s["alice"]["makespan"] < fifo["alice"]["makespan"]


def test_contention_is_tracked():
    res = _contended("fifo")
    assert res.arbiter.deferrals > 0
    assert res.arbiter.admitted > 0
    assert sum(res.metrics.admission_deferrals.values()) == res.arbiter.deferrals
    for tenant in ("alice", "bob"):
        assert res.arbiter.tenants[tenant].granted > 0


# --------------------------------------------------------------------------
# arrival processes
# --------------------------------------------------------------------------
def test_poisson_arrivals_drain_within_horizon():
    plane = ControlPlane("kubeadaptor", seed=11)
    plane.add_stream(_wf("ligo"), repeats=5, tenant="poisson-tenant",
                     arrival="poisson", rate=0.05, burst=2)
    res = plane.run(horizon_s=100_000)
    assert res.gateway.pending() == 0
    recs = res.metrics.tenant_records("poisson-tenant")
    assert len(recs) == 5
    for r in recs:
        assert r.ns_deleted > 0
        base = _wf("ligo").with_tenant(r.tenant).with_instance(r.instance)
        assert res.metrics.order_consistent(base)
    # arrivals are open-loop: submission times spread over the rate's scale
    subs = sorted(r.submitted_at for r in recs)
    assert subs[-1] > subs[0]


def test_serial_stream_is_closed_loop():
    """Serial arrival reproduces the paper's next-workflow trigger: each
    instance is handed off only after the previous one completed."""
    plane = ControlPlane("kubeadaptor", seed=2)
    plane.add_stream(_wf("montage"), repeats=3, tenant="default",
                     arrival="serial")
    res = plane.run(horizon_s=100_000)
    recs = sorted(res.metrics.workflows.values(), key=lambda r: r.submitted_at)
    assert len(recs) == 3
    for prev, nxt in zip(recs, recs[1:]):
        assert nxt.submitted_at >= prev.ns_deleted


def test_concurrent_stream_caps_in_flight():
    plane = ControlPlane("kubeadaptor", seed=3)
    plane.add_stream(_wf("cybershake"), repeats=4, tenant="default",
                     arrival="concurrent", concurrency=2)
    res = plane.run(horizon_s=100_000)
    recs = sorted(res.metrics.workflows.values(), key=lambda r: r.submitted_at)
    # first two go out together; the 3rd only after one of them finished
    assert recs[1].submitted_at - recs[0].submitted_at < 1.0
    assert recs[2].submitted_at >= min(recs[0].ns_deleted, recs[1].ns_deleted)


# --------------------------------------------------------------------------
# knob validation + registries + baselines through the gateway
# --------------------------------------------------------------------------
def test_registries_and_validation():
    assert set(ADMISSION_POLICIES) == {"fifo", "priority", "fair-share"}
    with pytest.raises(ValueError):
        ControlPlane("kubeadaptor", admission_policy="lottery")
    with pytest.raises(ValueError):
        ControlPlane("kubeadaptor", scheduler="magic")
    with pytest.raises(ValueError):
        ControlPlane("no-such-engine")
    with pytest.raises(ValueError):
        StreamSpec(workflow=_wf("montage"), arrival="fractal")
    with pytest.raises(ValueError):
        StreamSpec(workflow=_wf("montage"), arrival="poisson", rate=0.0)


@pytest.mark.parametrize("engine", ["batchjob", "argo"])
def test_baseline_engines_accept_multi_tenant_streams(engine):
    plane = ControlPlane(engine, seed=4)
    plane.add_stream(_wf("montage"), repeats=1, tenant="alice")
    plane.add_stream(_wf("ligo"), repeats=1, tenant="bob")
    res = plane.run(horizon_s=100_000)
    assert len(res.metrics.workflows) == 2
    assert all(r.ns_deleted > 0 for r in res.metrics.workflows.values())


def test_run_experiment_backwards_compatible():
    wf = _wf("montage")
    res = run_experiment("kubeadaptor", wf, repeats=2, seed=7)
    for i in range(2):
        assert res.metrics.order_consistent(wf.with_instance(i))
    assert res.gateway is not None and res.arbiter is not None
    assert math.isfinite(res.metrics.avg_lifecycle("montage"))


def test_tenant_scenarios_presets_run():
    spec_list = TENANT_SCENARIOS["duel"]
    plane = ControlPlane("kubeadaptor", admission_policy="fair-share", seed=9)
    for kw in spec_list:
        kw = dict(kw)
        wf = _wf(kw.pop("workflow"))
        plane.add_stream(wf, **kw)
    res = plane.run(horizon_s=200_000)
    summary = res.metrics.tenant_summary()
    assert set(summary) == {"alice", "bob"}
    for agg in summary.values():
        assert agg["completed"] == agg["workflows"]
        assert math.isfinite(agg["makespan"])
