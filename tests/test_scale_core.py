"""Scale-out event core (ISSUE 2): exactness pins + satellite fixes.

The perf refactor (indexed cluster state, native/bulk shuffle, batched
watch fan-out, specialized admission walks, informer aggregates) must
not move a single scheduling decision. These tests pin:

* the disordered scheduler's pod->node binding sequence for fixed
  seeds — hashes recorded against the pre-refactor core;
* ExactShuffler draw-stream equivalence with ``random.shuffle`` on
  every backend;
* specialized admission walks vs the generic re-sort loop;
* informer aggregates vs a full cache scan;
* zero apiserver cost of listers, resync deletion reconciliation, the
  pvc informer cache, sim note diagnostics, and streaming metrics.
"""
import hashlib
import random

import pytest

from repro.configs.workflows import get_workflow_spec, wide_fanout
from repro.core import calibration as cal
from repro.core.cluster import Cluster, PodObj
from repro.core.dag import make_workflow
from repro.core.informer import Informer, InformerSet
from repro.core.runner import ControlPlane
from repro.core.shuffle import ExactShuffler, _load_native
from repro.core.sim import Sim
from repro.core.stats import StreamingStat

# sha256 over the binding sequence "ns/pod->node@t", recorded on the
# pre-optimization core (commit 1bd52e9) — the refactor must not move it
PINNED = {
    "paper": ("3832b6fec9f1d4afd55898e04dba44377eb37258b3fb3b19c94f9a994f70a3ca", 42),
    "multi": ("546262a798da1d30d32312751fd6aa026f80e335a1e6b0fb56d33d9ef66f1834", 70),
}


def _binding_sequence(plane, loader):
    seq = []
    orig = plane.cluster._bind

    def record(pod, node):
        seq.append(f"{pod.namespace}/{pod.name}->{node.name}"
                   f"@{plane.sim.now():.4f}")
        orig(pod, node)

    plane.cluster._bind = record
    loader(plane)
    plane.run(horizon_s=500_000)
    return seq


def _paper_scenario():
    plane = ControlPlane("kubeadaptor", seed=7)
    wf = make_workflow("montage", get_workflow_spec("montage"))
    return _binding_sequence(
        plane, lambda p: p.gateway.load([wf.with_instance(i)
                                         for i in range(2)]))


def _multi_scenario():
    plane = ControlPlane("kubeadaptor", admission_policy="fair-share",
                         cluster_cfg=cal.PaperCluster(n_nodes=3), seed=11)
    mont = make_workflow("montage", get_workflow_spec("montage"))
    fan = make_workflow("fan", wide_fanout(width=12))

    def load(p):
        p.add_stream(mont, repeats=2, tenant="a", arrival="concurrent",
                     concurrency=2, weight=2.0)
        p.add_stream(fan, repeats=2, tenant="b", arrival="concurrent",
                     concurrency=2, weight=1.0)
    return _binding_sequence(plane, load)


@pytest.mark.parametrize("name,scenario",
                         [("paper", _paper_scenario),
                          ("multi", _multi_scenario)])
def test_binding_sequence_pinned(name, scenario):
    """Fixed seed => the exact pre-refactor pod->node binding order."""
    seq = scenario()
    digest = hashlib.sha256("\n".join(seq).encode()).hexdigest()
    want_digest, want_n = PINNED[name]
    assert len(seq) == want_n
    assert digest == want_digest, f"binding sequence moved for {name!r}"


def test_binding_sequence_deterministic():
    assert _paper_scenario() == _paper_scenario()


# ---------------------------------------------------------------------------
# shuffle replica
# ---------------------------------------------------------------------------
def _backends():
    out = [False]                      # pure python always
    if _load_native() is not None:
        out.append(True)
    return out


@pytest.mark.parametrize("native", _backends())
def test_exact_shuffler_matches_random_shuffle(native):
    for seed in (0, 7, 12345):
        ref, mine = random.Random(seed), random.Random(seed)
        sh = ExactShuffler(mine, native=native)
        for _ in range(120):           # enough to span buffer refills
            for ln in (2, 3, 6, 17, 56, 100, 101, 257):
                a, b = list(range(ln)), list(range(ln))
                ref.shuffle(a)
                sh.shuffle(b)
                assert a == b


@pytest.mark.parametrize("native", _backends())
def test_draw_apply_matches_shuffle_permutation(native):
    ref, mine = random.Random(3), random.Random(3)
    sh = ExactShuffler(mine, native=native)
    perm = sh.make_perm(64)
    for _ in range(200):
        a = list(range(64))
        ref.shuffle(a)
        sh.reset_perm(perm, 64)
        sh.draw_apply(perm, 64)
        assert list(perm) == a


# ---------------------------------------------------------------------------
# admission: specialized walks == generic re-sort loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["fifo", "priority", "fair-share"])
def test_fast_walks_match_generic_evaluate(policy):
    """Same scenario through the specialized walk and the generic loop
    must grant in the same order with the same deferral counts."""
    import repro.core.resources as rs

    def run(fast):
        grants = []
        orig_init = rs.AdmissionArbiter.__init__
        orig_ck = rs.AdmissionArbiter._create_bookkeep

        def pinit(self, *a, **k):
            orig_init(self, *a, **k)
            self._fast = fast

        def pck(self, req):
            grants.append((self.inf.pods.sim.now(), req.namespace,
                           req.task.id))
            return orig_ck(self, req)

        rs.AdmissionArbiter.__init__ = pinit
        rs.AdmissionArbiter._create_bookkeep = pck
        try:
            plane = ControlPlane("kubeadaptor", admission_policy=policy,
                                 cluster_cfg=cal.PaperCluster(n_nodes=2),
                                 seed=5)
            fan = make_workflow("fan", wide_fanout(width=16))
            mont = make_workflow("montage", get_workflow_spec("montage"))
            plane.add_stream(fan, repeats=2, tenant="heavy",
                             arrival="concurrent", concurrency=2,
                             priority=5, weight=3.0)
            plane.add_stream(mont, repeats=2, tenant="light",
                             arrival="poisson", rate=0.1, burst=2,
                             priority=0, weight=1.0)
            res = plane.run(horizon_s=500_000)
            return grants, res.arbiter.deferrals, res.arbiter.admitted
        finally:
            rs.AdmissionArbiter.__init__ = orig_init
            rs.AdmissionArbiter._create_bookkeep = orig_ck

    fast = run(True)
    generic = run(False)
    assert fast == generic


def test_informer_pod_aggregates_match_scan():
    plane = ControlPlane("kubeadaptor", seed=2)
    wf = make_workflow("cybershake", get_workflow_spec("cybershake"))
    arb = plane.arbiter
    checks = []
    orig = type(arb).evaluate

    def checked(self):
        checks.append(self.requested() == self._requested_scan())
        orig(self)

    plane.arbiter.evaluate = checked.__get__(plane.arbiter)
    plane.gateway.load([wf.with_instance(0)])
    plane.run(horizon_s=500_000)
    assert checks and all(checks)


# ---------------------------------------------------------------------------
# listers stay zero-cost; pvc informer; resync reconciliation
# ---------------------------------------------------------------------------
def test_api_calls_unchanged_by_lister_fast_path():
    sim = Sim()
    cluster = Cluster(sim)
    informers = InformerSet(sim, cluster)
    cluster.create_namespace("bench")
    sim.run()
    for i in range(20):
        cluster.create_pod(PodObj(name=f"p{i}", namespace="bench",
                                  task_id=f"p{i}", workflow="w",
                                  cpu_m=100, mem_mi=100, duration_s=1e9))
    sim.run(until=sim.now() + 5)
    before = cluster.api_calls
    for _ in range(500):
        informers.pods.lister()
        informers.pods.lister("bench")
        informers.nodes.lister()
        informers.pvcs.lister("bench")
    assert cluster.api_calls == before, "listers must not hit the apiserver"
    assert len(informers.pods.lister("bench")) == 20


def test_pvc_informer_cache_populated():
    """Satellite: the pvc informer's initial list / resync now see PVCs
    (Cluster.list_pvcs existed nowhere before)."""
    sim = Sim()
    cluster = Cluster(sim)
    cluster.create_namespace("ns1")
    sim.run()
    cluster.create_pvc("ns1", "vol1")
    sim.run(until=sim.now() + 5)
    fresh = Informer(sim, cluster, "pvc")       # initial list
    assert ("ns1", "vol1") in fresh.cache
    assert [p.name for p in fresh.lister("ns1")] == ["vol1"]
    assert cluster.list_pvcs("ns1")[0].bound


def test_resync_reconciles_missed_delete():
    """Satellite: a DELETED watch event that never arrives leaves a
    stale cache key; resync must drop it and fire on_delete (after the
    two-resync grace that protects in-flight events)."""
    sim = Sim()
    cluster = Cluster(sim)
    informer = Informer(sim, cluster, "pod")
    deleted = []
    informer.add_handlers(on_delete=deleted.append)
    cluster.create_namespace("ns1")
    sim.run()
    pod = PodObj(name="ghost", namespace="ns1", task_id="t", workflow="w",
                 cpu_m=100, mem_mi=100, duration_s=1e9)
    cluster.create_pod(pod)
    sim.run(until=sim.now() + 2)
    assert ("ns1", "ghost") in informer.cache
    # simulate a missed DELETED event: remove from the apiserver state
    # without notifying the watch stream
    del cluster.pods[("ns1", "ghost")]
    cluster._pending_pods.pop(("ns1", "ghost"), None)
    cluster._pods_by_ns["ns1"].pop(("ns1", "ghost"), None)
    p = cal.DEFAULT_PARAMS
    sim.after(2.5 * p.resync_interval, lambda: None)   # keep sim alive
    sim.run(until=sim.now() + 2.5 * p.resync_interval)
    assert ("ns1", "ghost") not in informer.cache
    assert [q.name for q in deleted] == ["ghost"]
    # aggregates reconciled too
    assert informer.nonterminal_cpu == 0


def test_resync_survives_normal_operation():
    """Reconciliation must not fire on_delete for objects that are
    still present (or only transiently in flight)."""
    res = None
    plane = ControlPlane("kubeadaptor", seed=4)
    wf = make_workflow("ligo", get_workflow_spec("ligo"))
    deleted = []
    plane.informers.namespaces.add_handlers(on_delete=deleted.append)
    plane.gateway.load([wf.with_instance(0)])
    res = plane.run(horizon_s=500_000)
    # exactly the workflow's own namespace deletion, no resync ghosts
    assert len(deleted) == 1
    assert res.metrics.wf_record(wf.with_instance(0)).ns_deleted > 0


# ---------------------------------------------------------------------------
# sim diagnostics + streaming metrics
# ---------------------------------------------------------------------------
def test_sim_runaway_error_names_pending_notes():
    sim = Sim()

    def loop():
        sim.after(0.1, loop, note="culprit-poller")

    loop()
    with pytest.raises(RuntimeError) as err:
        sim.run(max_events=50)
    assert "culprit-poller" in str(err.value)
    assert sim.events_processed == 50


def test_sim_counts_events():
    sim = Sim()
    for i in range(10):
        sim.after(i, lambda: None)
    sim.run()
    assert sim.events_processed == 10


def test_streaming_stat_matches_list_stats():
    rng = random.Random(9)
    xs = [rng.uniform(0, 100) for _ in range(5000)]
    st = StreamingStat(reservoir=256)
    for x in xs:
        st.add(x)
    assert st.count == len(xs)
    assert st.mean == pytest.approx(sum(xs) / len(xs))
    assert st.max == max(xs)
    assert st.min == min(xs)
    # reservoir percentile is approximate but must be in-range and sane
    p50 = st.percentile(50)
    assert min(xs) <= p50 <= max(xs)
    xs_sorted = sorted(xs)
    assert abs(p50 - xs_sorted[len(xs) // 2]) < 10.0


def test_streaming_sample_mode_keeps_memory_flat():
    plane = ControlPlane("kubeadaptor", seed=1, sample_mode="streaming",
                         retain_pod_log=False)
    wf = make_workflow("montage", get_workflow_spec("montage"))
    plane.gateway.load([wf.with_instance(i) for i in range(3)])
    res = plane.run(horizon_s=500_000)
    m = res.metrics
    assert m.samples == [] and m.tenant_samples == []
    assert m.cpu_stat.count > 0
    cpu_rate, mem_rate = m.overall_usage()
    assert 0 < cpu_rate <= 1 and 0 < mem_rate <= 1
    assert res.cluster.pod_log == []
    assert res.cluster.exec_stat.count > 0        # exec times still tracked
    assert res.cluster.max_pending_pods > 0
    assert res.arbiter.max_pending >= 0


def test_full_mode_unchanged_for_paper_runs():
    plane = ControlPlane("kubeadaptor", seed=1)
    wf = make_workflow("montage", get_workflow_spec("montage"))
    plane.gateway.load([wf.with_instance(0)])
    res = plane.run(horizon_s=500_000)
    assert len(res.metrics.samples) > 10
    assert len(res.cluster.pod_log) == len(wf.tasks)
