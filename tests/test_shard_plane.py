"""Sharded control plane (ISSUE 6): mergeable stats + shard equivalence.

Three layers of guarantees:

* property tests: merging per-chunk ``StreamingStat`` /
  ``StepAccumulator`` over ANY partition of a stream reproduces the
  whole-stream accumulation (counts/min/max/peak exact, means and
  variances to float tolerance, step residence times per level exact
  up to summation order) — driven by hypothesis when installed,
  otherwise by a seeded random-case generator exercising the same
  invariant (the property and checks are identical in both drivers);
* partition determinism: ``shard_of`` is a pinned stable hash,
  ``shard_seed`` spawns distinct wallclock-free seeds, node slices
  are disjoint and exhaustive;
* mode equivalence: ``processes=False`` (in-process, sequential) and
  ``processes=True`` (forked workers) produce identical per-tenant
  binding sequences, tenant summaries, and event counts — pinned by
  hash so a regression in either mode (or in the merge layer) fails
  loudly.
"""
import hashlib
import math
import random

from repro.configs.workflows import get_workflow_spec
from repro.core.dag import make_workflow
from repro.core.metrics import MetricsPartial, TenantAgg
from repro.core.shard import (ShardedControlPlane, partition_nodes, shard_of,
                              shard_seed)
from repro.core.stats import StepAccumulator, StreamingStat

try:                                     # property-based when available,
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # seeded sweep otherwise
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# property tests: merge of splits == whole, any partition
# --------------------------------------------------------------------------
def _check_stream_partition(xs, chunks):
    whole = StreamingStat()
    for x in xs:
        whole.add(x)
    parts = []
    for chunk in chunks:
        stat = StreamingStat()
        for x in chunk:
            stat.add(x)
        parts.append(stat)
    merged = parts[0]
    for stat in parts[1:]:
        merged.merge(stat)
    assert merged.count == whole.count == len(xs)
    assert merged.min == whole.min
    assert merged.max == whole.max
    assert math.isclose(merged.mean, whole.mean,
                        rel_tol=1e-9, abs_tol=1e-6)
    assert math.isclose(merged.variance, whole.variance,
                        rel_tol=1e-6, abs_tol=1e-3)
    # the merged reservoir stays a sample of the stream
    assert len(merged._reservoir) == min(len(xs), 512)
    assert set(merged._reservoir) <= set(xs)


def _check_step_split(dts, levels, cut):
    whole = StepAccumulator(t0=0.0)
    t = 0.0
    for dt, lv in zip(dts, levels):
        t += dt
        whole.set(t, lv)
    whole.close(t)

    a = StepAccumulator(t0=0.0)
    t = 0.0
    for dt, lv in zip(dts[:cut], levels[:cut]):
        t += dt
        a.set(t, lv)
    a.close(t)
    b = StepAccumulator(t0=t, level=a.level)
    for dt, lv in zip(dts[cut:], levels[cut:]):
        t += dt
        b.set(t, lv)
    b.close(t)

    a.merge(b)
    assert a.peak == whole.peak
    assert a.changes == whole.changes
    assert math.isclose(a.total_time, whole.total_time,
                        rel_tol=1e-9, abs_tol=1e-9)
    assert set(a.level_dur) == set(whole.level_dur)
    for lv, dur in whole.level_dur.items():
        assert math.isclose(a.level_dur[lv], dur,
                            rel_tol=1e-9, abs_tol=1e-9)
    assert math.isclose(a.mean(), whole.mean(),
                        rel_tol=1e-9, abs_tol=1e-9)
    if whole.total_time > 0:
        assert a.percentile(95) == whole.percentile(95)


def _random_stream_case(rng):
    xs = [rng.uniform(-1e9, 1e9) for _ in range(rng.randint(1, 120))]
    cuts = sorted(rng.randint(0, len(xs))
                  for _ in range(rng.randint(0, len(xs) - 1)))
    bounds = [0] + cuts + [len(xs)]
    return xs, [xs[a:b] for a, b in zip(bounds, bounds[1:])]


def _random_step_case(rng):
    n = rng.randint(1, 60)
    dts = [rng.uniform(0.0, 100.0) for _ in range(n)]
    levels = [rng.randint(0, 50) for _ in range(n)]
    return dts, levels, rng.randint(0, n)


if HAVE_HYPOTHESIS:
    finite_floats = st.floats(min_value=-1e9, max_value=1e9,
                              allow_nan=False, allow_infinity=False)

    @st.composite
    def partitioned_stream(draw):
        xs = draw(st.lists(finite_floats, min_size=1, max_size=120))
        n_chunks = draw(st.integers(min_value=1, max_value=len(xs)))
        cuts = sorted(draw(st.lists(
            st.integers(min_value=0, max_value=len(xs)),
            min_size=n_chunks - 1, max_size=n_chunks - 1)))
        bounds = [0] + cuts + [len(xs)]
        return xs, [xs[a:b] for a, b in zip(bounds, bounds[1:])]

    @st.composite
    def step_schedule(draw):
        dts = draw(st.lists(st.floats(min_value=0.0, max_value=100.0,
                                      allow_nan=False),
                            min_size=1, max_size=60))
        levels = draw(st.lists(st.integers(min_value=0, max_value=50),
                               min_size=len(dts), max_size=len(dts)))
        cut = draw(st.integers(min_value=0, max_value=len(dts)))
        return dts, levels, cut

    @given(partitioned_stream())
    @settings(max_examples=200, deadline=None)
    def test_streaming_stat_merge_any_partition(case):
        _check_stream_partition(*case)

    @given(step_schedule())
    @settings(max_examples=200, deadline=None)
    def test_step_accumulator_merge_split_equals_whole(case):
        _check_step_split(*case)
else:
    def test_streaming_stat_merge_any_partition():
        rng = random.Random(0xA11CE)
        for _ in range(300):
            _check_stream_partition(*_random_stream_case(rng))

    def test_step_accumulator_merge_split_equals_whole():
        rng = random.Random(0xB0B)
        for _ in range(300):
            _check_step_split(*_random_step_case(rng))


def test_tenant_agg_merge_matches_single_fold():
    # two halves of a record stream folded separately then merged
    # == one agg folding everything
    from repro.core.metrics import WorkflowRecord
    recs = []
    for i in range(10):
        r = WorkflowRecord("wf", i, tenant="t", submitted_at=float(i),
                           first_create=i + 1.0, ns_created=i + 0.5,
                           ns_deleted=i + 10.0)
        if i % 4 == 3:
            r.failed = True
        recs.append(r)
    whole, left, right = TenantAgg(), TenantAgg(), TenantAgg()
    for r in recs:
        whole.fold(r, deadline_s=12.0)
    for r in recs[:5]:
        left.fold(r, deadline_s=12.0)
    for r in recs[5:]:
        right.fold(r, deadline_s=12.0)
    left.merge(right)
    assert left == whole
    assert left.summary_row(deadline_s=12.0) == \
        whole.summary_row(deadline_s=12.0)


# --------------------------------------------------------------------------
# partition determinism
# --------------------------------------------------------------------------
def test_shard_of_is_pinned_stable_hash():
    # crc32-based: stable across processes and Python versions (NOT
    # Python's randomized hash). Pinned values document the contract.
    assert shard_of("montage-prod0", 8) == 2
    assert shard_of("montage-prod0", 1) == 0
    assert all(0 <= shard_of(f"tenant-{i}", 5) < 5 for i in range(100))
    # the bench naming scheme spreads each {topo}-{klass} family of W
    # tenants across all W shards exactly evenly (crc32 is affine)
    for topo in ("montage", "epigenomics", "cybershake", "ligo"):
        for klass in ("prod", "batch"):
            shards = {shard_of(f"{topo}-{klass}{j}", 8) for j in range(8)}
            assert shards == set(range(8))


def test_shard_seed_spawning():
    seeds = [shard_seed(42, i) for i in range(16)]
    assert len(set(seeds)) == 16           # decorrelated
    assert seeds == [shard_seed(42, i) for i in range(16)]  # reproducible
    assert shard_seed(43, 0) != shard_seed(42, 0)


def test_partition_nodes_disjoint_exhaustive():
    for n, w in ((8000, 8), (10, 3), (5, 5), (7, 2)):
        slices = partition_nodes(n, w)
        assert sum(slices) == n
        assert len(slices) == w
        assert max(slices) - min(slices) <= 1


# --------------------------------------------------------------------------
# in-process vs multi-process equivalence (pinned)
# --------------------------------------------------------------------------
def _mini_sharded(processes, workers=2):
    wf = make_workflow("montage", get_workflow_spec("montage"))
    ep = make_workflow("epigenomics", get_workflow_spec("epigenomics"))
    plane = ShardedControlPlane(
        workers, admission_policy="fair-share", seed=42,
        sample_mode="streaming", usage_mode="event", retain_pod_log=False,
        processes=processes, record_bindings=True)
    for j in range(workers):
        plane.add_stream(wf, repeats=6, tenant=f"montage-prod{j}",
                         arrival="concurrent", concurrency=2, priority=10,
                         weight=3.0, deadline_s=180.0)
        plane.add_stream(ep, repeats=6, tenant=f"epigenomics-batch{j}",
                         arrival="poisson", rate=0.5, burst=2,
                         deadline_s=3600.0)
    return plane


def _binding_digest(bindings):
    h = hashlib.sha256()
    for tenant in sorted(bindings):
        h.update(tenant.encode())
        for line in bindings[tenant]:
            h.update(line.encode())
    return h.hexdigest()


def test_inprocess_equals_multiprocess_pinned():
    r_in = _mini_sharded(processes=False).run()
    r_mp = _mini_sharded(processes=True).run()
    # identical per-tenant binding sequences, bit for bit
    assert r_in.bindings() == r_mp.bindings()
    assert r_in.events == r_mp.events
    assert [s["events"] for s in r_in.shards] == \
        [s["events"] for s in r_mp.shards]
    assert r_in.tenant_summary() == r_mp.tenant_summary()
    assert r_in.usage_summary() == r_mp.usage_summary()
    assert r_in.completed_workflows == r_mp.completed_workflows == 24
    # pinned digest: moving ANY binding in EITHER mode fails here
    digest = _binding_digest(r_in.bindings())
    assert _binding_digest(r_mp.bindings()) == digest
    assert digest == PINNED_SHARD_BINDINGS


PINNED_SHARD_BINDINGS = \
    "93f5b4f868f093d4b454f72593407b0859aa39f2a0e26c84ffdca98a9f60aa3f"


def test_tenant_partition_is_disjoint_and_merged_summary_is_union():
    plane = _mini_sharded(processes=False)
    res = plane.run()
    tenant_sets = [set(s["tenants"]) for s in res.shards]
    for i, a in enumerate(tenant_sets):
        for b in tenant_sets[i + 1:]:
            assert not (a & b)
    # merged summary == union of per-shard partial summaries (tenants
    # are disjoint, so this is exact — float-for-float)
    union = {}
    for s in res.shards:
        union.update(s["metrics_partial"].tenant_summary())
    assert res.tenant_summary() == union
    # every stream's workflows completed somewhere
    assert res.completed_workflows == 24
    assert res.failed_workflows == 0


def test_metrics_partial_merge_is_order_independent_on_counts():
    plane = _mini_sharded(processes=False)
    res = plane.run()
    parts = [s["metrics_partial"] for s in res.shards]
    ab = MetricsPartial()
    ab.merge(parts[0])
    ab.merge(parts[1])
    ba = MetricsPartial()
    ba.merge(parts[1])
    ba.merge(parts[0])
    assert ab.tenant_summary() == ba.tenant_summary()
    assert ab.completed == ba.completed == res.completed_workflows
