"""Heterogeneous node classes + scored placement + descheduler (ISSUE 8).

Pins for the utilization-aware placement plane:

* ``placement="first-fit"`` (the default) reproduces the PR-2 pinned
  binding-sequence hash bit-for-bit — the scored code path must be
  invisible unless opted into;
* the scored modes consume the IDENTICAL shuffle word stream as
  first-fit (only the pick among feasible nodes changes), and the
  native fused cycle matches the pure-Python semantic reference
  bit-for-bit across all six admission policies on mixed request
  sizes over a heterogeneous cluster;
* admission fast walks == generic re-sort loop under scored placement
  for every policy preset;
* ``kill_node``/``drain_node``/``restore_node`` write per-node
  capacities through the native free/ready mirrors on heterogeneous
  clusters (the uniform-capacity-assumption regression);
* the descheduler daemon rebalances hot nodes deterministically, the
  evicted pods requeue with no retry-budget charge, and the daemon
  never keeps a drained sim alive;
* scored-spread yields lower per-node time-averaged utilization
  variance than first-fit on the same heterogeneous scenario (the CI
  smoke gate's semantic pin).
"""
import hashlib

import pytest

from repro.configs.workflows import get_workflow_spec, wide_fanout
from repro.core import calibration as cal
from repro.core.chaos import ChaosSchedule
from repro.core.dag import make_workflow
from repro.core.descheduler import DeschedulePolicy, Descheduler
from repro.core.runner import ControlPlane

from tests.test_scale_core import PINNED, _binding_sequence

POLICIES = ("fifo", "priority", "fair-share", "drf", "quota", "preempt")

# mixed request sizes: cycle of (cpu_m, mem_mi) shapes covering
# cpu-heavy, mem-heavy, small and large pods (all fit the smallest
# node class of both presets)
SHAPES = ((400, 300), (1200, 1200), (2400, 800), (800, 2600),
          (3200, 3200), (600, 1800))


def _mixed_fanout(width=12):
    """wide_fanout with per-task heterogeneous resource requests."""
    spec = wide_fanout(width=width)
    for i in range(width):
        cpu, mem = SHAPES[i % len(SHAPES)]
        spec[f"w{i}"]["cpuNum"] = [str(cpu)]
        spec[f"w{i}"]["memNum"] = [str(mem)]
    return spec


def _force_python_backend():
    """Context values for the fallback-forcing idiom (see
    test_informer_views.py)."""
    import repro.core.shuffle as shuffle_mod
    saved = (shuffle_mod._native_lib, shuffle_mod._native_tried)
    shuffle_mod._native_lib, shuffle_mod._native_tried = None, True
    return shuffle_mod, saved


def _mixed_plane(policy, placement, mix="cpu-mem-skew", n_nodes=9, seed=23,
                 **plane_kw):
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=cal.hetero_cluster(n_nodes, mix),
                         seed=seed, usage_mode="event",
                         placement=placement, **plane_kw)
    fan = make_workflow("fan", _mixed_fanout(width=12))
    mont = make_workflow("montage", get_workflow_spec("montage"))

    def load(p):
        p.add_stream(fan, repeats=2, tenant="a", arrival="concurrent",
                     concurrency=2, priority=10, weight=3.0,
                     quota_cpu_m=20_000)
        p.add_stream(mont, repeats=2, tenant="b", arrival="concurrent",
                     concurrency=2, priority=0, weight=1.0,
                     quota_cpu_m=12_000)
    return plane, load


# ---------------------------------------------------------------------------
# heterogeneous cluster config
# ---------------------------------------------------------------------------
def test_hetero_cluster_nodes_and_averages():
    for mix, classes in cal.NODE_MIXES.items():
        cycle_len = sum(c.weight for c in classes)
        cfg = cal.hetero_cluster(2 * cycle_len, mix)
        nodes = cfg.nodes()
        assert len(nodes) == 2 * cycle_len
        # weighted per-node average equals the paper node, so hetero
        # tiers keep total allocatable comparable to the uniform tier
        assert sum(cpu for _, cpu, _ in nodes) == \
            2 * cycle_len * cal.PaperCluster.node_cpu_m
        assert sum(mem for _, _, mem in nodes) == \
            2 * cycle_len * cal.PaperCluster.node_mem_mi
        # every class fits the paper task
        assert all(cpu >= cal.TASK_CPU_M and mem >= cal.TASK_MEM_MI
                   for _, cpu, mem in nodes)


def test_hetero_cluster_unknown_mix_rejected():
    with pytest.raises(ValueError):
        cal.hetero_cluster(6, "no-such-mix")


def test_hetero_shard_slice_is_prefix():
    """``replace(cfg, n_nodes=k)`` (the shard node-slicing idiom) must
    see the same class assignment for its nodes as the full cluster —
    the weighted round-robin cycle makes any slice a prefix."""
    from dataclasses import replace
    cfg = cal.hetero_cluster(10, "big-small")
    full = cfg.nodes()
    for k in (1, 3, 7):
        assert replace(cfg, n_nodes=k).nodes() == full[:k]


# ---------------------------------------------------------------------------
# first-fit stays pinned; scored is opt-in and genuinely different
# ---------------------------------------------------------------------------
def test_unknown_placement_rejected():
    with pytest.raises(ValueError):
        ControlPlane("kubeadaptor", placement="best-fit")


def test_explicit_first_fit_matches_pinned_hash():
    """placement="first-fit" spelled out == the default == the PR-2
    pinned binding hash (the scored path is invisible un-opted-in)."""
    plane = ControlPlane("kubeadaptor", seed=7, placement="first-fit")
    wf = make_workflow("montage", get_workflow_spec("montage"))
    seq = _binding_sequence(
        plane, lambda p: p.gateway.load([wf.with_instance(i)
                                         for i in range(2)]))
    digest = hashlib.sha256("\n".join(seq).encode()).hexdigest()
    want_digest, want_n = PINNED["paper"]
    assert (len(seq), digest) == (want_n, want_digest)


def test_scored_differs_from_first_fit_on_hetero():
    seqs = {}
    for placement in ("first-fit", "scored-spread", "scored-pack"):
        plane, load = _mixed_plane("fifo", placement)
        seqs[placement] = _binding_sequence(plane, load)
    assert seqs["first-fit"] != seqs["scored-spread"]
    assert seqs["scored-spread"] != seqs["scored-pack"]
    # same pods scheduled either way, just onto different nodes
    assert len({len(s) for s in seqs.values()}) == 1


def test_scored_consumes_identical_word_stream():
    """Word-stream discipline: a scored run burns exactly the draws a
    first-fit run burns — the seeded RNG parks on the same state."""
    shuffle_mod, saved = _force_python_backend()
    try:
        states = {}
        for placement in ("first-fit", "scored-spread", "scored-pack"):
            plane, load = _mixed_plane("fifo", placement)
            _binding_sequence(plane, load)
            states[placement] = plane.cluster.rng.getstate()
        assert states["first-fit"] == states["scored-spread"]
        assert states["first-fit"] == states["scored-pack"]
    finally:
        shuffle_mod._native_lib, shuffle_mod._native_tried = saved


# ---------------------------------------------------------------------------
# native fused scored cycle == pure-Python reference, all six policies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_scored_native_matches_python(policy):
    import repro.core.shuffle as shuffle_mod
    if shuffle_mod._load_native() is None:
        pytest.skip("no native backend on this host")

    def run_once():
        plane, load = _mixed_plane(policy, "scored-spread")
        return _binding_sequence(plane, load)

    native_seq = run_once()
    shuffle_mod, saved = _force_python_backend()
    try:
        python_seq = run_once()
    finally:
        shuffle_mod._native_lib, shuffle_mod._native_tried = saved
    assert native_seq == python_seq
    assert native_seq           # the scenario actually bound pods


def test_scored_pack_native_matches_python():
    import repro.core.shuffle as shuffle_mod
    if shuffle_mod._load_native() is None:
        pytest.skip("no native backend on this host")

    def run_once():
        plane, load = _mixed_plane("fair-share", "scored-pack",
                                   mix="big-small")
        return _binding_sequence(plane, load)

    native_seq = run_once()
    shuffle_mod, saved = _force_python_backend()
    try:
        python_seq = run_once()
    finally:
        shuffle_mod._native_lib, shuffle_mod._native_tried = saved
    assert native_seq == python_seq


# ---------------------------------------------------------------------------
# admission fast walks == generic loop under scored placement
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_scored_fast_walks_match_generic(policy):
    import repro.core.resources as rs

    def run(fast):
        grants = []
        orig_init = rs.AdmissionArbiter.__init__
        orig_ck = rs.AdmissionArbiter._create_bookkeep

        def pinit(self, *a, **k):
            orig_init(self, *a, **k)
            self._fast = fast

        def pck(self, req):
            grants.append((self.inf.pods.sim.now(), req.namespace,
                           req.task.id))
            return orig_ck(self, req)

        rs.AdmissionArbiter.__init__ = pinit
        rs.AdmissionArbiter._create_bookkeep = pck
        try:
            plane, load = _mixed_plane(policy, "scored-spread")
            seq = _binding_sequence(plane, load)
            return (grants, seq, plane.arbiter.deferrals,
                    plane.arbiter.admitted)
        finally:
            rs.AdmissionArbiter.__init__ = orig_init
            rs.AdmissionArbiter._create_bookkeep = orig_ck

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# satellite: heterogeneous kill/drain/restore through the native mirrors
# ---------------------------------------------------------------------------
def _chaos_hetero_plane():
    # scripted chaos: kill a big node, drain a small one, restore both
    chaos = ChaosSchedule(seed=5, events=(
        (8.0, "kill", "node1"),       # big (16000m) under big-small
        (12.0, "drain", "node2"),     # small (4000m)
        (25.0, "restore", "node1"),
        (30.0, "restore", "node2"),
    ))
    plane = ControlPlane("kubeadaptor", admission_policy="preempt",
                         cluster_cfg=cal.hetero_cluster(6, "big-small"),
                         seed=13, usage_mode="event",
                         placement="scored-spread", chaos=chaos)
    fan = make_workflow("fan", _mixed_fanout(width=10))

    def load(p):
        p.add_stream(fan, repeats=4, tenant="a", arrival="concurrent",
                     concurrency=2)
    return plane, load


def test_hetero_kill_drain_restore_mirrors():
    """After killing/draining and restoring heterogeneous nodes, every
    native mirror slot must hold that node's OWN capacity — a uniform
    -capacity assumption anywhere in kill/drain/restore would corrupt
    the 16000m slot with an 8000m write."""
    plane, load = _chaos_hetero_plane()
    cluster = plane.cluster
    if cluster._c_free_cpu is None:
        pytest.skip("no native backend on this host")
    load(plane)
    plane.run(horizon_s=100_000)
    assert plane.chaos.node_kills == 1
    assert plane.chaos.node_drains == 1
    assert plane.chaos.node_restores == 2
    for i, node in enumerate(cluster._node_seq):
        # per-node allocs survived the round trip...
        assert cluster._c_alloc_cpu[i] == node.cpu_alloc
        assert cluster._c_alloc_mem[i] == node.mem_alloc
        # ...and the free mirrors re-anchored to each node's own state
        assert cluster._c_free_cpu[i] == node.cpu_alloc - node.cpu_used
        assert cluster._c_free_mem[i] == node.mem_alloc - node.mem_used
        assert cluster._c_ready[i] == node.ready
        assert node.ready           # both casualties were restored
    # the big and small nodes really have different capacities
    caps = {n.cpu_alloc for n in cluster._node_seq}
    assert caps == {16000, 4000}


def test_hetero_chaos_native_matches_python():
    import repro.core.shuffle as shuffle_mod
    if shuffle_mod._load_native() is None:
        pytest.skip("no native backend on this host")

    def run_once():
        plane, load = _chaos_hetero_plane()
        return _binding_sequence(plane, load)

    native_seq = run_once()
    shuffle_mod, saved = _force_python_backend()
    try:
        python_seq = run_once()
    finally:
        shuffle_mod._native_lib, shuffle_mod._native_tried = saved
    assert native_seq == python_seq


# ---------------------------------------------------------------------------
# descheduler
# ---------------------------------------------------------------------------
def _descheduler_run():
    plane = ControlPlane("kubeadaptor", admission_policy="fifo",
                         cluster_cfg=cal.hetero_cluster(8, "big-small"),
                         seed=5, usage_mode="event",
                         placement="first-fit",
                         deschedule=DeschedulePolicy(
                             interval_s=3.0, util_threshold=0.35,
                             max_evict_per_node=2))
    fan = make_workflow("fan", wide_fanout(width=6))
    plane.add_stream(fan, repeats=6, tenant="a", arrival="concurrent",
                     concurrency=3)
    return plane, plane.run(horizon_s=100_000)


def test_descheduler_rebalances_without_retry_charge():
    plane, res = _descheduler_run()
    m = res.metrics
    done = sum(1 for r in m.workflows.values()
               if r.ns_deleted > 0 and not r.failed)
    assert done == 6                       # rebalancing never loses work
    assert res.descheduler.evictions > 0   # the daemon genuinely fired
    assert res.cluster.rebalances == res.descheduler.evictions
    # no retry-budget charge: evictions ride the requeue machinery
    assert sum(r.retries for r in m.workflows.values()) == 0
    ts = m.tenant_summary()["a"]
    assert ts["rebalanced"] == res.cluster.rebalances
    rec = m.export_partial().recovery_summary()
    assert rec["rebalanced"] == res.cluster.rebalances
    # the daemon is pure observation+eviction: it must not keep the
    # drained sim alive (the run ended long before the horizon)
    assert res.sim.last_event_t < 100_000


def test_descheduler_deterministic_replay():
    def fingerprint():
        plane, res = _descheduler_run()
        return (res.descheduler.counters(), res.cluster.rebalances,
                res.sim.last_event_t, res.sim.events_processed)
    assert fingerprint() == fingerprint()


def test_descheduler_draws_nothing():
    """The daemon must not touch the scheduler RNG stream: same run
    with and without the descheduler parks the RNG identically."""
    shuffle_mod, saved = _force_python_backend()
    try:
        states = []
        for deschedule in (None, DeschedulePolicy(interval_s=3.0,
                                                  util_threshold=0.35)):
            plane = ControlPlane(
                "kubeadaptor", admission_policy="fifo",
                cluster_cfg=cal.hetero_cluster(6, "big-small"),
                seed=9, usage_mode="event", deschedule=deschedule)
            fan = make_workflow("fan", wide_fanout(width=6))
            plane.add_stream(fan, repeats=2, tenant="a",
                             arrival="concurrent", concurrency=2)
            plane.run(horizon_s=100_000)
            states.append(plane.cluster.rng.getstate())
        assert states[0] == states[1]
    finally:
        shuffle_mod._native_lib, shuffle_mod._native_tried = saved


def test_descheduler_validation():
    from repro.core.sim import Sim
    with pytest.raises(ValueError):
        Descheduler(Sim(), None, DeschedulePolicy(interval_s=0.0))
    with pytest.raises(ValueError):
        Descheduler(Sim(), None, DeschedulePolicy(util_threshold=0.0))


# ---------------------------------------------------------------------------
# hotspot spread: the CI gate's semantic pin
# ---------------------------------------------------------------------------
def _hotspot_variance(placement):
    plane, load = _mixed_plane("fifo", placement, mix="big-small",
                               n_nodes=12, seed=42)
    load(plane)
    plane.run(horizon_s=200_000)
    return plane.cluster.hotspot_summary()


def test_scored_spread_reduces_util_variance():
    ff = _hotspot_variance("first-fit")
    sp = _hotspot_variance("scored-spread")
    assert sp["util_variance"] <= ff["util_variance"]
    assert sp["nodes"] == ff["nodes"] == 12.0
    # averages are genuine time means, bounded like utilizations
    for h in (ff, sp):
        assert 0.0 <= h["min_mean_util"] <= h["mean_util"] \
            <= h["max_mean_util"] <= 1.0


def test_hotspot_summary_sharded_merge():
    """The pooled-population merge over disjoint shard node slices is
    exact: same identities as one flat population."""
    from repro.core.shard import ShardedControlPlane
    plane = ShardedControlPlane(
        2, admission_policy="fifo",
        cluster_cfg=cal.hetero_cluster(8, "big-small"), seed=31,
        usage_mode="event", processes=False, fold_completed=True,
        capture_trace=False, placement="scored-spread")
    fan = make_workflow("fan", wide_fanout(width=8))
    for t in ("a", "b", "c", "d"):
        plane.add_stream(fan, repeats=2, tenant=t, arrival="concurrent",
                         concurrency=2)
    res = plane.run(horizon_s=200_000)
    merged = res.hotspot_summary()
    assert merged["nodes"] == 8
    # recompute from the raw shard rows: pooled mean must equal the
    # weighted mean and the variance identity must hold exactly
    per = [s["node_hotspot"] for s in res.shards]
    want_mean = sum(h["nodes"] * h["mean_util"] for h in per) / 8
    assert merged["mean_util"] == pytest.approx(want_mean, rel=1e-12)
    assert merged["max_mean_util"] == max(h["max_mean_util"] for h in per)
    assert merged["util_variance"] >= 0.0
    assert res.completed_workflows == 8
