"""Hypothesis property tests on the system's invariants."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.core.dag import Task, Workflow, add_virtual_entry_exit
from repro.core.runner import run_experiment


# ---------------------------------------------------------------------------
# random-DAG strategy: layered DAGs (guaranteed acyclic, arbitrary width)
# ---------------------------------------------------------------------------
@st.composite
def layered_dag(draw, max_layers=5, max_width=4):
    n_layers = draw(st.integers(2, max_layers))
    layers = []
    tid = 0
    for _ in range(n_layers):
        width = draw(st.integers(1, max_width))
        layers.append([f"t{tid + i}" for i in range(width)])
        tid += width
    tasks = {}
    for li, layer in enumerate(layers):
        for name in layer:
            inputs = []
            if li > 0:
                prev = layers[li - 1]
                # every task gets >= 1 parent from the previous layer
                n_par = draw(st.integers(1, len(prev)))
                inputs = sorted(draw(st.permutations(prev))[:n_par])
            tasks[name] = Task(id=name, inputs=inputs, duration_s=2.0)
    for t in tasks.values():
        for dep in t.inputs:
            tasks[dep].outputs.append(t.id)
    return Workflow("prop", add_virtual_entry_exit(tasks))


@given(layered_dag())
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_topo_order_is_valid_linearization(wf):
    order = wf.topo_order()
    pos = {t: i for i, t in enumerate(order)}
    assert len(order) == len(wf.tasks)
    for t in wf.tasks.values():
        for dep in t.inputs:
            assert pos[dep] < pos[t.id]


@given(layered_dag())
@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_levels_partition_and_respect_deps(wf):
    levels = wf.levels()
    seen = set()
    flat = [t for lv in levels for t in lv]
    assert sorted(flat) == sorted(wf.tasks)
    for lv in levels:
        for t in lv:
            assert all(d in seen for d in wf.tasks[t].inputs)
        seen.update(lv)


@given(layered_dag(), st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_engine_order_consistent_on_random_dags(wf, seed):
    """THE paper property: for any DAG and any scheduler disorder seed,
    KubeAdaptor's execution is a dependency-consistent linearization."""
    res = run_experiment("kubeadaptor", wf, repeats=1, seed=seed,
                         sample_resources=False)
    assert res.metrics.order_consistent(wf.with_instance(0))
    rec = res.metrics.wf_record(wf.with_instance(0))
    assert rec.ns_deleted > rec.ns_created > 0


@given(layered_dag(), st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_resource_accounting_invariants(wf, seed):
    """Node usage never negative, never above allocatable, and returns
    to zero after all workflows finish (conservation)."""
    res = run_experiment("kubeadaptor", wf, repeats=1, seed=seed)
    for node in res.cluster.nodes.values():
        assert node.cpu_used == 0 and node.mem_used == 0     # all released
    cpu_a, mem_a = res.cluster.allocatable()
    for _, cpu, mem in res.metrics.samples:
        assert 0 <= cpu <= cpu_a and 0 <= mem <= mem_a


@given(layered_dag())
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_informer_cache_coherent_after_run(wf):
    """After the sim drains, the informer cache mirrors the cluster."""
    res = run_experiment("kubeadaptor", wf, repeats=1, seed=0,
                         sample_resources=False)
    inf = res.engine.inf
    assert set(inf.pods.cache.keys()) == set(res.cluster.pods.keys())
    assert set(inf.namespaces.cache.keys()) == set(res.cluster.namespaces.keys())
    assert len(res.cluster.pods) == 0          # everything cleaned up


@given(layered_dag(), st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_volume_carries_all_data_dependencies(wf, seed):
    """Every task's payload must see its dependencies' outputs in the
    shared volume (PV/NFS analogue) — checked via stress_payload wiring."""
    from repro.core.payloads import stress_payload
    import dataclasses
    tasks = {tid: dataclasses.replace(t, payload=stress_payload)
             for tid, t in wf.tasks.items()}
    wf2 = Workflow("prop", tasks)
    res = run_experiment("kubeadaptor", wf2, repeats=1, seed=seed,
                         sample_resources=False)
    assert res.metrics.order_consistent(wf2.with_instance(0))
