"""Serving correctness: incremental decode must match full-sequence
forward (the strongest cache-correctness property), per family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import RunConfig, build

# one representative per family
FAMILY_REPS = ["qwen2-0.5b", "qwen2-moe-a2.7b", "mamba2-2.7b",
               "zamba2-1.2b", "musicgen-medium", "llama-3.2-vision-11b"]


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_incremental_decode_matches_forward(arch):
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # capacity drops are a train-time batching artifact; the
        # decode-equivalence property needs drop-free routing
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    rc = RunConfig(param_dtype="float32", compute_dtype="float32")
    model = build(cfg, rc)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    key = jax.random.PRNGKey(1)
    if cfg.frontend == "audio":
        embeds = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        full, _, _ = model.apply(params, {"embeds": embeds})
        cache = model.init_cache(B, S)
        outs = []
        for t in range(S):
            logits, cache = model.decode(params, cache,
                                         {"embeds": embeds[:, t:t + 1]})
            outs.append(logits)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens}
        if cfg.frontend == "vision":
            img = jax.random.normal(key, (B, cfg.n_img_tokens, cfg.d_model),
                                    jnp.float32)
            batch["img_embeds"] = img
            # vision decode needs the cross-KV cache -> prefill first then
            # compare the decode continuation against forward on S+1
            logits_full, cache = model.prefill(params, batch)
            nxt = jnp.ones((B, 1), jnp.int32)
            tokens2 = jnp.concatenate([tokens, nxt], axis=1)
            full2, _, _ = model.apply(params, {"tokens": tokens2,
                                               "img_embeds": img})
            pad = ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))
            cache["k"] = jnp.pad(cache["k"], pad)   # room for the new token
            cache["v"] = jnp.pad(cache["v"], pad)
            dec, cache = model.decode(params, cache, {"tokens": nxt})
            err = jnp.abs(dec[:, 0] - full2[:, -1]).max()
            assert float(err) < 2e-3, float(err)
            return
        full, _, _ = model.apply(params, batch)
        cache = model.init_cache(B, S)
        outs = []
        for t in range(S):
            logits, cache = model.decode(params, cache,
                                         {"tokens": tokens[:, t:t + 1]})
            outs.append(logits)
    inc = jnp.concatenate(outs, axis=1)
    err = jnp.abs(inc - full).max()
    assert float(err) < 2e-3, float(err)
    assert int(cache["pos"]) == S


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b", "zamba2-1.2b"])
def test_prefill_then_decode_continuation(arch):
    """prefill(tokens[:k]) + decode(tokens[k:]) == forward(tokens)."""
    cfg = get_config(arch).reduced()
    rc = RunConfig(param_dtype="float32", compute_dtype="float32")
    model = build(cfg, rc)
    params = model.init(jax.random.PRNGKey(0))
    B, S, k = 2, 16, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    full, _, _ = model.apply(params, {"tokens": tokens})
    _, cache = model.prefill(params, {"tokens": tokens[:, :k]})
    if "k" in cache:   # grow KV cache to S
        pad = S - k
        cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    outs = []
    for t in range(k, S):
        logits, cache = model.decode(params, cache, {"tokens": tokens[:, t:t + 1]})
        outs.append(logits)
    inc = jnp.concatenate(outs, axis=1)
    err = jnp.abs(inc - full[:, k:]).max()
    assert float(err) < 2e-3, float(err)


def test_chunked_attention_matches_dense():
    from repro.models.attention import chunked_attention, full_attention
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 128, 4, 32
    q, k, v = (jax.random.normal(kk, (B, S, H, hd), jnp.float32)
               for kk in jax.random.split(key, 3))
    dense = full_attention(q, k, v, causal=True)
    for chunk in (16, 32, 64, 128):
        chunked = chunked_attention(q, k, v, chunk=chunk, causal=True)
        err = jnp.abs(dense - chunked).max()
        assert float(err) < 1e-4, (chunk, float(err))
