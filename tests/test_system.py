"""End-to-end behaviour of the KubeAdaptor system vs the paper's claims."""
import pytest

from repro.configs.workflows import WORKFLOWS, get_workflow_spec
from repro.core.dag import make_workflow
from repro.core.runner import run_experiment

ALL_WF = sorted(WORKFLOWS)


def _wf(name):
    return make_workflow(name, get_workflow_spec(name))


def _stack(seed=10):
    """Fresh full KubeAdaptor stack for fine-grained tests."""
    from repro.core.cluster import Cluster
    from repro.core.engine import KubeAdaptorEngine
    from repro.core.events import EventRegistry
    from repro.core.informer import InformerSet
    from repro.core.injector import WorkflowInjector
    from repro.core.metrics import MetricsCollector
    from repro.core.sim import Sim
    from repro.core.volumes import VolumeManager

    sim = Sim()
    cluster = Cluster(sim, seed=seed)
    informers = InformerSet(sim, cluster)
    events = EventRegistry(sim)
    volumes = VolumeManager(sim, cluster)
    metrics = MetricsCollector(sim, cluster)
    engine = KubeAdaptorEngine(sim, cluster, informers, events, volumes, metrics)
    return sim, cluster, engine, metrics, WorkflowInjector


# --------------------------------------------------------------------------
# Scheduling-order consistency (paper Fig 6 + the motivation Fig 1)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_WF)
def test_kubeadaptor_order_consistency(name):
    wf = _wf(name)
    res = run_experiment("kubeadaptor", wf, repeats=2, seed=7)
    for i in range(2):
        assert res.metrics.order_consistent(wf.with_instance(i))


def test_direct_submission_violates_dependencies():
    """Fig 1: throwing all pods at the K8s scheduler breaks the DAG order
    (tasks start before their dependencies finished)."""
    wf = _wf("epigenomics")       # deep pipelines -> violations guaranteed
    res = run_experiment("direct", wf, repeats=1, seed=3)
    assert not res.metrics.order_consistent(wf.with_instance(0))


@pytest.mark.parametrize("engine", ["batchjob", "argo"])
def test_baselines_respect_dependencies(engine):
    # level-sync and reconcile approaches are slow but still dependency-safe
    wf = _wf("montage")
    res = run_experiment(engine, wf, repeats=1, seed=5)
    assert res.metrics.order_consistent(wf.with_instance(0))


# --------------------------------------------------------------------------
# Lifecycle / exec-time reproduction (Figs 7, 8)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_WF)
def test_lifecycle_reproduces_paper(name, paper_numbers):
    wf = _wf(name)
    for engine, target in paper_numbers["lifecycle"][name].items():
        res = run_experiment(engine, wf, repeats=2, seed=1)
        got = res.metrics.avg_lifecycle(name)
        assert got == pytest.approx(target, rel=0.12), (engine, got, target)


@pytest.mark.parametrize("name", ALL_WF)
def test_task_exec_time_reproduces_paper(name, paper_numbers):
    wf = _wf(name)
    res = run_experiment("kubeadaptor", wf, repeats=2, seed=1)
    got = res.metrics.avg_pod_exec_time(name)
    assert got == pytest.approx(paper_numbers["exec"][name], rel=0.05)


@pytest.mark.parametrize("name", ALL_WF)
def test_kubeadaptor_beats_baselines(name):
    wf = _wf(name)
    life, ex = {}, {}
    for engine in ("kubeadaptor", "batchjob", "argo"):
        res = run_experiment(engine, wf, repeats=2, seed=2)
        life[engine] = res.metrics.avg_lifecycle(name)
        ex[engine] = res.metrics.avg_pod_exec_time(name)
    assert life["kubeadaptor"] < life["batchjob"] < life["argo"]
    assert ex["kubeadaptor"] < ex["batchjob"]
    assert ex["kubeadaptor"] < ex["argo"]
    red = 1 - life["kubeadaptor"] / life["argo"]
    assert red > 0.35, red       # headline: ~43-49% lifecycle reduction


def test_apiserver_pressure_reduced_by_informer():
    wf = _wf("montage")
    kube = run_experiment("kubeadaptor", wf, repeats=2, seed=4).api_calls
    batch = run_experiment("batchjob", wf, repeats=2, seed=4).api_calls
    argo = run_experiment("argo", wf, repeats=2, seed=4).api_calls
    assert kube < batch and kube < argo


# --------------------------------------------------------------------------
# Resource usage (Figs 9-14)
# --------------------------------------------------------------------------
def test_resource_usage_rate_ordering():
    wf = _wf("cybershake")
    rates = {}
    for engine in ("kubeadaptor", "batchjob", "argo"):
        res = run_experiment(engine, wf, repeats=1, seed=6)
        rates[engine] = res.metrics.first_lifecycle_usage("cybershake")
    assert rates["kubeadaptor"][0] > rates["batchjob"][0] > rates["argo"][0]
    assert rates["kubeadaptor"][1] > rates["argo"][1]


def test_resource_usage_never_exceeds_allocatable():
    wf = _wf("cybershake")
    res = run_experiment("kubeadaptor", wf, repeats=2, seed=8)
    cpu_a, mem_a = res.cluster.allocatable()
    for _, cpu, mem in res.metrics.samples:
        assert 0 <= cpu <= cpu_a
        assert 0 <= mem <= mem_a


# --------------------------------------------------------------------------
# Fault tolerance (§4.5) + straggler mitigation
# --------------------------------------------------------------------------
def test_pod_failure_recovery():
    from repro.core.cluster import RUNNING
    sim, cluster, engine, metrics, Injector = _stack(11)
    wf = _wf("ligo")
    injector = Injector(sim, engine.submit)
    engine.on_workflow_done = injector.request_next
    injector.load([wf.with_instance(0)])
    injector.start()
    sim.after(20.0, lambda: next(
        (cluster.fail_pod(p.namespace, p.name)
         for p in cluster.list_pods() if p.phase == RUNNING), None))
    sim.run(until=100000)
    rec = metrics.wf_record(wf.with_instance(0))
    assert rec.ns_deleted > 0, "workflow did not complete after failure"
    assert rec.retries >= 1
    assert metrics.order_consistent(wf.with_instance(0))


def test_node_failure_recovery():
    sim, cluster, engine, metrics, Injector = _stack(12)
    wf = _wf("cybershake")
    injector = Injector(sim, engine.submit)
    engine.on_workflow_done = injector.request_next
    injector.load([wf.with_instance(0)])
    injector.start()
    sim.after(25.0, lambda: cluster.fail_node("node3"))
    sim.run(until=100000)
    rec = metrics.wf_record(wf.with_instance(0))
    assert rec.ns_deleted > 0, "workflow did not survive node failure"


def test_straggler_speculative_execution():
    sim, cluster, engine, metrics, Injector = _stack(13)
    engine.speculative = True
    cluster.nodes["node1"].slow_factor = 30.0      # a straggling node
    wf = _wf("epigenomics")
    injector = Injector(sim, engine.submit)
    engine.on_workflow_done = injector.request_next
    injector.load([wf.with_instance(0)])
    injector.start()
    sim.run(until=100000)
    rec = metrics.wf_record(wf.with_instance(0))
    assert rec.ns_deleted > 0
    # a straggling pod (300 s) would push the lifecycle past 400 s
    assert rec.lifecycle < 400, rec.lifecycle


# --------------------------------------------------------------------------
# 100-run totals (paper §5.3) — scaled to 10 runs for CI, same ordering
# --------------------------------------------------------------------------
def test_total_time_over_repeated_runs():
    wf = _wf("montage")
    totals = {}
    for engine in ("kubeadaptor", "batchjob", "argo"):
        res = run_experiment(engine, wf, repeats=10, seed=9)
        totals[engine] = res.metrics.total_time("montage")
    assert totals["kubeadaptor"] < totals["batchjob"] < totals["argo"]


def test_level1_scheduler_is_pluggable():
    from repro.core.schedulers import LongestPathScheduler
    sim, cluster, engine, metrics, Injector = _stack(14)
    engine.scheduler_cls = LongestPathScheduler
    wf = _wf("montage")
    injector = Injector(sim, engine.submit)
    engine.on_workflow_done = injector.request_next
    injector.load([wf.with_instance(0)])
    injector.start()
    sim.run(until=100000)
    assert metrics.wf_record(wf.with_instance(0)).ns_deleted > 0
    assert metrics.order_consistent(wf.with_instance(0))
