"""Pluggable admission pipeline (ISSUE 4): exactness pins + new-stage
invariants.

The monolithic arbiter was split into staged plugins
(repro/core/policy/); these tests pin:

* fifo/priority/fair-share binding-sequence hashes through the
  pipeline — recorded on the pre-pipeline monolith (commit 8ad51d8)
  under a contended 3-tenant scenario where the three policies
  genuinely diverge (the PR-2/PR-3 pins in test_scale_core cover the
  paper + fair-share scenarios);
* drf's specialized walk vs the generic re-sort loop;
* hard quota caps are never exceeded at any instant (exact
  StepAccumulator peaks, per-grant usage assertions, and a hypothesis
  sweep over widths/caps/seeds), compose with any ordering, and a
  capped tenant never bars other tenants;
* preemption fires ONLY under the starvation condition (deferred
  beneficiary, headroom deficit, strictly-lower-priority victims) and
  preempted pods eventually complete with no retry-budget charge;
* trace capture round-trips exactly through ``--trace``-style replay;
* per-stream SLO (deadline hit-rate) accounting.
"""
import hashlib
import json

import pytest

from repro.configs.workflows import get_workflow_spec, wide_fanout
from repro.core import calibration as cal
from repro.core.cluster import FAILED, RUNNING, Cluster, PodObj
from repro.core.dag import make_workflow
from repro.core.injector import StreamSpec
from repro.core.policy import (POLICY_PRESETS, QUEUE_ORDERS, PipelineSpec,
                               QueueOrder)
from repro.core.resources import (ADMISSION_POLICIES, AdmissionArbiter,
                                  FairSharePolicy, FifoPolicy, PriorityPolicy)
from repro.core.runner import ControlPlane
from repro.core.sim import Sim

# sha256 over the binding sequence "ns/pod->node@t" under the contended
# scenario below, recorded on the PRE-PIPELINE monolith (commit 8ad51d8)
# — the staged pipeline must not move a single binding
PINNED_MONOLITH = {
    "fifo": ("cc5570c122ba24a1c4662c055eb6a0f310a8231a6aae1e315fd2398fa8657dfc", 118),
    "priority": ("476cbacf62c6802dfb4d461d20e8cf87778fcfa754002946e5c68cc321880970", 118),
    "fair-share": ("16d8e3450fb7f977c234cfb4e51a00573e528cc48b3f22a1e10aa4fb338c874e", 118),
}


def _contended_plane(policy, **plane_kw):
    """3 tenants x 3 arrival modes on a 2-node cluster: enough backlog
    that the three legacy policies produce distinct binding orders."""
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=cal.PaperCluster(n_nodes=2), seed=13,
                         **plane_kw)
    fan = make_workflow("fan", wide_fanout(width=14))
    mont = make_workflow("montage", get_workflow_spec("montage"))
    cyber = make_workflow("cybershake", get_workflow_spec("cybershake"))
    plane.add_stream(fan, repeats=2, tenant="a", arrival="concurrent",
                     concurrency=2, priority=5, weight=3.0)
    plane.add_stream(mont, repeats=2, tenant="b", arrival="concurrent",
                     concurrency=2, priority=0, weight=1.0)
    plane.add_stream(cyber, repeats=2, tenant="c", arrival="poisson",
                     rate=0.5, burst=2, priority=2, weight=2.0)
    return plane


def _run_bindings(plane):
    seq = []
    orig = plane.cluster._bind

    def record(pod, node):
        seq.append(f"{pod.namespace}/{pod.name}->{node.name}"
                   f"@{plane.sim.now():.4f}")
        orig(pod, node)

    plane.cluster._bind = record
    res = plane.run(horizon_s=500_000)
    return seq, res


@pytest.mark.parametrize("policy", ["fifo", "priority", "fair-share"])
def test_legacy_policies_bit_identical_through_pipeline(policy):
    seq, _res = _run_bindings(_contended_plane(policy))
    digest = hashlib.sha256("\n".join(seq).encode()).hexdigest()
    want_digest, want_n = PINNED_MONOLITH[policy]
    assert len(seq) == want_n
    assert digest == want_digest, \
        f"pipeline moved the {policy!r} binding sequence vs the monolith"


def test_registries_and_presets():
    # legacy registry keeps exactly the monolith's three names
    assert set(ADMISSION_POLICIES) == {"fifo", "priority", "fair-share"}
    assert set(QUEUE_ORDERS) == {"fifo", "fifo-merge", "priority",
                                 "fair-share", "drf"}
    assert set(POLICY_PRESETS) == {"fifo", "priority", "fair-share", "drf",
                                   "quota", "preempt"}
    assert POLICY_PRESETS["preempt"].preempt
    assert POLICY_PRESETS["quota"].order == "fifo-merge"
    # the monolith's class names remain importable and ARE the plugins
    assert ADMISSION_POLICIES["fifo"] is FifoPolicy
    assert issubclass(FairSharePolicy, QueueOrder)
    assert issubclass(PriorityPolicy, QueueOrder)
    with pytest.raises(ValueError):
        ControlPlane("kubeadaptor", admission_policy="lottery")
    with pytest.raises(ValueError):
        StreamSpec(workflow=make_workflow("w", wide_fanout(width=2)),
                   quota_cpu_m=-1)


def test_drf_fast_walk_matches_generic_evaluate():
    """drf's lazy-merge walk must grant in exactly the generic
    dynamic-order loop's sequence (the same equivalence the legacy
    walks are pinned to in test_scale_core)."""
    import repro.core.resources as rs

    def memhog(name):
        return make_workflow(name, {
            str(i): {"input": [], "output": [], "cpuNum": ["200"],
                     "memNum": ["4000"], "args": ["-c", "1", "-m", "100",
                                                  "-t", "5"]}
            for i in range(8)})

    def run(fast):
        grants = []
        orig_init = rs.AdmissionArbiter.__init__
        orig_ck = rs.AdmissionArbiter._create_bookkeep

        def pinit(self, *a, **k):
            orig_init(self, *a, **k)
            self._fast = fast

        def pck(self, req):
            grants.append((self.inf.pods.sim.now(), req.namespace,
                           req.task.id))
            return orig_ck(self, req)

        rs.AdmissionArbiter.__init__ = pinit
        rs.AdmissionArbiter._create_bookkeep = pck
        try:
            plane = ControlPlane("kubeadaptor", admission_policy="drf",
                                 cluster_cfg=cal.PaperCluster(n_nodes=2),
                                 seed=5)
            fan = make_workflow("fan", wide_fanout(width=16))
            plane.add_stream(fan, repeats=2, tenant="cpu",
                             arrival="concurrent", concurrency=2, weight=2.0)
            plane.add_stream(memhog("hog"), repeats=2, tenant="mem",
                             arrival="concurrent", concurrency=2, weight=1.0)
            res = plane.run(horizon_s=500_000)
            return grants, res.arbiter.deferrals, res.arbiter.admitted
        finally:
            rs.AdmissionArbiter.__init__ = orig_init
            rs.AdmissionArbiter._create_bookkeep = orig_ck

    assert run(True) == run(False)


def test_drf_ranks_by_dominant_resource():
    """The ROADMAP gap: cpu-only fair-share lets a memory-hog tenant
    look underserved forever. Under drf its dominant (memory) share
    ranks it, so it can no longer crowd the memory axis."""
    def run(policy):
        plane = ControlPlane("kubeadaptor", admission_policy=policy,
                             cluster_cfg=cal.PaperCluster(n_nodes=2), seed=3,
                             usage_mode="event")
        memhog = make_workflow("memhog", {
            str(i): {"input": [], "output": [], "cpuNum": ["200"],
                     "memNum": ["4000"],
                     "args": ["-c", "1", "-m", "100", "-t", "5"]}
            for i in range(10)})
        cpuhog = make_workflow("cpuhog", {
            str(i): {"input": [], "output": [], "cpuNum": ["1500"],
                     "memNum": ["300"],
                     "args": ["-c", "1", "-m", "100", "-t", "5"]}
            for i in range(10)})
        plane.add_stream(memhog, repeats=3, tenant="mem",
                         arrival="concurrent", concurrency=2)
        plane.add_stream(cpuhog, repeats=3, tenant="cpu",
                         arrival="concurrent", concurrency=2)
        return plane.run(horizon_s=500_000)

    fs = run("fair-share")
    drf = run("drf")
    # equal weights: drf throttles the memory-dominant tenant's mean
    # memory holding vs cpu-only ranking, which over-served it
    assert drf.metrics.tenant_mean_mem("mem") < \
        fs.metrics.tenant_mean_mem("mem")
    # everything still completes under both
    for res in (fs, drf):
        assert all(r.ns_deleted > 0 for r in res.metrics.workflows.values())


# ---------------------------------------------------------------------------
# quota caps
# ---------------------------------------------------------------------------
QUOTA_CPU = 4000
QUOTA_MEM = 6000


def _quota_plane(policy="quota", seed=5, width=12, quota_cpu=QUOTA_CPU,
                 quota_mem=0):
    plane = ControlPlane("kubeadaptor", admission_policy=policy,
                         cluster_cfg=cal.PaperCluster(n_nodes=2), seed=seed,
                         usage_mode="event")
    capped = make_workflow("capped-fan", wide_fanout(width=width))
    free = make_workflow("free-fan", wide_fanout(width=width))
    plane.add_stream(capped, repeats=3, tenant="capped",
                     arrival="concurrent", concurrency=2,
                     quota_cpu_m=quota_cpu, quota_mem_mi=quota_mem)
    plane.add_stream(free, repeats=3, tenant="free",
                     arrival="concurrent", concurrency=2)
    return plane


def _assert_quota_held(res, quota_cpu=QUOTA_CPU, quota_mem=0):
    m = res.metrics
    if quota_cpu:
        # exact step function over bound pods: never above the cap at
        # ANY instant (bound usage <= admitted usage <= cap)
        assert m.tenant_cpu_accs["capped"].peak <= quota_cpu
    if quota_mem:
        assert m.tenant_mem_accs["capped"].peak <= quota_mem
    assert res.arbiter.quota_rejects > 0          # the cap actually bound
    s = m.tenant_summary()
    assert s["capped"]["quota_rejects"] == res.arbiter.quota_rejects
    assert s["free"]["quota_rejects"] == 0
    for agg in s.values():
        assert agg["completed"] == agg["workflows"]   # caps never deadlock


def test_quota_cap_never_exceeded():
    res = _quota_plane().run(horizon_s=500_000)
    _assert_quota_held(res)
    assert res.arbiter.tenants["capped"].quota_rejects > 0


def test_quota_cap_on_memory_axis():
    res = _quota_plane(quota_cpu=0, quota_mem=QUOTA_MEM).run(horizon_s=500_000)
    assert res.metrics.tenant_mem_accs["capped"].peak <= QUOTA_MEM
    assert res.arbiter.quota_rejects > 0


def test_quota_composes_with_any_ordering():
    for policy in ("fair-share", "priority", "drf"):
        res = _quota_plane(policy=policy).run(horizon_s=500_000)
        _assert_quota_held(res)


@pytest.mark.parametrize("policy", ["quota", "fair-share", "drf"])
def test_quota_merge_walks_match_generic(policy):
    """With caps active, every tenant-merge walk (fifo-merge and the
    dynamic orders) must grant exactly like its generic-loop reference
    — including the head-of-line truncation behind a capped head.
    Mixed request sizes make any intra-tenant rescan divergence
    visible (a small request behind a capped big head)."""
    import repro.core.resources as rs

    def mixed(name):
        # alternating 1200m and 400m tasks: a capped 1200m head could
        # otherwise be back-filled past by its own 400m successors
        return make_workflow(name, {
            str(i): {"input": [], "output": [],
                     "cpuNum": ["1200" if i % 2 == 0 else "400"],
                     "memNum": ["600"],
                     "args": ["-c", "1", "-m", "100", "-t", "5"]}
            for i in range(10)})

    def run(fast):
        grants = []
        orig_init = rs.AdmissionArbiter.__init__
        orig_ck = rs.AdmissionArbiter._create_bookkeep

        def pinit(self, *a, **k):
            orig_init(self, *a, **k)
            self._fast = fast

        def pck(self, req):
            grants.append((self.inf.pods.sim.now(), req.namespace,
                           req.task.id))
            return orig_ck(self, req)

        rs.AdmissionArbiter.__init__ = pinit
        rs.AdmissionArbiter._create_bookkeep = pck
        try:
            plane = ControlPlane("kubeadaptor", admission_policy=policy,
                                 cluster_cfg=cal.PaperCluster(n_nodes=2),
                                 seed=9, usage_mode="event")
            plane.add_stream(mixed("capped-mix"), repeats=3, tenant="capped",
                             arrival="concurrent", concurrency=2,
                             quota_cpu_m=3600, weight=1.0)
            plane.add_stream(mixed("free-mix"), repeats=3, tenant="free",
                             arrival="concurrent", concurrency=2, weight=2.0)
            res = plane.run(horizon_s=500_000)
            return (grants, res.arbiter.deferrals, res.arbiter.admitted,
                    res.arbiter.quota_rejects)
        finally:
            rs.AdmissionArbiter.__init__ = orig_init
            rs.AdmissionArbiter._create_bookkeep = orig_ck

    fast = run(True)
    generic = run(False)
    assert fast == generic
    assert fast[3] > 0               # the cap genuinely bound


def test_quota_checked_at_every_grant_instant():
    """Stronger than the bound-usage peak: at the instant of EVERY
    grant, admitted usage (informer non-terminal + reservations) plus
    the granted request must stay within the cap."""
    import repro.core.resources as rs

    overshoots = []
    orig_ck = rs.AdmissionArbiter._create_bookkeep

    def pck(self, req):
        share = self.tenant(req.tenant)
        if share.quota_cpu_m:
            cpu, _mem = self.tenant_usage()[0].get(req.tenant, 0), 0
            if cpu + req.cpu > share.quota_cpu_m:
                overshoots.append((req.tenant, cpu, req.cpu))
        return orig_ck(self, req)

    rs.AdmissionArbiter._create_bookkeep = pck
    try:
        res = _quota_plane().run(horizon_s=500_000)
    finally:
        rs.AdmissionArbiter._create_bookkeep = orig_ck
    assert res.arbiter.quota_rejects > 0
    assert not overshoots


def test_quota_property_sweep():
    """Hypothesis sweep: the instant-peak invariant holds across
    widths, cap levels and seeds (the StepAccumulator property test of
    the ISSUE checklist)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(width=st.integers(min_value=3, max_value=10),
                      caps=st.integers(min_value=2, max_value=6),
                      seed=st.integers(min_value=0, max_value=50))
    def check(width, caps, seed):
        quota = caps * 1200               # whole task-request multiples
        res = _quota_plane(seed=seed, width=width,
                           quota_cpu=quota).run(horizon_s=500_000)
        m = res.metrics
        assert m.tenant_cpu_accs["capped"].peak <= quota
        s = m.tenant_summary()
        for agg in s.values():
            assert agg["completed"] == agg["workflows"]

    check()


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------
def _preempt_plane(prod_priority=10, seed=7):
    plane = ControlPlane("kubeadaptor", admission_policy="preempt",
                         cluster_cfg=cal.PaperCluster(n_nodes=2), seed=seed,
                         usage_mode="event")
    batch = make_workflow("batchfan", wide_fanout(width=16))
    plane.add_stream(batch, repeats=2, tenant="batch",
                     arrival="concurrent", concurrency=2, priority=0)
    mont = make_workflow("montage", get_workflow_spec("montage"))
    plane.add_stream(mont, repeats=2, tenant="prod", arrival="poisson",
                     rate=0.2, burst=2, priority=prod_priority)
    return plane


def test_preemption_triggers_only_when_starved():
    res = _preempt_plane().run(horizon_s=500_000)
    arb = res.arbiter
    assert arb.preemptions > 0
    assert res.cluster.evictions == arb.preemptions
    for plan in arb.preemption_log:
        # beneficiary was blocked by a real headroom deficit ...
        assert plan["deficit_cpu_m"] > 0 or plan["deficit_mem_mi"] > 0
        assert plan["victims"], "a plan must evict someone"
        # ... and every victim belongs to a strictly lower class
        for _ns, _name, victim_tenant in plan["victims"]:
            assert arb.tenant(victim_tenant).priority < plan["priority"]


def test_no_preemption_without_priority_gap():
    """Equal priorities: the starvation condition can never hold, so
    the armed Preempt stage must stay silent."""
    res = _preempt_plane(prod_priority=0).run(horizon_s=500_000)
    assert res.arbiter.preemptions == 0
    assert res.cluster.evictions == 0
    assert res.arbiter.preemption_log == []


def test_preempted_pods_eventually_complete():
    res = _preempt_plane().run(horizon_s=500_000)
    m = res.metrics
    s = m.tenant_summary()
    # every workflow of every tenant completed despite evictions
    for agg in s.values():
        assert agg["completed"] == agg["workflows"]
        assert agg["failed"] == 0
    assert s["batch"]["preempted"] == float(res.arbiter.preemptions)
    assert s["prod"]["preempted"] == 0.0
    # eviction is not a failure: the retry budget was never charged
    assert all(r.retries == 0 for r in m.workflows.values())
    assert sum(r.preempted for r in m.workflows.values()) \
        == res.arbiter.preemptions
    assert res.gateway.pending() == 0


def test_preempt_without_contention_matches_priority():
    """No starvation -> the preempt preset is bit-identical to plain
    priority ordering (the Preempt stage only ever adds evictions)."""
    def run(policy):
        plane = ControlPlane("kubeadaptor", admission_policy=policy, seed=7)
        mont = make_workflow("montage", get_workflow_spec("montage"))
        plane.gateway.load([mont.with_instance(i) for i in range(2)])
        return _run_bindings(plane)

    seq_pre, res_pre = run("preempt")
    seq_prio, _ = run("priority")
    assert seq_pre == seq_prio
    assert res_pre.arbiter.preemptions == 0


def test_evict_pod_semantics():
    sim = Sim()
    cluster = Cluster(sim)
    cluster.create_namespace("ns1")
    sim.run()
    pod = PodObj(name="victim", namespace="ns1", task_id="t", workflow="w",
                 cpu_m=500, mem_mi=500, duration_s=1e9,
                 labels={"tenant": "batch"})
    cluster.create_pod(pod)
    sim.run(until=sim.now() + 5)
    live = cluster.pods[("ns1", "victim")]
    assert live.phase == RUNNING
    used_before = cluster.used()
    assert used_before == (500, 500)
    assert cluster.evict_pod("ns1", "victim") is True
    assert live.phase == FAILED and live.evicted
    assert cluster.used() == (0, 0)
    assert cluster.tenant_holding_cpu["batch"] == 0
    assert cluster.tenant_holding_mem["batch"] == 0
    assert cluster.evictions == 1
    # not RUNNING anymore: second eviction is a no-op
    assert cluster.evict_pod("ns1", "victim") is False
    assert cluster.evict_pod("ns1", "ghost") is False
    assert cluster.evictions == 1


# ---------------------------------------------------------------------------
# trace capture round-trip
# ---------------------------------------------------------------------------
def test_trace_capture_roundtrip(tmp_path):
    mont = make_workflow("montage", get_workflow_spec("montage"))
    ligo = make_workflow("ligo", get_workflow_spec("ligo"))

    plane = ControlPlane("kubeadaptor", seed=11)
    plane.add_stream(mont, repeats=2, tenant="a", arrival="concurrent",
                     concurrency=2, weight=2.0)
    plane.add_stream(ligo, repeats=2, tenant="b", arrival="poisson",
                     rate=0.1, burst=1, priority=3, deadline_s=400.0)
    res = plane.run(horizon_s=500_000)

    path = tmp_path / "capture.json"
    doc = res.gateway.record_trace(path=str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == doc
    assert doc["schema"] == "arrival_trace/v1"
    assert len(doc["arrivals"]) == 4
    assert doc["tenants"]["b"] == {"priority": 3, "weight": 1.0,
                                   "deadline_s": 400.0}
    # times are pre-gRPC dispatch instants, non-decreasing per capture
    assert all(a["t"] >= 0 for a in doc["arrivals"])

    replay = ControlPlane("kubeadaptor", seed=11)
    replay.add_trace(doc["arrivals"], tenants=doc["tenants"])
    res2 = replay.run(horizon_s=500_000)
    # replay reproduces every submission instant and tenant exactly
    orig = sorted((round(r.submitted_at, 9), r.tenant)
                  for r in res.metrics.workflows.values())
    rep = sorted((round(r.submitted_at, 9), r.tenant)
                 for r in res2.metrics.workflows.values())
    assert rep == orig
    # the tenants header re-registered shares + deadline on the replay
    assert res2.arbiter.tenants["b"].priority == 3
    assert res2.metrics.tenant_deadlines["b"] == 400.0


def test_trace_capture_of_trace_replay_is_identity():
    """Replaying a capture and re-capturing yields the same arrivals —
    capture is a fixed point."""
    mont = make_workflow("montage", get_workflow_spec("montage"))
    plane = ControlPlane("kubeadaptor", seed=2)
    plane.add_stream(mont, repeats=3, tenant="t", arrival="serial")
    res = plane.run(horizon_s=500_000)
    doc = res.gateway.record_trace()

    replay = ControlPlane("kubeadaptor", seed=2)
    replay.add_trace(doc["arrivals"])
    res2 = replay.run(horizon_s=500_000)
    doc2 = res2.gateway.record_trace()
    assert doc2["arrivals"] == doc["arrivals"]


# ---------------------------------------------------------------------------
# per-stream SLO
# ---------------------------------------------------------------------------
def test_deadline_slo_hit_rates():
    def run(deadline):
        plane = ControlPlane("kubeadaptor", seed=4)
        mont = make_workflow("montage", get_workflow_spec("montage"))
        plane.add_stream(mont, repeats=2, tenant="t", arrival="serial",
                         deadline_s=deadline)
        return plane.run(horizon_s=500_000)

    hit = run(10_000.0).metrics.tenant_summary()["t"]
    assert hit["deadline_hit_rate"] == 1.0 and hit["deadline_hits"] == 2.0
    assert hit["deadline_s"] == 10_000.0
    miss = run(0.5).metrics.tenant_summary()["t"]
    assert miss["deadline_hit_rate"] == 0.0 and miss["deadline_hits"] == 0.0
    # no deadline registered -> no SLO keys (legacy summaries unchanged)
    plane = ControlPlane("kubeadaptor", seed=4)
    mont = make_workflow("montage", get_workflow_spec("montage"))
    plane.add_stream(mont, repeats=1, tenant="t")
    s = plane.run(horizon_s=500_000).metrics.tenant_summary()["t"]
    assert "deadline_hit_rate" not in s


def test_arbiter_accepts_pipeline_spec_and_custom_policy():
    """Programmatic composition: a PipelineSpec and a legacy
    order/may_backfill object both resolve (the latter through the
    generic loop)."""
    plane = ControlPlane("kubeadaptor", seed=1)

    class SillyPolicy:
        name = "silly"

        def order(self, pending, arbiter):
            return sorted(pending, key=lambda r: (r.task.id, r.seq))

        def may_backfill(self, blocked, candidate, arbiter):
            return True

    arb = AdmissionArbiter(plane.informers, policy=SillyPolicy())
    assert arb._fast is False            # generic loop
    arb2 = AdmissionArbiter(plane.informers,
                            policy=PipelineSpec(order="drf", preempt=True))
    assert arb2._fast is True
    assert arb2.preemptor is not None
