import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the jitted step (train_step / prefill / serve_step) with the
     real sharding rules,
  3. ``.lower(**input_specs)`` against ShapeDtypeStructs (no allocation),
  4. ``.compile()`` — any sharding mismatch / unsupported collective
     fails HERE, which is the point of the exercise,
  5. prints ``compiled.memory_analysis()`` + ``cost_analysis()`` and
     parses the optimized HLO for loop-aware FLOPs / collective bytes,
  6. writes a JSON artifact to ``artifacts/dryrun/`` for the roofline
     report (benchmarks/roofline.py reads these).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import REGISTRY, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import RunConfig, build
from repro.optim.adamw import OptConfig
from repro.parallel.sharding import ShardingPolicy
from repro.runtime.serve import build_decode_step, build_prefill_step
from repro.runtime.specs import input_specs
from repro.runtime.train import TrainRunConfig, build_train_step

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link


def pick_grad_accum(cfg, shape) -> int:
    """Microbatch count keeping activations-per-chip sane (see DESIGN)."""
    if cfg.family in ("ssm", "hybrid"):
        return 8            # SSD intra-chunk tensors are fat per param
    n = cfg.param_count()
    if n > 30e9:
        return 16
    if n > 8e9:
        return 8
    if n > 2e9:
        return 4
    return 2


def make_runconfig(cfg, shape) -> RunConfig:
    return RunConfig(
        param_dtype="float32",
        compute_dtype="bfloat16",
        remat=(shape.kind == "train"),
        remat_policy="full",   # save only layer-boundary carries
        attn_chunk=1024,
        attn_dense_max=4096,
        # c=32 is the measured intra/inter traffic optimum for BOTH ssm
        # archs (§Perf cell C + zamba2 confirmation)
        ssd_chunk=32 if shape.kind == "train" else 0,   # prefill prefers 128
    )


def build_cell(cfg, shape, mesh, rc=None, policy=None, trc=None):
    """Returns (jitted_fn, kwargs_of_ShapeDtypeStructs)."""
    rc = rc or make_runconfig(cfg, shape)
    policy = policy or ShardingPolicy()
    if shape.kind == "train":
        trc = trc or TrainRunConfig(opt=OptConfig(),
                                    grad_accum=pick_grad_accum(cfg, shape))
        jitted, state_sds, batch_sds, *_ = build_train_step(
            cfg, mesh, B=shape.global_batch, S=shape.seq_len, rc=rc,
            policy=policy, trc=trc)
        return jitted, {"state": state_sds, "batch": batch_sds}
    if shape.kind == "prefill":
        jitted, params_sds, batch_sds, *_ = build_prefill_step(
            cfg, mesh, B=shape.global_batch, S=shape.seq_len, rc=rc,
            policy=policy)
        return jitted, {"params": params_sds, "batch": batch_sds}
    if shape.kind == "decode":
        jitted, params_sds, cache_sds, batch_sds, *_ = build_decode_step(
            cfg, shape, mesh, rc=rc, policy=policy)
        return jitted, {"params": params_sds, "cache": cache_sds,
                        "batch": batch_sds}
    raise ValueError(shape.kind)


def roofline_terms(stats: hlo_analysis.HloStats):
    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.mem_bytes / HBM_BW
    collective_s = stats.total_collective_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return terms, dominant


def model_flops(cfg, shape) -> float:
    """Analytic 6ND / 2ND 'useful' FLOPs for the cell (global)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: one token


def _tree_bytes(sds_tree) -> int:
    import numpy as np
    total = 0
    for leaf in jax.tree.leaves(sds_tree):
        total += int(np.prod(leaf.shape)) * jnp_dtype_size(leaf.dtype)
    return total


def jnp_dtype_size(dt) -> int:
    import numpy as np
    try:
        return np.dtype(dt).itemsize
    except TypeError:
        return 2  # bf16 et al.


def ideal_step_seconds(cfg, shape, n_chips: int, kwargs) -> float:
    """The roofline floor for this cell on this mesh.

    train/prefill: compute-bound floor (MODEL_FLOPS at peak bf16).
    decode: ALSO bandwidth-bound floor — every step must stream the
    (bf16) weights + the KV/SSM cache once; the larger floor governs.
    """
    comp = model_flops(cfg, shape) / n_chips / PEAK_FLOPS
    if shape.kind != "decode":
        return comp
    bytes_ideal = cfg.active_param_count() * 2
    if "cache" in kwargs:
        bytes_ideal += _tree_bytes(kwargs["cache"])
    return max(comp, bytes_ideal / n_chips / HBM_BW)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             mesh=None, verbose: bool = True, policy=None, rc=None,
             trc=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "tag": tag, "status": "ok"}

    if not shape_applicable(cfg, shape):
        result["status"] = "skipped"
        result["reason"] = ("long_500k requires a sub-quadratic family; "
                            f"{arch} is pure full-attention (see DESIGN.md)")
        print(f"[dryrun] SKIP {cell_id}: {result['reason']}")
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{cell_id}.json").write_text(json.dumps(result, indent=1))
        return result

    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        jitted, kwargs = build_cell(cfg, shape, mesh, rc=rc, policy=policy, trc=trc)
        # positional: dict insertion order matches the step signature
        lowered = jitted.lower(*kwargs.values())
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failing cell is a bug we must surface
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {cell_id}: {result['error']}")
        return result

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    stats = hlo_analysis.analyze(text)
    terms, dominant = roofline_terms(stats)
    mf = model_flops(cfg, shape)
    hlo_flops_global = stats.flops * n_chips

    result.update({
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes,
        },
        "xla_cost_analysis": {"flops": cost.get("flops", 0.0),
                              "bytes_accessed": cost.get("bytes accessed", 0.0)},
        "hlo_per_device": {
            "flops": stats.flops,
            "mem_bytes": stats.mem_bytes,
            "collective_bytes": dict(stats.collective_bytes),
            "collective_counts": dict(stats.collective_counts),
            "total_collective_bytes": stats.total_collective_bytes,
            "n_while": stats.n_while,
            "trip_counts": stats.trip_counts[:32],
        },
        "roofline": {**terms, "dominant": dominant,
                     "step_time_bound_s": max(terms.values())},
        "model_flops_global": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "ideal_step_s": ideal_step_seconds(cfg, shape, n_chips, kwargs),
        "roofline_fraction": (
            ideal_step_seconds(cfg, shape, n_chips, kwargs) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
    })

    if verbose:
        ma = result["memory_analysis"]
        print(f"[dryrun] OK   {cell_id}  compile={t_compile:.1f}s")
        print(f"  memory_analysis: args={ma['argument_bytes']/1e9:.2f}GB "
              f"temp={ma['temp_bytes']/1e9:.2f}GB "
              f"peak/device={ma['peak_bytes_per_device']/1e9:.2f}GB")
        print(f"  cost_analysis: flops/dev={cost.get('flops', 0):.3e} "
              f"bytes/dev={cost.get('bytes accessed', 0):.3e}")
        print(f"  hlo(loop-aware)/dev: flops={stats.flops:.3e} "
              f"mem={stats.mem_bytes/1e9:.2f}GB "
              f"coll={stats.total_collective_bytes/1e9:.3f}GB "
              f"{dict(stats.collective_counts)}")
        print(f"  roofline: compute={terms['compute_s']*1e3:.2f}ms "
              f"memory={terms['memory_s']*1e3:.2f}ms "
              f"collective={terms['collective_s']*1e3:.2f}ms "
              f"dominant={dominant} useful_ratio={result['useful_flops_ratio']:.3f} "
              f"roofline_frac={result['roofline_fraction']:.3f}")

    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell_id}.json").write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-cached", action="store_true")
    args = ap.parse_args()

    archs = sorted(REGISTRY) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    summary = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                cached = out_dir / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_cached and cached.exists():
                    prev = json.loads(cached.read_text())
                    if prev.get("status") == "ok" or prev.get("status") == "skipped":
                        print(f"[dryrun] CACHED {cached.stem} ({prev['status']})")
                        summary.append(prev)
                        continue
                summary.append(run_cell(arch, shape, multi, out_dir, mesh=mesh))

    ok = sum(1 for r in summary if r["status"] == "ok")
    sk = sum(1 for r in summary if r["status"] == "skipped")
    bad = [r for r in summary if r["status"] == "error"]
    print(f"\n[dryrun] total={len(summary)} ok={ok} skipped={sk} failed={len(bad)}")
    for r in bad:
        print(f"  FAILED {r['arch']} x {r['shape']} x {r['mesh']}: {r['error']}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
