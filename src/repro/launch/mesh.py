"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before any jax
import and only then calls ``make_production_mesh``.

Production target: TPU v5e pods, 256 chips per pod.
  single-pod: (data=16, model=16)
  multi-pod : (pod=2, data=16, model=16) = 512 chips
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / reduced configs (e.g. (2,4) on 8 devs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
