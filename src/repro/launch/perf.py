import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""§Perf hillclimbing driver: re-lower one cell under named variants.

Each variant overrides RunConfig / ShardingPolicy / TrainRunConfig knobs
and writes a tagged artifact next to the baseline, so
EXPERIMENTS.md §Perf can diff terms per hypothesis.

  PYTHONPATH=src python -m repro.launch.perf --cell deepseek-67b:train_4k \
      --variant accum8
"""
import argparse
import dataclasses
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import make_runconfig, pick_grad_accum, run_cell
from repro.launch.mesh import make_production_mesh
from repro.models import RunConfig
from repro.optim.adamw import OptConfig
from repro.parallel.sharding import ShardingPolicy
from repro.runtime.train import TrainRunConfig


def variants_for(cfg, shape):
    """Named knob bundles. Each returns (rc, policy, trc) overrides."""
    base_rc = make_runconfig(cfg, shape)
    base_trc = TrainRunConfig(opt=OptConfig(),
                              grad_accum=pick_grad_accum(cfg, shape))
    pol = ShardingPolicy()

    def trc_accum(a):
        return TrainRunConfig(opt=OptConfig(), grad_accum=a)

    out = {
        "baseline": (base_rc, pol, base_trc),
        # microbatching: fewer FSDP weight regathers vs more activations
        "accum4": (base_rc, pol, trc_accum(4)),
        "accum8": (base_rc, pol, trc_accum(8)),
        "accum2": (base_rc, pol, trc_accum(2)),
        # params kept bf16 (no f32 master copies in the jit graph)
        "bf16params": (base_rc.replace(param_dtype="bfloat16"), pol, base_trc),
        # no FSDP: pure TP + replicated storage (small models only)
        "nofsdp": (base_rc, ShardingPolicy(fsdp=False), base_trc),
        # remat policy: save dot outputs instead of recomputing everything
        "rematdots": (base_rc.replace(remat_policy="dots"), pol, base_trc),
        "noremat": (base_rc.replace(remat=False), pol, base_trc),
        # attention chunk sizing
        "chunk512": (base_rc.replace(attn_chunk=512), pol, base_trc),
        "chunk2048": (base_rc.replace(attn_chunk=2048), pol, base_trc),
        "densattn": (base_rc.replace(attn_dense_max=100_000), pol, base_trc),
        # MoE dispatch group sizing
        "moegroup4096": (base_rc.replace(moe_group=4096), pol, base_trc),
        "moegroup1024": (base_rc.replace(moe_group=1024), pol, base_trc),
        "moegroup8192": (base_rc.replace(moe_group=8192), pol, base_trc),
        "moe8192_accum8": (base_rc.replace(moe_group=8192), pol, trc_accum(8)),
        "moe8192_accum4": (base_rc.replace(moe_group=8192), pol, trc_accum(4)),
        "moe16384_accum4": (base_rc.replace(moe_group=16384), pol, trc_accum(4)),
        "moe8192_a8_bf16": (base_rc.replace(moe_group=8192,
                                            param_dtype="bfloat16"), pol,
                            trc_accum(8)),
        "moe8192_a8_bf16_ax": (base_rc.replace(moe_group=8192,
                                               param_dtype="bfloat16",
                                               attn_exit_constrain=True), pol,
                               trc_accum(8)),
        "attnexit": (base_rc.replace(attn_exit_constrain=True), pol, base_trc),
        # Megatron-SP residual carries (layer-stash / collective trade)
        "spcarry": (base_rc.replace(seq_shard_carry=True), pol, base_trc),
        "spcarry_accum8": (base_rc.replace(seq_shard_carry=True), pol,
                           trc_accum(8)),
        "spcarry_accum4": (base_rc.replace(seq_shard_carry=True), pol,
                           trc_accum(4)),
        "spcarry_dots": (base_rc.replace(seq_shard_carry=True,
                                         remat_policy="dots"), pol, base_trc),
        "spcarry_noremat": (base_rc.replace(seq_shard_carry=True,
                                            remat=False), pol, base_trc),
        # combined best-known (deepseek cell): SP carries + accum4 +
        # chunked attention + bf16 params
        "best_dense": (base_rc.replace(seq_shard_carry=True,
                                       attn_dense_max=2048,
                                       param_dtype="bfloat16"), pol,
                       trc_accum(4)),
        "sp_a4_bf16": (base_rc.replace(seq_shard_carry=True,
                                       param_dtype="bfloat16"), pol,
                       trc_accum(4)),
        # SSD chunk sizing (ssm/hybrid)
        "ssdchunk128": (base_rc.replace(ssd_chunk=128), pol, base_trc),
        "ssdchunk32": (base_rc.replace(ssd_chunk=32), pol, base_trc),
        "ssdchunk16": (base_rc.replace(ssd_chunk=16), pol, base_trc),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    arch, shape_name = args.cell.split(":")
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rc, pol, trc = variants_for(cfg, shape)[args.variant]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    r = run_cell(arch, shape_name, args.multi_pod, Path(args.out), mesh=mesh,
                 rc=rc, policy=pol, trc=trc, tag=args.variant)
    return 0 if r["status"] == "ok" else 1


if __name__ == "__main__":
    raise SystemExit(main())
