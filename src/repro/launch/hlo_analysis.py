"""Post-SPMD HLO analysis: loop-aware FLOPs, memory traffic & collectives.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — with
scan-over-layers that under-reports a 95-layer model by ~95x. This
module parses ``compiled.as_text()`` (the partitioned, optimized module)
and walks the call graph from ENTRY, multiplying each while body by its
trip count (recovered from the loop-condition constant), to produce
per-device:

  * flops            — 2*M*N*K over every dot (trip-count weighted)
  * mem_bytes        — sum of operand+result sizes of top-level ops
                       (fusions counted at their call site = an HBM
                       traffic model), trip-count weighted
  * collective_bytes — operand sizes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       by type, trip-count weighted

All numbers are PER DEVICE (the module analyzed is the per-partition
one); roofline terms divide by per-chip peak rates.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples by summing)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    op: str
    out_type: str
    operands: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[^\s=]+))\s+"      # type: tuple | bare (layout incl.)
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            s = line.strip()
            if s.endswith("{") and "->" in s and (s.startswith("%") or s.startswith("ENTRY")):
                tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
                name = tok.lstrip("%").split("(")[0]
                cur = Computation(name)
                comps[name] = cur
                if s.startswith("ENTRY"):
                    entry = name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, out_type, op, rest = m.groups()
        # operand section: up to the closing paren at depth 0
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str, attrs = rest[:end], rest[end + 1:]
        operands = [o for o in _OPERAND_RE.findall(operand_str)]
        inst = Instr(name, op, out_type, operands, line)
        cur.instrs.append(inst)
        cur.by_name[name] = inst
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Recover scan trip count from the loop condition's compare constant."""
    consts = []
    for inst in cond.instrs:
        if inst.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", inst.raw)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _dot_flops(inst: Instr, comp: Computation, comps) -> int:
    out_dims = _shape_dims(inst.out_type) or []
    lhs_name = inst.operands[0] if inst.operands else None
    lhs = comp.by_name.get(lhs_name)
    lhs_dims = _shape_dims(lhs.out_type) if lhs else None
    if lhs_dims is None:
        return 0
    m = _CONTRACT_RE.search(inst.raw)
    contract = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    k = 1
    for d in contract:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    out = 1
    for d in out_dims:
        out *= d
    return 2 * out * k


def _called(inst: Instr) -> List[Tuple[str, str]]:
    """[(kind, computation_name)] referenced by this instruction."""
    out = []
    for key in ("calls", "body", "condition", "to_apply"):
        for m in re.finditer(key + r"=%?([\w.\-]+)", inst.raw):
            out.append((key, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", inst.raw)
    if m:
        for name in _OPERAND_RE.findall(m.group(1)):
            out.append(("branch", name))
    return out


@dataclass
class HloStats:
    flops: float = 0.0
    mem_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    n_while: int = 0
    trip_counts: List[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze(text: str) -> HloStats:
    comps, entry = parse_hlo(text)
    stats = HloStats()
    if entry is None:
        return stats

    def operand_bytes(inst: Instr, comp: Computation,
                      skip_aliased: bool = False) -> int:
        sizes = []
        for op_name in inst.operands:
            src = comp.by_name.get(op_name)
            if src is not None:
                sizes.append(_shape_bytes(src.out_type))
        if skip_aliased and sizes:
            # in-place update: the big buffer operand aliases the output
            # (only the touched slice moves) — drop the largest operand
            sizes.remove(max(sizes))
        return sum(sizes)

    def fusion_root_op(comp_name: str) -> str:
        comp = comps.get(comp_name)
        if comp and comp.instrs:
            return comp.instrs[-1].op      # ROOT is last in HLO text
        return ""

    _INPLACE = ("dynamic-update-slice", "scatter")

    seen_depth = [0]

    def walk(comp_name: str, mult: float):
        if comp_name not in comps or seen_depth[0] > 64:
            return
        seen_depth[0] += 1
        comp = comps[comp_name]
        for inst in comp.instrs:
            opn = inst.op
            base = opn.replace("-start", "")
            if base in _COLLECTIVES:
                b = operand_bytes(inst, comp)
                stats.collective_bytes[base] += mult * b
                stats.collective_counts[base] += 1
            if base == "dot":
                stats.flops += mult * _dot_flops(inst, comp, comps)
            # HBM traffic model: top-level op operands + result.
            # In-place updates (dus/scatter, incl. fusions rooted in them)
            # alias their buffer operand — only the update slice moves.
            if opn not in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast", "while", "call", "conditional"):
                skip = opn in _INPLACE
                out_b = _shape_bytes(inst.out_type)
                if opn == "fusion":
                    for kind, name in _called(inst):
                        if kind == "calls" and fusion_root_op(name) in _INPLACE:
                            skip = True
                if opn == "dynamic-slice":
                    stats.mem_bytes += mult * 2 * out_b   # slice read+write
                elif skip:
                    # read the small operands, write the updated slice
                    stats.mem_bytes += mult * 2 * operand_bytes(
                        inst, comp, skip_aliased=True)
                else:
                    stats.mem_bytes += mult * (operand_bytes(inst, comp) + out_b)
            # control flow
            if opn == "while":
                body = cond = None
                for kind, name in _called(inst):
                    if kind == "body":
                        body = name
                    elif kind == "condition":
                        cond = name
                m = _TRIP_RE.search(inst.raw)  # XLA annotates static trip counts
                if m:
                    trips = int(m.group(1))
                else:
                    trips = _trip_count(comps[cond]) if cond in comps else 1
                stats.n_while += 1
                stats.trip_counts.append(trips)
                if body:
                    walk(body, mult * max(trips, 1))
            elif opn in ("call", "conditional", "custom-call"):
                for kind, name in _called(inst):
                    if kind in ("calls", "branch", "to_apply") and name in comps:
                        walk(name, mult)
            # NOTE: fusion bodies are NOT traversed (in-VMEM compute);
            # dots inside fusions still matter for flops though:
            elif opn == "fusion":
                for kind, name in _called(inst):
                    if kind == "calls" and name in comps:
                        walk_fusion_dots(name, mult)
        seen_depth[0] -= 1

    def walk_fusion_dots(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for inst in comp.instrs:
            if inst.op == "dot":
                stats.flops += mult * _dot_flops(inst, comp, comps)

    walk(entry, 1.0)
    return stats
