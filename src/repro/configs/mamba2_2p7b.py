"""mamba2-2.7b — pure Mamba2 (SSD) stack, attention-free.

[arXiv:2405.21060; unverified] 64L d_model=2560, d_ff=0, vocab=50280,
ssm_state=128. d_inner = 2*2560 = 5120, head_dim P=64 -> 80 SSD heads.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    source="SSD (state-space duality) [arXiv:2405.21060; unverified]",
)
