"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 (EnCodec codebook size). The audio frontend (EnCodec) is a
STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings (B, S, d_model); the head predicts one codebook stream.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio",
    source="decoder-only over EnCodec tokens [arXiv:2306.05284; hf]",
)
