"""Architecture & shape configuration system.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` module
defining ``CONFIG: ArchConfig`` with the exact published shape.  The
registry in ``configs/__init__.py`` exposes ``get_config`` /
``list_configs`` for ``--arch <id>`` selection everywhere (launchers,
benchmarks, tests).

``ArchConfig.reduced()`` derives a tiny same-family config used by the
per-arch CPU smoke tests; the full configs are only ever exercised via
the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    """A single architecture's hyper-parameters (published shapes)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int                        # dense MLP hidden (0 = no MLP)
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # attention details
    qkv_bias: bool = False
    gelu_mlp: bool = False           # True = GeGLU (gemma), False = SwiGLU
    logit_softcap: float = 0.0       # gemma-style final-logit soft cap (0 = off)
    rope_theta: float = 10_000.0
    scale_embeddings: bool = False   # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0               # routed experts (0 = dense)
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0               # N (d_state); 0 = no SSM layers
    ssm_head_dim: int = 64           # P
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_chunk: int = 128             # SSD chunk length
    attn_every: int = 0              # hybrid: shared attn block after every N ssm layers

    # modality frontends (STUBS per assignment: precomputed embeddings)
    frontend: Optional[str] = None   # None | 'audio' | 'vision'
    cross_attn_every: int = 0        # vlm: cross-attn layer after every N self layers
    n_img_tokens: int = 1601         # vision stub: patch tokens per image

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    source: str = ""                 # provenance note ([arXiv/hf; tier])

    # ---- derived ----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way TP.

        Only mamba2's 50280 actually needs this (-> 50432); padding rows
        are masked out of the loss. Standard Megatron-style practice.
        """
        return _round_up(self.vocab_size, 256)

    @property
    def n_experts_padded(self) -> int:
        """Experts padded to a multiple of 16 for clean EP sharding.

        qwen2-moe's 60 -> 64; the 4 pad experts get -inf router logits.
        """
        if self.n_experts == 0:
            return 0
        return _round_up(self.n_experts, 16)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token contexts (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS and docs)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = 0
        # embeddings (+ untied LM head)
        n += self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm") or self.attn_every:
            attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
            mlp = 0
            if self.n_experts:
                mlp += self.n_experts * 3 * d * self.expert_d_ff
                mlp += d * self.n_experts  # router
                if self.shared_expert_d_ff:
                    mlp += 3 * d * self.shared_expert_d_ff
            elif self.d_ff:
                mlp += 3 * d * self.d_ff
            block = attn + mlp + 2 * d
        else:
            block = 0
        if self.family in ("ssm", "hybrid"):
            di, N, H = self.ssm_d_inner, self.ssm_state, self.ssm_n_heads
            ssm = d * di * 2            # x, z projections
            ssm += d * N * 2            # B, C projections
            ssm += d * H                # dt projection
            ssm += self.ssm_conv_width * (di + 2 * N)  # causal conv
            ssm += H * 3                # A_log, dt_bias, D
            ssm += di * d               # out proj
            ssm += 2 * d                # norms
            if self.family == "ssm":
                per_layer = ssm
                n += self.n_layers * per_layer
            else:  # hybrid: ssm stack + ONE shared attn/mlp block
                n += self.n_layers * ssm
                n += block              # shared weights counted once
        else:
            per_layer = block
            n += self.n_layers * per_layer
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            cross = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2 + 2 * d
            n += n_cross * cross
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k only) for 6·N_active·D."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense_experts = self.n_experts * 3 * d * self.expert_d_ff
        active_experts = self.top_k * 3 * d * self.expert_d_ff
        return self.param_count() - self.n_layers * (dense_experts - active_experts)

    # ---- reduced config for CPU smoke tests --------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for single-CPU smoke tests."""
        changes = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if self.attn_every == 0 else 2 * max(self.attn_every, 1)),
            d_model=128,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
        )
        if self.n_experts:
            changes.update(n_experts=8, top_k=min(self.top_k, 2), expert_d_ff=64,
                           n_shared_experts=min(self.n_shared_experts, 1),
                           shared_expert_d_ff=64 if self.shared_expert_d_ff else 0)
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.attn_every:
            changes.update(attn_every=2, n_layers=4)
        if self.cross_attn_every:
            changes.update(cross_attn_every=2, n_layers=4, n_img_tokens=16)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (workload cell)."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes. decode_*/long_* lower `serve_step`
# (one new token against a KV cache of seq_len), NOT train_step.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k only runs for sub-quadratic families (see DESIGN.md)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
