"""llama-3.2-vision-11b — decoder with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified] 40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256. A cross-attention layer (attending
to vision patch embeddings) is inserted after every 5th self-attention
layer. The vision encoder is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings
(B, n_img_tokens, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    frontend="vision",
    cross_attn_every=5,
    n_img_tokens=1601,
    source="cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)
