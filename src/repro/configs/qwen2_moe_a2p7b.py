"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 24L d_model=2048 16H (GQA kv=16)
expert d_ff=1408 vocab=151936. Shared expert hidden = 4*1408 = 5632.
60 routed experts are padded to 64 for clean 16-way EP (pad experts get
-inf router logits; see ArchConfig.n_experts_padded).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151_936,
    qkv_bias=True,
    n_experts=60,
    top_k=4,
    expert_d_ff=1408,
    n_shared_experts=4,
    shared_expert_d_ff=5632,
    source="4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
)
