"""Architecture registry: ``--arch <id>`` resolution.

All ten assigned architectures plus the paper's own workload (the
KubeAdaptor paper has no model of its own — its workloads are workflow
DAGs, registered in ``configs/workflows.py``).
"""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_applicable

from repro.configs import (
    mamba2_2p7b,
    zamba2_1p2b,
    llama4_scout_17b_a16e,
    qwen2_moe_a2p7b,
    qwen2_1p5b,
    gemma_7b,
    deepseek_67b,
    qwen2_0p5b,
    musicgen_medium,
    llama32_vision_11b,
)

_MODULES = (
    mamba2_2p7b,
    zamba2_1p2b,
    llama4_scout_17b_a16e,
    qwen2_moe_a2p7b,
    qwen2_1p5b,
    gemma_7b,
    deepseek_67b,
    qwen2_0p5b,
    musicgen_medium,
    llama32_vision_11b,
)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_configs():
    return sorted(REGISTRY)


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "REGISTRY", "get_config", "list_configs",
]
