"""llama4-scout-17b-a16e — MoE with 16 routed experts, top-1 + shared.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120
40H (GQA kv=8) d_ff=8192 (expert hidden) vocab=202048, MoE 16e top-1,
one shared expert per layer (early-fusion multimodal in the original;
text backbone here).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    n_experts=16,
    top_k=1,
    expert_d_ff=8192,
    n_shared_experts=1,
    shared_expert_d_ff=8192,
    rope_theta=500_000.0,
    source="MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
