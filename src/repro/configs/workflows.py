"""The paper's four real-world scientific workflows (§5.2, Fig 5).

Encoded in the exact ConfigMap JSON format of Listing 1 (input/output/
image/cpuNum/memNum/args per task node). Structures follow the ~20-task
variants from the Pegasus workflow gallery, with entry/exit nodes added
at the entrance and exit (the paper gives every node the same stress
task: ``-c 1 -m 100 -t 5`` -> ~10 s busy).

Level structure (depth includes entry/exit):
  montage      4-6-1-1-4-1-1 core, depth 10   (mProjectPP..mJPEG)
  epigenomics  1-4-4-4-4-1-1 core, depth 9    (fastqSplit..maqIndex)
  cybershake   2-8-8-2 core, depth 6          (ExtractSGT..ZipPSA)
  ligo         4-8-2-4-1 core, depth 7        (TmpltBank..Thinca2)
"""
from __future__ import annotations

from typing import Dict, List

IMAGE = "shanchenggang/task-emulator:latest"
ARGS = ["-c", "1", "-m", "100", "-t", "5"]
CPU, MEM = "1200", "1200"


def _node(inputs: List[str], outputs: List[str]) -> Dict:
    return {"input": inputs, "output": outputs, "image": [IMAGE],
            "cpuNum": [CPU], "memNum": [MEM], "args": list(ARGS)}


def _wire(layers: List[List[str]], edges: Dict[str, List[str]]) -> Dict[str, Dict]:
    """Build ConfigMap dict from explicit edge lists (u -> [v...])."""
    nodes = [n for layer in layers for n in layer]
    spec = {n: _node([], []) for n in nodes}
    for u, vs in edges.items():
        for v in vs:
            spec[u]["output"].append(v)
            spec[v]["input"].append(u)
    return spec


def montage() -> Dict[str, Dict]:
    proj = [f"mProjectPP{i}" for i in range(1, 5)]
    diff = [f"mDiffFit{i}" for i in range(1, 7)]
    bg = [f"mBackground{i}" for i in range(1, 5)]
    layers = [["entry"], proj, diff, ["mConcatFit"], ["mBgModel"], bg,
              ["mImgtbl"], ["mAdd"], ["mJPEG"], ["exit"]]
    edges: Dict[str, List[str]] = {"entry": proj}
    # each mDiffFit consumes an overlapping pair of projections
    pairs = [(0, 1), (1, 2), (2, 3), (0, 2), (1, 3), (0, 3)]
    for d, (a, b) in zip(diff, pairs):
        edges.setdefault(proj[a], []).append(d)
        edges.setdefault(proj[b], []).append(d)
    for d in diff:
        edges.setdefault(d, []).append("mConcatFit")
    edges["mConcatFit"] = ["mBgModel"]
    edges["mBgModel"] = list(bg)
    for i, b in enumerate(bg):   # mBackground_i also re-reads projection i
        edges.setdefault(proj[i], []).append(b)
        edges.setdefault(b, []).append("mImgtbl")
    edges["mImgtbl"] = ["mAdd"]
    edges["mAdd"] = ["mJPEG"]
    edges["mJPEG"] = ["exit"]
    return _wire(layers, edges)


def epigenomics() -> Dict[str, Dict]:
    lanes = range(1, 5)
    filt = [f"filterContams{i}" for i in lanes]
    sol = [f"sol2sanger{i}" for i in lanes]
    bfq = [f"fastq2bfq{i}" for i in lanes]
    mp = [f"map{i}" for i in lanes]
    layers = [["entry"], ["fastqSplit"], filt, sol, bfq, mp,
              ["mapMerge"], ["maqIndex"], ["exit"]]
    edges: Dict[str, List[str]] = {"entry": ["fastqSplit"],
                                   "fastqSplit": list(filt)}
    for a, b, c, d in zip(filt, sol, bfq, mp):
        edges[a] = [b]
        edges[b] = [c]
        edges[c] = [d]
        edges[d] = ["mapMerge"]
    edges["mapMerge"] = ["maqIndex"]
    edges["maqIndex"] = ["exit"]
    return _wire(layers, edges)


def cybershake() -> Dict[str, Dict]:
    sgt = ["ExtractSGT1", "ExtractSGT2"]
    seis = [f"Seismogram{i}" for i in range(1, 9)]
    peak = [f"PeakValCalc{i}" for i in range(1, 9)]
    layers = [["entry"], sgt, seis, peak + ["ZipSeis"], ["ZipPSA"], ["exit"]]
    edges: Dict[str, List[str]] = {"entry": list(sgt)}
    for i, s in enumerate(seis):     # 4 synthesis jobs per SGT extraction
        edges.setdefault(sgt[i // 4], []).append(s)
        edges.setdefault(s, []).extend([peak[i], "ZipSeis"])
    for p in peak:
        edges.setdefault(p, []).append("ZipPSA")
    edges.setdefault("ZipSeis", []).append("exit")
    edges["ZipPSA"] = ["exit"]
    return _wire(layers, edges)


def ligo() -> Dict[str, Dict]:
    bank = [f"TmpltBank{i}" for i in range(1, 5)]
    insp = [f"Inspiral{i}" for i in range(1, 9)]
    thinca = ["Thinca1", "Thinca2"]
    trig = [f"TrigBank{i}" for i in range(1, 5)]
    layers = [["entry"], bank, insp, thinca, trig, ["Thinca2nd"], ["exit"]]
    edges: Dict[str, List[str]] = {"entry": list(bank)}
    for i, s in enumerate(insp):     # 2 inspirals per template bank
        edges.setdefault(bank[i // 2], []).append(s)
        edges.setdefault(s, []).append(thinca[i // 4])
    for i, t in enumerate(trig):     # 2 trigbanks per thinca
        edges.setdefault(thinca[i // 2], []).append(t)
        edges.setdefault(t, []).append("Thinca2nd")
    edges["Thinca2nd"] = ["exit"]
    return _wire(layers, edges)


WORKFLOWS = {
    "montage": montage,
    "epigenomics": epigenomics,
    "cybershake": cybershake,
    "ligo": ligo,
}


def get_workflow_spec(name: str) -> Dict[str, Dict]:
    return WORKFLOWS[name]()


# ---------------------------------------------------------------------------
# Synthetic admission-pressure workload (beyond-paper): a src -> N-wide
# fan-out -> sink DAG that keeps many tasks ready at once, unlike the
# paper DAGs whose narrow phases gate demand. ConfigMap format, so it
# parses through the same path as the scientific workflows.
# ---------------------------------------------------------------------------
def wide_fanout(width: int = 24, duration_s: float = 8.0) -> Dict[str, Dict]:
    secs = str(duration_s / 2.0)            # stress -t secs -> 2x busy time
    spec = {"src": _node([], []), "sink": _node([], [])}
    spec["src"]["args"] = spec["sink"]["args"] = \
        ["-c", "1", "-m", "100", "-t", "0.25"]
    for i in range(width):
        w = f"w{i}"
        spec[w] = _node(["src"], ["sink"])
        spec[w]["args"] = ["-c", "1", "-m", "100", "-t", secs]
        spec["src"]["output"].append(w)
        spec["sink"]["input"].append(w)
    return spec


# ---------------------------------------------------------------------------
# Multi-tenant scenario presets (beyond-paper): named stream mixes for the
# ControlPlane builder. Each entry is a list of add_stream kwargs minus the
# workflow object itself — resolve "workflow" names via get_workflow_spec.
# ---------------------------------------------------------------------------
TENANT_SCENARIOS: Dict[str, List[Dict]] = {
    # the paper's experiment, expressed as one serial default-tenant stream
    "paper-serial": [
        {"workflow": "montage", "repeats": 2, "tenant": "default",
         "arrival": "serial"},
    ],
    # two equal tenants racing fixed-concurrency streams
    "duel": [
        {"workflow": "montage", "repeats": 3, "tenant": "alice",
         "arrival": "concurrent", "concurrency": 2, "weight": 1.0},
        {"workflow": "cybershake", "repeats": 3, "tenant": "bob",
         "arrival": "concurrent", "concurrency": 2, "weight": 1.0},
    ],
    # a heavy production tenant vs a bursty best-effort tenant
    "prod-vs-burst": [
        {"workflow": "ligo", "repeats": 4, "tenant": "prod",
         "arrival": "concurrent", "concurrency": 2,
         "priority": 10, "weight": 3.0},
        {"workflow": "epigenomics", "repeats": 4, "tenant": "burst",
         "arrival": "poisson", "rate": 0.05, "burst": 2,
         "priority": 0, "weight": 1.0},
    ],
}
