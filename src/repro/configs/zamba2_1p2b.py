"""zamba2-1.2b — Mamba2 backbone + ONE shared attention+MLP block.

[arXiv:2411.15242; hf] 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64. The shared transformer block (its weights
counted once) is applied after every 6th Mamba2 layer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    attn_every=6,
    source="Mamba2 + shared attn blocks [arXiv:2411.15242; hf]",
)
