"""gemma-7b — dense, GeGLU, head_dim=256, scaled embeddings.

[arXiv:2403.08295; hf] 28L d_model=3072 16H (kv=16) d_ff=24576
vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab_size=256_000,
    gelu_mlp=True,
    scale_embeddings=True,
    tie_embeddings=True,
    source="GeGLU, head_dim=256 [arXiv:2403.08295; hf]",
)
