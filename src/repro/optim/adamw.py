"""AdamW + warmup-cosine schedule + global-norm clipping (no optax)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class TrainState(NamedTuple):
    params: Any
    m: Any
    v: Any
    step: jax.Array


def schedule(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> TrainState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, m=zeros,
                      v=jax.tree.map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def apply_updates(state: TrainState, grads, cfg: OptConfig):
    """One AdamW step; returns (new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, state.params, grads, state.m, state.v)
    params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return TrainState(params, m, v, step), {"lr": lr, "grad_norm": gnorm}
