"""Attention: GQA, dense + chunked(online-softmax) + decode-with-cache paths.

Shapes convention:
  q: (B, S, H, hd)    k/v: (B, T, K, hd)    H = K * G   (GQA groups)

Sharding design (see DESIGN.md §5): prefill/train attention computes in
full-H form — KV heads are broadcast to H *after* projection (GQA saves
KV memory/bandwidth, not score FLOPs) and scores are sharded over the
head axis ('tp'). This keeps every contraction (head_dim, seq) unsharded
so the only model-parallel collective per block is the Megatron
row-parallel all-reduce at wo/w2. Decode keeps the (K, G) folded form:
the KV cache stays in K heads (the big tensor) and the tiny score psum
is cheaper than materializing a repeated cache.

The chunked path is the memory-subquadratic attention used for 32k
prefill: O(S * chunk) live scores instead of O(S^2). The Pallas flash
kernel (kernels/flash_attention.py) implements the same algorithm for
TPU; ``kernels/ops.py`` dispatches between them.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import RunConfig, apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg, dtype, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, K * hd), dtype),
        "wv": dense_init(ks[2], (d, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def repeat_kv(k, n_heads: int):
    """(B,T,K,hd) -> (B,T,H,hd) by broadcasting each KV head over its group."""
    B, T, K, hd = k.shape
    G = n_heads // K
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, T, K, G, hd))
    return k.reshape(B, T, K * G, hd)


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0):
    """Dense attention in full-H form. q:(B,S,H,hd) k/v:(B,T,H,hd)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        S, T = scores.shape[-2], scores.shape[-1]
        qpos = jnp.arange(S) + q_offset
        mask = qpos[:, None] >= jnp.arange(T)[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", p, v)


def chunked_attention(q, k, v, *, chunk: int, causal: bool = True):
    """Online-softmax attention, scanning KV in blocks of ``chunk``.

    Full-H form. Memory: O(S * chunk) scores live at once (vs O(S^2)
    dense). FLOPs are the full S^2 (future blocks are masked, not
    skipped) — block skipping is a recorded §Perf hillclimb item.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    n_blocks = T // chunk
    assert n_blocks * chunk == T, (T, chunk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    kb = k.reshape(B, n_blocks, chunk, H, hd)
    vb = v.reshape(B, n_blocks, chunk, H, hd)
    qpos = jnp.arange(S)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        s = jnp.einsum("bshd,bchd->bhsc", q, kj).astype(jnp.float32) * scale
        if causal:
            kpos = j * chunk + jnp.arange(chunk)
            s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhsc,bchd->bshd", p.astype(vj.dtype), vj)
        acc = acc * corr.transpose(0, 2, 1)[..., None].astype(acc.dtype) + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, S, H, hd), v.dtype)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(n_blocks)))
    l = jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return (acc.astype(jnp.float32) / l).astype(v.dtype)


def _gqa_fold(q, n_kv):
    """(B,S,H,hd) -> (B,S,K,G,hd)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def decode_attention(q, k_cache, v_cache, index):
    """Single-token decode, GQA-folded. q:(B,1,K,G,hd) caches:(B,T,K,hd)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    # mixed-precision dot (bf16 x bf16 -> f32): avoids materializing an
    # f32 copy of the whole KV cache (7.5 GB/dev on gemma decode_32k)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(k_cache.shape[1]) <= index   # positions written so far
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", p, v_cache)


def apply_attention(
    params,
    x,
    cfg,
    rc: RunConfig,
    positions,
    *,
    kv_x=None,                 # cross-attention source (B, N, D); None = self
    causal: bool = True,
    cache: Optional[Tuple] = None,   # (k_cache, v_cache) for decode
    cache_index=None,
    return_kv: bool = False,
    is_cross: bool = False,
):
    """Returns (out, new_kv) where new_kv is (k,v) for caching or None."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    cross = is_cross or (kv_x is not None)
    src = kv_x if cross else x

    q = jnp.einsum("bsd,df->bsf", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = _split_heads(q, H, hd)

    if cross and cache is not None:
        # cross-attn KV was computed at prefill and lives in the cache
        k, v = cache
    else:
        k = jnp.einsum("bsd,df->bsf", src, params["wk"])
        v = jnp.einsum("bsd,df->bsf", src, params["wv"])
        if "bk" in params:
            k, v = k + params["bk"], v + params["bv"]
        k = _split_heads(k, K, hd)
        v = _split_heads(v, K, hd)
        if not cross:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_kv = None
    if cache is not None and not cross:
        # ---- decode: GQA-folded against the K-head cache ----
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_index, axis=1)
        new_kv = (k_cache, v_cache)
        out = decode_attention(_gqa_fold(q, K), k_cache, v_cache, cache_index)
        out = out.reshape(out.shape[:2] + (H * hd,))
    else:
        if cache is not None and cross:
            new_kv = (k, v)
        elif return_kv:
            new_kv = (k, v)
        # ---- full-H sharded compute ----
        # 'heads': classic Megatron head-TP (needs H % tp == 0).
        # 'seq':   query-sequence TP — each rank owns a q-row block against
        #          the full KV (always divisible; picked by the runtime when
        #          H doesn't divide the TP axis, e.g. 14 heads on tp=16).
        if rc.attn_shard == "seq":
            q_axes, kv_axes = ("dp", "tp", None, None), ("dp", None, None, None)
        else:
            q_axes = kv_axes = ("dp", None, "tp", None)
        q = rc.constrain(q, q_axes)
        kf = rc.constrain(repeat_kv(k, H), kv_axes)
        vf = rc.constrain(repeat_kv(v, H), kv_axes)
        S = x.shape[1]
        if causal and S > rc.attn_dense_max:
            out = chunked_attention(q, kf, vf, chunk=rc.attn_chunk or 1024,
                                    causal=True)
        else:
            out = full_attention(q, kf, vf, causal=causal)
        out = rc.constrain(out, q_axes)
        out = out.reshape(out.shape[:2] + (H * hd,))

    return jnp.einsum("bsf,fd->bsd", out, params["wo"]), new_kv
