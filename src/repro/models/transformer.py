"""Model assembly: blocks, scan-over-layers, hybrid/cross-attn interleave.

One code path per family:
  dense / moe / audio : homogeneous block stack    -> single lax.scan
  ssm                 : homogeneous Mamba2 stack   -> single lax.scan
  hybrid (zamba2)     : Mamba2 stack in segments, ONE shared attn+MLP
                        block applied after every ``attn_every`` layers
  vlm (llama3.2-V)    : self-attn stack in segments, gated cross-attn
                        layer after every ``cross_attn_every`` layers

Scan-over-layers keeps the HLO O(1) in depth: a 95-layer deepseek-67b
train step lowers to one while-loop body. Params are stored stacked
(leading L axis) so FSDP/TP shardings apply uniformly.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (RunConfig, apply_mlp, embed_init, init_mlp,
                                 rms_norm, softmax_cross_entropy)

# SSM / router leaves that stay f32 through compute-dtype casting
_KEEP_F32 = ("A_log", "dt_bias", "D_skip", "router", "gate")


def _cast_params(params, rc: RunConfig):
    def cast(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if any(k in name for k in _KEEP_F32):
            return leaf
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(rc.cdtype)
        return leaf
    return jax.tree_util.tree_map_with_path(cast, params)


# ---------------------------------------------------------------------------
# Block initialisers
# ---------------------------------------------------------------------------
def _init_attn_block(key, cfg, dtype, use_moe: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn_lib.init_attention(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_mamba_block(key, cfg, dtype):
    return {
        "ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "mamba": ssm_lib.init_mamba(key, cfg, dtype),
    }


def _init_cross_block(key, cfg, dtype):
    return {
        "ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn_lib.init_attention(key, cfg, dtype, cross=True),
        "gate": jnp.zeros((), jnp.float32),
    }


def init_params(cfg, key, rc: RunConfig) -> Dict[str, Any]:
    dtype = rc.pdtype
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab_padded, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(keys[1], (cfg.vocab_padded, cfg.d_model), dtype)

    L = cfg.n_layers
    if cfg.family in ("dense", "audio", "vlm"):
        params["blocks"] = jax.vmap(
            lambda k: _init_attn_block(k, cfg, dtype, use_moe=False)
        )(jax.random.split(keys[2], L))
    elif cfg.family == "moe":
        params["blocks"] = jax.vmap(
            lambda k: _init_attn_block(k, cfg, dtype, use_moe=True)
        )(jax.random.split(keys[2], L))
    elif cfg.family in ("ssm", "hybrid"):
        params["blocks"] = jax.vmap(
            lambda k: _init_mamba_block(k, cfg, dtype)
        )(jax.random.split(keys[2], L))
        if cfg.family == "hybrid":
            params["shared_block"] = _init_attn_block(keys[3], cfg, dtype, use_moe=False)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        params["cross_blocks"] = jax.vmap(
            lambda k: _init_cross_block(k, cfg, dtype)
        )(jax.random.split(keys[4], n_cross))
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _carry_axes(rc: RunConfig):
    # Megatron-SP: the residual stream parks sequence-sharded on 'tp'
    # between blocks (the axis is idle there) — 16x smaller scan stash.
    return ("dp", "tp", None) if rc.seq_shard_carry else ("dp", None, None)


def _enter(x, rc: RunConfig):
    """SP block entry: ONE all-gather of the post-norm activations."""
    if rc.seq_shard_carry:
        return rc.constrain(x, ("dp", None, None))
    return x


def _residual_add(h, delta, rc: RunConfig, block_exit: bool = False):
    """SP: reduce-scatter the block output into the sharded carry.
    Without SP, constrain only at the block exit (mid-block constraints
    measurably regressed the MoE cells — see §Perf cell B notes)."""
    if rc.seq_shard_carry:
        delta = rc.constrain(delta, _carry_axes(rc))
        return rc.constrain(h + delta, _carry_axes(rc))
    if block_exit or rc.attn_exit_constrain:
        return rc.constrain(h + delta, _carry_axes(rc))
    return h + delta


def _apply_attn_block(bp, h, cfg, rc, positions, *, cache=None, cache_index=None,
                      return_kv=False):
    x1 = _enter(rms_norm(h, bp["ln1"], cfg.norm_eps), rc)
    a, kv = attn_lib.apply_attention(
        bp["attn"], x1, cfg, rc, positions,
        cache=cache, cache_index=cache_index, return_kv=return_kv)
    h = _residual_add(h, a, rc)
    aux = jnp.zeros((), jnp.float32)
    x2 = _enter(rms_norm(h, bp["ln2"], cfg.norm_eps), rc)
    if "moe" in bp:
        m, aux = moe_lib.apply_moe(bp["moe"], x2, cfg, rc)
    else:
        m = apply_mlp(bp["mlp"], x2, gelu=cfg.gelu_mlp)
    h = _residual_add(h, m, rc, block_exit=True)
    return h, kv, aux


def _apply_mamba_block(bp, h, cfg, rc, *, state=None, return_state=False):
    x1 = _enter(rms_norm(h, bp["ln"], cfg.norm_eps), rc)
    y, new_state = ssm_lib.apply_mamba(
        bp["mamba"], x1, cfg, rc, state=state, return_state=return_state)
    return _residual_add(h, y, rc, block_exit=True), new_state


def _apply_cross_block(bp, h, cfg, rc, img_embeds, *, cache=None):
    a, kv = attn_lib.apply_attention(
        bp["attn"], rms_norm(h, bp["ln"], cfg.norm_eps), cfg, rc, None,
        kv_x=img_embeds, causal=False, cache=cache, return_kv=True,
        is_cross=True)
    h = h + jnp.tanh(bp["gate"]).astype(h.dtype) * a
    return h, kv


def _maybe_remat(fn, rc: RunConfig):
    if not rc.remat:
        return fn
    if rc.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params, cfg, rc: RunConfig, *, tokens=None, embeds=None,
            img_embeds=None, return_cache: bool = False,
            last_only: bool = False):
    """Full-sequence forward.

    Returns (logits, aux_loss, cache) — cache is None unless
    ``return_cache`` (prefill), and is a dict matching init_cache's
    structure with pos = S. ``last_only`` emits logits for the final
    position only (what serving prefill actually needs — skips the
    (B,S,V) logits tensor entirely).
    """
    params = _cast_params(params, rc)
    if embeds is not None:
        h = embeds.astype(rc.cdtype)
        B, S = h.shape[:2]
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
        B, S = tokens.shape
    if cfg.scale_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, rc.cdtype)
    h = rc.constrain(h, ("dp", None, None))
    positions = jnp.arange(S)[None, :]

    aux_total = jnp.zeros((), jnp.float32)
    cache: Optional[Dict[str, Any]] = {} if return_cache else None

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if cfg.family == "vlm" and img_embeds is not None:
            h, cache, aux_total = _vlm_forward(params, cfg, rc, h, positions,
                                               img_embeds, return_cache)
        else:
            def body(carry, bp):
                hh, aux = carry
                hh, kv, a = _apply_attn_block(bp, hh, cfg, rc, positions,
                                              return_kv=return_cache)
                return (hh, aux + a), kv
            body = _maybe_remat(body, rc)
            (h, aux_total), kvs = jax.lax.scan(body, (h, aux_total), params["blocks"])
            if return_cache:
                cache = {"k": kvs[0], "v": kvs[1]}
    elif cfg.family == "ssm":
        def body(carry, bp):
            hh, aux = carry
            hh, st = _apply_mamba_block(bp, hh, cfg, rc, return_state=return_cache)
            return (hh, aux), st
        body = _maybe_remat(body, rc)
        (h, aux_total), states = jax.lax.scan(body, (h, aux_total), params["blocks"])
        if return_cache:
            cache = {"ssm": states}
    elif cfg.family == "hybrid":
        h, cache, aux_total = _hybrid_forward(params, cfg, rc, h, positions,
                                              return_cache)
    else:
        raise ValueError(cfg.family)

    if last_only:
        h = h[:, -1:, :]
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,vd->bsv", h, head)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    logits = rc.constrain(logits, ("dp", None, "tp"))
    if return_cache and cache is not None:
        cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, aux_total, cache


def _segments(n_layers: int, every: int):
    """[(a, b, apply_special_after), ...] covering n_layers in chunks."""
    segs = []
    a = 0
    while a < n_layers:
        b = min(a + every, n_layers)
        segs.append((a, b, b - a == every))
        a = b
    return segs


def _slice_stack(tree, a: int, b: int):
    return jax.tree.map(lambda p: p[a:b], tree)


def _hybrid_forward(params, cfg, rc, h, positions, return_cache):
    aux = jnp.zeros((), jnp.float32)
    cache = {"ssm": [], "k": [], "v": []} if return_cache else None

    def body(carry, bp):
        hh = carry
        hh, st = _apply_mamba_block(bp, hh, cfg, rc, return_state=return_cache)
        return hh, st
    body = _maybe_remat(body, rc)

    for a, b, full in _segments(cfg.n_layers, cfg.attn_every):
        h, states = jax.lax.scan(body, h, _slice_stack(params["blocks"], a, b))
        if return_cache:
            cache["ssm"].append(states)
        if full:
            h, kv, a_ = _apply_attn_block(params["shared_block"], h, cfg, rc,
                                          positions, return_kv=return_cache)
            aux = aux + a_
            if return_cache:
                cache["k"].append(kv[0])
                cache["v"].append(kv[1])
    if return_cache:
        cache["ssm"] = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *cache["ssm"]) \
            if len(cache["ssm"]) > 1 else cache["ssm"][0]
        cache["k"] = jnp.stack(cache["k"], 0)
        cache["v"] = jnp.stack(cache["v"], 0)
    return h, cache, aux


def _vlm_forward(params, cfg, rc, h, positions, img_embeds, return_cache):
    aux = jnp.zeros((), jnp.float32)
    cache = {"k": [], "v": [], "xk": [], "xv": []} if return_cache else None
    img = img_embeds.astype(rc.cdtype)

    def body(carry, bp):
        hh = carry
        hh, kv, _ = _apply_attn_block(bp, hh, cfg, rc, positions,
                                      return_kv=return_cache)
        return hh, kv
    body = _maybe_remat(body, rc)

    n_cross = cfg.n_layers // cfg.cross_attn_every
    ci = 0
    for a, b, full in _segments(cfg.n_layers, cfg.cross_attn_every):
        h, kvs = jax.lax.scan(body, h, _slice_stack(params["blocks"], a, b))
        if return_cache:
            cache["k"].append(kvs[0])
            cache["v"].append(kvs[1])
        if full and ci < n_cross:
            cb = _slice_stack(params["cross_blocks"], ci, ci + 1)
            cb = jax.tree.map(lambda p: p[0], cb)
            h, xkv = _apply_cross_block(cb, h, cfg, rc, img)
            if return_cache:
                cache["xk"].append(xkv[0])
                cache["xv"].append(xkv[1])
            ci += 1
    if return_cache:
        cache["k"] = jnp.concatenate(cache["k"], 0)
        cache["v"] = jnp.concatenate(cache["v"], 0)
        cache["xk"] = jnp.stack(cache["xk"], 0)
        cache["xv"] = jnp.stack(cache["xv"], 0)
    return h, cache, aux


# ---------------------------------------------------------------------------
# Decode (single token against a cache)
# ---------------------------------------------------------------------------
def init_cache(cfg, rc: RunConfig, batch: int, max_len: int):
    """Zeroed decode cache. Matches the structure forward(return_cache=True)
    produces (modulo max_len sizing)."""
    K, hd, L = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_layers
    cdt = rc.cdtype
    if cfg.family in ("dense", "moe", "audio"):
        c = {"k": jnp.zeros((L, batch, max_len, K, hd), cdt),
             "v": jnp.zeros((L, batch, max_len, K, hd), cdt)}
    elif cfg.family == "vlm":
        n_cross = L // cfg.cross_attn_every
        c = {"k": jnp.zeros((L, batch, max_len, K, hd), cdt),
             "v": jnp.zeros((L, batch, max_len, K, hd), cdt),
             "xk": jnp.zeros((n_cross, batch, cfg.n_img_tokens, K, hd), cdt),
             "xv": jnp.zeros((n_cross, batch, cfg.n_img_tokens, K, hd), cdt)}
    elif cfg.family == "ssm":
        st = ssm_lib.init_ssm_state(cfg, batch, cdt)
        c = {"ssm": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), st)}
    elif cfg.family == "hybrid":
        st = ssm_lib.init_ssm_state(cfg, batch, cdt)
        n_apps = sum(1 for *_, f in _segments(L, cfg.attn_every) if f)
        c = {"ssm": jax.tree.map(
                 lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), st),
             "k": jnp.zeros((n_apps, batch, max_len, K, hd), cdt),
             "v": jnp.zeros((n_apps, batch, max_len, K, hd), cdt)}
    else:
        raise ValueError(cfg.family)
    c["pos"] = jnp.zeros((), jnp.int32)
    return c


def decode_step(params, cfg, rc: RunConfig, cache, tokens, *, embeds=None):
    """One decode step. tokens: (B, 1) int32 (or embeds (B,1,D) for audio).

    Returns (logits (B,1,Vp), new_cache)."""
    params = _cast_params(params, rc)
    index = cache["pos"]
    if embeds is not None:
        h = embeds.astype(rc.cdtype)
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, rc.cdtype)
    positions = jnp.broadcast_to(index[None, None], tokens.shape[:1] + (1,)) \
        if tokens is not None else jnp.full((h.shape[0], 1), index)

    new_cache = dict(cache)
    if cfg.family in ("dense", "moe", "audio"):
        def body(hh, xs):
            bp, kc, vc = xs
            hh, kv, _ = _apply_attn_block(bp, hh, cfg, rc, positions,
                                          cache=(kc, vc), cache_index=index)
            return hh, kv
        h, kvs = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = kvs
    elif cfg.family == "vlm":
        h, new_cache = _vlm_decode(params, cfg, rc, h, positions, cache, index)
    elif cfg.family == "ssm":
        def body(hh, xs):
            bp, st = xs
            hh, st2 = _apply_mamba_block(bp, hh, cfg, rc, state=ssm_lib.SSMState(*st))
            return hh, tuple(st2)
        h, states = jax.lax.scan(body, h, (params["blocks"], tuple(cache["ssm"])))
        new_cache["ssm"] = ssm_lib.SSMState(*states)
    elif cfg.family == "hybrid":
        h, new_cache = _hybrid_decode(params, cfg, rc, h, positions, cache, index)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,vd->bsv", h, head)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    new_cache["pos"] = index + 1
    return logits, new_cache


def _hybrid_decode(params, cfg, rc, h, positions, cache, index):
    new_cache = dict(cache)
    ssm_states = []
    ks, vs = [], []
    app = 0

    def body(hh, xs):
        bp, st = xs
        hh, st2 = _apply_mamba_block(bp, hh, cfg, rc, state=ssm_lib.SSMState(*st))
        return hh, tuple(st2)

    for a, b, full in _segments(cfg.n_layers, cfg.attn_every):
        seg_state = jax.tree.map(lambda p: p[a:b], tuple(cache["ssm"]))
        h, states = jax.lax.scan(body, h, (_slice_stack(params["blocks"], a, b), seg_state))
        ssm_states.append(states)
        if full:
            h, kv, _ = _apply_attn_block(
                params["shared_block"], h, cfg, rc, positions,
                cache=(cache["k"][app], cache["v"][app]), cache_index=index)
            ks.append(kv[0])
            vs.append(kv[1])
            app += 1
    new_cache["ssm"] = ssm_lib.SSMState(*jax.tree.map(
        lambda *xs: jnp.concatenate(xs, 0), *ssm_states))
    new_cache["k"] = jnp.stack(ks, 0)
    new_cache["v"] = jnp.stack(vs, 0)
    return h, new_cache


def _vlm_decode(params, cfg, rc, h, positions, cache, index):
    new_cache = dict(cache)
    ks, vs = [], []
    ci = 0
    n_cross = cfg.n_layers // cfg.cross_attn_every

    def body(hh, xs):
        bp, kc, vc = xs
        hh, kv, _ = _apply_attn_block(bp, hh, cfg, rc, positions,
                                      cache=(kc, vc), cache_index=index)
        return hh, kv

    for a, b, full in _segments(cfg.n_layers, cfg.cross_attn_every):
        h, kvs = jax.lax.scan(
            body, h, (_slice_stack(params["blocks"], a, b),
                      cache["k"][a:b], cache["v"][a:b]))
        ks.append(kvs[0])
        vs.append(kvs[1])
        if full and ci < n_cross:
            cb = jax.tree.map(lambda p: p[ci], params["cross_blocks"])
            h, _ = _apply_cross_block(cb, h, cfg, rc, None,
                                      cache=(cache["xk"][ci], cache["xv"][ci]))
            ci += 1
    new_cache["k"] = jnp.concatenate(ks, 0)
    new_cache["v"] = jnp.concatenate(vs, 0)
    return h, new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def lm_loss(logits, labels, cfg, aux=None, aux_weight: float = 0.01):
    ce = softmax_cross_entropy(logits, labels, cfg.vocab_size).mean()
    if aux is not None:
        ce = ce + aux_weight * aux
    return ce
