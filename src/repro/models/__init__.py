from repro.models.layers import RunConfig
from repro.models.model_zoo import Model, build

__all__ = ["RunConfig", "Model", "build"]
