"""Shared neural-net layers (pure functions over param pytrees)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Run-time configuration threaded through model code.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RunConfig:
    """How to *run* a model (orthogonal to ArchConfig = what the model is)."""

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = False                # activation checkpointing over blocks
    remat_policy: str = "none"        # none | dots | everything
    attn_chunk: int = 0                # >0: online-softmax chunked attention block
    attn_dense_max: int = 8192         # use dense attention up to this seq_len
    attn_shard: str = "heads"          # 'heads' | 'seq' (q-sequence TP when
                                       #  n_heads doesn't divide the TP axis)
    attn_exit_constrain: bool = False  # constrain h after the attention
                                       # residual too (helps llama4-MoE,
                                       # hurts qwen2-moe — per-arch knob)
    seq_shard_carry: bool = False      # Megatron-SP: shard the residual
                                       # stream (B,S,D) over 'tp' between
                                       # blocks — 16x smaller layer-scan
                                       # stash at the cost of AG/RS pairs
    moe_group: int = 2048              # MoE dispatch group size (tokens)
    ssd_chunk: int = 0                 # SSD chunk override (0 = ArchConfig's)
    use_pallas: bool = False           # TPU kernels (interpret-validated on CPU)
    # logical-axis -> PartitionSpec constrain hook, injected by the runtime.
    # Signature: constrain(x, logical_axes: tuple) -> x.  Default: identity.
    constrain: Callable = field(default=lambda x, axes: x)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in initializer (what most LMs ship with)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-5):
    """RMSNorm in f32, cast back to input dtype; scale is (1 + g)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def linear(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


def swiglu(x, w1, w3, w2):
    """SwiGLU MLP: w2( silu(x w1) * (x w3) )."""
    return linear(jax.nn.silu(linear(x, w1)) * linear(x, w3), w2)


def geglu(x, w1, w3, w2):
    """GeGLU MLP (gemma): w2( gelu(x w1) * (x w3) )."""
    return linear(jax.nn.gelu(linear(x, w1), approximate=True) * linear(x, w3), w2)


def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "w3": dense_init(k2, (d_model, d_ff), dtype),
        "w2": dense_init(k3, (d_ff, d_model), dtype),
    }


def apply_mlp(params, x, gelu: bool = False):
    fn = geglu if gelu else swiglu
    return fn(x, params["w1"], params["w3"], params["w2"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)          # (head_dim//2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits, labels, vocab_size: int):
    """CE in f32 with padded-vocab masking. logits: (..., Vp), labels ints."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:  # mask padded vocab slots out of the softmax
        pad_mask = (jnp.arange(vp) >= vocab_size)
        logits = jnp.where(pad_mask, -1e9, logits)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold
