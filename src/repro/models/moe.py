"""Mixture-of-Experts layer: routed top-k experts + shared experts.

GShard-style dense dispatch/combine: token-choice top-k routing with a
per-group expert capacity; dispatch and combine are one-hot einsums so
the layer lowers to plain dot_generals + the collectives XLA SPMD picks
for the (tokens: data-sharded) x (experts: model-sharded) contraction.
This compiles robustly on every mesh (the design baseline); a ragged
all-to-all variant is an explicitly-recorded §Perf hillclimb item.

Experts are padded to a multiple of 16 (``cfg.n_experts_padded``) so EP
shards evenly; pad experts receive -inf router logits and zero capacity
use.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import RunConfig, dense_init, init_mlp, apply_mlp


def init_moe(key, cfg, dtype):
    d, f, Ep = cfg.d_model, cfg.expert_d_ff, cfg.n_experts_padded
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, Ep), jnp.float32),
        "w1": dense_init(ks[1], (Ep, d, f), dtype),
        "w3": dense_init(ks[2], (Ep, d, f), dtype),
        "w2": dense_init(ks[3], (Ep, f, d), dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = init_mlp(ks[4], d, cfg.shared_expert_d_ff, dtype)
    return p


def _capacity(cfg, group: int) -> int:
    c = int(cfg.top_k * group / cfg.n_experts * cfg.capacity_factor)
    return max(4, (c + 3) // 4 * 4)


def route(logits_f32, cfg, group: int):
    """Top-k routing with capacity. logits: (G, S, Ep) f32.

    Returns (dispatch (G,S,E,C) bf16, combine (G,S,E,C) f32-weights,
    aux_loss scalar).
    """
    E, Ep, k = cfg.n_experts, cfg.n_experts_padded, cfg.top_k
    C = _capacity(cfg, group)
    if Ep > E:  # padded experts never routable
        pad = jnp.arange(Ep) >= E
        logits_f32 = jnp.where(pad, -1e9, logits_f32)
    probs = jax.nn.softmax(logits_f32, axis=-1)                  # (G,S,Ep)
    gate_vals, idx = jax.lax.top_k(probs, k)                     # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    G, S, _ = probs.shape
    dispatch = jnp.zeros((G, S, Ep, C), jnp.bfloat16)
    combine = jnp.zeros((G, S, Ep, C), jnp.float32)
    counts = jnp.zeros((G, Ep), jnp.int32)
    for slot in range(k):                                        # k <= 4, unrolled
        oh = jax.nn.one_hot(idx[:, :, slot], Ep, dtype=jnp.int32)    # (G,S,Ep)
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]       # rank in queue
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, 0), C, dtype=jnp.float32)
        sel = (keep.astype(jnp.float32))[..., None] * pos_oh         # (G,S,Ep,C)
        dispatch = dispatch + sel.astype(jnp.bfloat16)
        combine = combine + sel * gate_vals[:, :, slot, None, None]
        counts = counts + oh.sum(axis=1)

    # load-balancing aux loss (Switch-style), over real experts only
    me = probs[..., :E].mean(axis=(0, 1))
    assign = dispatch[..., :E, :].astype(jnp.float32).sum(-1).mean(axis=(0, 1))
    aux = E * jnp.sum(me * assign)
    return dispatch, combine, aux


def apply_moe(params, x, cfg, rc: RunConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (y, aux_loss)."""
    B, S, D = x.shape
    tokens = B * S
    group = min(rc.moe_group, tokens)
    G = tokens // group
    assert G * group == tokens, (tokens, group)
    xg = x.reshape(G, group, D)

    logits = jnp.einsum("gsd,de->gse", xg, params["router"].astype(rc.cdtype))
    dispatch, combine, aux = route(logits.astype(jnp.float32), cfg, group)

    # NOTE(§Perf, refuted): constraining xe/he to an expert-sharded layout
    # here ("dp","tp",None,None) doubled collective bytes on the 16x16
    # mesh — resharding the (G,E,C,D) tensors costs more than the
    # all-reduce XLA picks on its own. Left unconstrained deliberately.
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)              # (G,E,C,D)
    h1 = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w1"]))
    h3 = jnp.einsum("gecd,edf->gecf", xe, params["w3"])
    he = jnp.einsum("gecf,efd->gecd", h1 * h3, params["w2"])     # (G,E,C,D)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(he.dtype), he)

    if "shared" in params:
        y = y + apply_mlp(params["shared"], xg)
    return y.reshape(B, S, D), aux
