"""Mamba2 (SSD — state-space duality) blocks.

Implements the chunked SSD algorithm of arXiv:2405.21060 §6:
  * intra-chunk (quadratic-in-chunk "attention-like" term)
  * chunk boundary states + inter-chunk linear recurrence (lax.scan)
  * O(1)-state single-token decode

Projections are kept as separate tensors (x, z, B, C, dt) instead of one
fused in_proj so each shards cleanly on the TP axis (see
parallel/sharding.py). A depthwise causal conv (width 4) precedes x/B/C
exactly as in the reference implementation; with n_groups = 1, B and C
are shared across SSD heads.

The Pallas kernel (kernels/ssd_scan.py) mirrors ``ssd_chunked`` for TPU;
``kernels/ref.py`` re-exports it as the oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import RunConfig, dense_init, rms_norm


class SSMState(NamedTuple):
    """Decode-time recurrent state for one Mamba2 layer (stackable)."""

    ssd: jax.Array      # (B, H, P, N)
    conv_x: jax.Array   # (B, W-1, d_inner)
    conv_B: jax.Array   # (B, W-1, N)
    conv_C: jax.Array   # (B, W-1, N)


def init_mamba(key, cfg, dtype):
    d, di, N, H, W = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    # dt bias initialised so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[6], (H,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_x": dense_init(ks[0], (d, di), dtype),
        "in_z": dense_init(ks[1], (d, di), dtype),
        "in_B": dense_init(ks[2], (d, N), dtype),
        "in_C": dense_init(ks[3], (d, N), dtype),
        "in_dt": dense_init(ks[4], (d, H), dtype),
        "conv_x": (jax.random.normal(ks[5], (W, di), jnp.float32) * 0.1).astype(dtype),
        "conv_B": jnp.zeros((W, N), dtype) + 1.0 / W,
        "conv_C": jnp.zeros((W, N), dtype) + 1.0 / W,
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "out": dense_init(ks[7], (di, d), dtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def causal_conv(x, w, tail=None):
    """Depthwise causal conv. x:(B,S,C) w:(W,C) tail:(B,W-1,C) or None.

    Returns (y, new_tail). Implemented as W shifted adds (W is 4) — cheap,
    fusion-friendly, and SPMD-safe.
    """
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)          # (B, S+W-1, C)
    S = x.shape[1]
    y = sum(xp[:, i:i + S, :] * w[i] for i in range(W))
    new_tail = xp[:, -(W - 1):, :]
    return y, new_tail


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs per head; dt: (B,S,H) post-softplus step sizes;
    A: (H,) negative decay rates; Bm/Cm: (B,S,N) input/output maps.
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    f32 = jnp.float32

    dA = (dt.astype(f32) * A.astype(f32))                       # (B,S,H) log-decay
    dAc = dA.reshape(Bsz, nc, chunk, H)
    cum = jnp.cumsum(dAc, axis=2)                               # (B,nc,c,H)
    xc = xh.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    # ---- intra-chunk (diagonal blocks) -------------------------------
    # Processed in head blocks: the decay tensor (B,nc,c,c,hb) would be
    # tens of GB at hb=H (e.g. zamba2 train_4k hit 45GB/device) — the
    # Pallas kernel (kernels/ssd_scan.py) keeps it in VMEM instead.
    CB = jnp.einsum("bzin,bzjn->bzij", Cc, Bc)                  # (B,nc,c,c)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    CBm = jnp.where(tri[None, None], CB, 0.0)

    hb = min(4, H)   # (B,nc,c,c,hb) f32 is the peak intra-chunk tensor
    while H % hb:
        hb -= 1

    @jax.checkpoint  # recompute decay in bwd: keep ONE block live at a time
    def _diag_block(args):
        cum_b, dt_b, x_b = args                 # (B,nc,c,hb), ..., (B,nc,c,hb,P)
        decay = jnp.exp(cum_b[:, :, :, None, :] - cum_b[:, :, None, :, :])
        decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
        return jnp.einsum("bzij,bzijh,bzjh,bzjhp->bzihp",
                          CBm, decay, dt_b, x_b)

    cum_hb = cum.reshape(Bsz, nc, chunk, H // hb, hb).transpose(3, 0, 1, 2, 4)
    dt_hb = dtc.reshape(Bsz, nc, chunk, H // hb, hb).transpose(3, 0, 1, 2, 4)
    x_hb = xc.astype(f32).reshape(Bsz, nc, chunk, H // hb, hb, P).transpose(3, 0, 1, 2, 4, 5)
    y_hb = jax.lax.map(_diag_block, (cum_hb, dt_hb, x_hb))      # (H/hb,B,nc,c,hb,P)
    y_diag = y_hb.transpose(1, 2, 3, 0, 4, 5).reshape(Bsz, nc, chunk, H, P)

    # ---- chunk boundary states ---------------------------------------
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                      # decay from j to chunk end
    states = jnp.einsum("bzjn,bzjh,bzjhp->bzhpn", Bc, seg * dtc, xc.astype(f32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H)

    # ---- inter-chunk recurrence (the only sequential part) -----------
    s0 = jnp.zeros((Bsz, H, P, N), f32) if init_state is None else init_state.astype(f32)

    def step(s, inp):
        dec, st = inp                                           # (B,H), (B,H,P,N)
        s_prev = s
        s = dec[:, :, None, None] * s + st
        return s, s_prev

    final, s_prev = jax.lax.scan(
        step, s0, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)))
    s_prev = s_prev.swapaxes(0, 1)                              # (B,nc,H,P,N)

    # ---- inter-chunk contribution to outputs --------------------------
    y_off = jnp.einsum("bzin,bzih,bzhpn->bzihp",
                       Cc, jnp.exp(cum), s_prev)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(xh.dtype), final


def ssd_decode_step(state, x, dt, A, Bv, Cv):
    """One-token SSD update. x:(B,H,P) dt:(B,H) Bv/Cv:(B,N) state:(B,H,P,N)."""
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))                # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(f32), Bv.astype(f32), x.astype(f32))
    state = dA[:, :, None, None] * state + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(f32), state)
    return y.astype(x.dtype), state


def apply_mamba(params, x, cfg, rc: RunConfig, state: Optional[SSMState] = None,
                return_state: bool = False):
    """Mamba2 block body (no residual/norm — transformer.py owns those).

    x: (B,S,D). With ``state`` given and S==1 this is a decode step.
    Returns (y, new_state | None).
    """
    H, P, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    cdt = rc.cdtype

    xv = jnp.einsum("bsd,df->bsf", x, params["in_x"])
    zv = jnp.einsum("bsd,df->bsf", x, params["in_z"])
    Bv = jnp.einsum("bsd,dn->bsn", x, params["in_B"])
    Cv = jnp.einsum("bsd,dn->bsn", x, params["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["in_dt"])

    tails = (None, None, None) if state is None else (state.conv_x, state.conv_B, state.conv_C)
    xv, tx = causal_conv(xv, params["conv_x"], tails[0])
    Bv, tb = causal_conv(Bv, params["conv_B"], tails[1])
    Cv, tc = causal_conv(Cv, params["conv_C"], tails[2])
    xv = jax.nn.silu(xv)
    Bv = jax.nn.silu(Bv)
    Cv = jax.nn.silu(Cv)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    Bsz, S, _ = x.shape
    xh = xv.reshape(Bsz, S, H, P)

    new_state = None
    if state is not None and S == 1:
        y, ssd = ssd_decode_step(state.ssd, xh[:, 0], dt[:, 0], A, Bv[:, 0], Cv[:, 0])
        y = y[:, None]                                          # (B,1,H,P)
        new_state = SSMState(ssd, tx, tb, tc)
    else:
        init = state.ssd if state is not None else None
        chunk = min(rc.ssd_chunk or cfg.ssm_chunk, S)
        while S % chunk:
            chunk -= 1
        y, ssd = ssd_chunked(xh, dt, A, Bv, Cv, chunk, init_state=init)
        if return_state:
            new_state = SSMState(ssd, tx, tb, tc)

    # D skip, gate, norm, out-projection
    y = y + params["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, H * P).astype(cdt)
    y = y * jax.nn.silu(zv)
    y = rms_norm(y, params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, params["out"])
    return out, new_state


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> SSMState:
    H, P, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    W, di = cfg.ssm_conv_width, cfg.ssm_d_inner
    return SSMState(
        ssd=jnp.zeros((batch, H, P, N), jnp.float32),
        conv_x=jnp.zeros((batch, W - 1, di), dtype),
        conv_B=jnp.zeros((batch, W - 1, N), dtype),
        conv_C=jnp.zeros((batch, W - 1, N), dtype),
    )
