"""Public model API: ArchConfig -> init / loss / prefill / decode callables."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.layers import RunConfig


class Model:
    """Thin functional bundle for one architecture."""

    def __init__(self, cfg, rc: Optional[RunConfig] = None):
        self.cfg = cfg
        self.rc = rc or RunConfig()

    # -- parameters -----------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        return transformer.init_params(self.cfg, key, self.rc)

    def init_eval_shape(self):
        """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
        return jax.eval_shape(lambda: transformer.init_params(
            self.cfg, jax.random.PRNGKey(0), self.rc))

    # -- training -------------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        logits, aux, _ = self.apply(params, batch)
        return transformer.lm_loss(logits, batch["labels"], self.cfg, aux)

    def apply(self, params, batch, return_cache: bool = False,
              last_only: bool = False):
        cfg = self.cfg
        kw = dict(return_cache=return_cache, last_only=last_only)
        if cfg.frontend == "audio":
            return transformer.forward(params, cfg, self.rc,
                                       embeds=batch["embeds"], **kw)
        if cfg.frontend == "vision":
            return transformer.forward(params, cfg, self.rc,
                                       tokens=batch["tokens"],
                                       img_embeds=batch["img_embeds"], **kw)
        return transformer.forward(params, cfg, self.rc,
                                   tokens=batch["tokens"], **kw)

    # -- serving ----------------------------------------------------------
    def prefill(self, params, batch):
        logits, _, cache = self.apply(params, batch, return_cache=True,
                                      last_only=True)
        return logits, cache

    def decode(self, params, cache, batch):
        cfg = self.cfg
        if cfg.frontend == "audio":
            return transformer.decode_step(params, cfg, self.rc, cache,
                                           None, embeds=batch["embeds"])
        return transformer.decode_step(params, cfg, self.rc, cache,
                                       batch["tokens"])

    def init_cache(self, batch: int, max_len: int):
        return transformer.init_cache(self.cfg, self.rc, batch, max_len)

    def init_cache_eval_shape(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))


def build(cfg, rc: Optional[RunConfig] = None) -> Model:
    return Model(cfg, rc)
