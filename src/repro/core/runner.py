"""One-call experiment runner: engine x workflow x repeats -> metrics.

This is the harness every benchmark and test uses; it wires a fresh
Sim/Cluster/Informer/Event/Volume/Metrics stack, runs ``repeats``
back-to-back instances (the paper runs 100), and returns the collector.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Type

from repro.core import calibration as cal
from repro.core.baselines import ArgoLikeEngine, BatchJobEngine, DirectSubmitEngine
from repro.core.cluster import Cluster
from repro.core.dag import Workflow
from repro.core.engine import KubeAdaptorEngine
from repro.core.events import EventRegistry
from repro.core.informer import InformerSet
from repro.core.injector import WorkflowInjector
from repro.core.metrics import MetricsCollector
from repro.core.sim import Sim
from repro.core.volumes import VolumeManager

ENGINES = {
    "kubeadaptor": KubeAdaptorEngine,
    "batchjob": BatchJobEngine,
    "argo": ArgoLikeEngine,
    "direct": DirectSubmitEngine,
}


@dataclass
class RunResult:
    metrics: MetricsCollector
    cluster: Cluster
    sim: Sim
    engine: object
    api_calls: int


def run_experiment(engine_name: str, workflow: Workflow, repeats: int = 1,
                   params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                   cluster_cfg: cal.PaperCluster = cal.DEFAULT_CLUSTER,
                   payload_mode: str = "virtual", seed: int = 0,
                   speculative: bool = False,
                   sample_resources: bool = True,
                   horizon_s: float = 500_000.0) -> RunResult:
    sim = Sim()
    cluster = Cluster(sim, params, cluster_cfg, payload_mode=payload_mode,
                      seed=seed)
    volumes = VolumeManager(sim, cluster, params)
    metrics = MetricsCollector(sim, cluster, params)

    if engine_name == "kubeadaptor":
        informers = InformerSet(sim, cluster, params)
        events = EventRegistry(sim)
        engine = KubeAdaptorEngine(sim, cluster, informers, events, volumes,
                                   metrics, params, speculative=speculative)
        injector = WorkflowInjector(sim, engine.submit)
        engine.on_workflow_done = injector.request_next
        injector.load([workflow.with_instance(i) for i in range(repeats)])
        if sample_resources:
            metrics.start_sampling()
        injector.start()
        injector.on_drained = metrics.stop_sampling
    else:
        cls = ENGINES[engine_name]
        engine = cls(sim, cluster, volumes, metrics, params)
        injector = WorkflowInjector(sim, engine.submit)
        engine.on_workflow_done = injector.request_next
        injector.load([workflow.with_instance(i) for i in range(repeats)])
        if sample_resources:
            metrics.start_sampling()
        injector.start()
        injector.on_drained = metrics.stop_sampling

    sim.run(until=horizon_s)
    if not sim.idle() and injector.queue:
        raise RuntimeError(f"{engine_name} did not finish within horizon")
    return RunResult(metrics=metrics, cluster=cluster, sim=sim, engine=engine,
                     api_calls=cluster.api_calls)
