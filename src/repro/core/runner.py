"""Experiment harness: the ControlPlane builder + one-call runner.

Architecture (multi-tenant control plane):

    ┌────────────────────────── ControlPlane ─────────────────────────┐
    │  Sim ── Cluster ── VolumeManager ── MetricsCollector            │
    │                                                                 │
    │  WorkflowGateway ──submit──▶ engine ──admission──▶ Arbiter      │
    │   streams:                   kubeadaptor | batchjob |           │
    │     tenant, arrival          argo | direct                      │
    │     (serial/concurrent/      (baselines skip the informer       │
    │      poisson), priority,      stack and the arbiter)            │
    │      fair-share weight                                          │
    └─────────────────────────────────────────────────────────────────┘

``ControlPlane`` composes sim/cluster/informers/events/volumes/metrics/
engine/gateway for any engine and exposes the tenancy knobs: call
``add_stream`` once per tenant workload (arrival mode, concurrency,
Poisson rate, priority, fair-share weight, hard quota caps, SLO
deadline), pick an admission policy (``fifo`` / ``priority`` /
``fair-share`` / ``drf`` / ``quota`` / ``preempt`` — see
repro.core.policy), then ``run``.

``run_experiment`` keeps the original one-workflow signature — it is a
ControlPlane with a single default-tenant serial stream, which is
exactly the paper's serialized injector experiment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core import calibration as cal
from repro.core.autoscaler import Autoscaler, AutoscalePolicy
from repro.core.baselines import ArgoLikeEngine, BatchJobEngine, DirectSubmitEngine
from repro.core.chaos import ChaosInjector, ChaosSchedule
from repro.core.cluster import Cluster
from repro.core.dag import Workflow
from repro.core.descheduler import Descheduler, DeschedulePolicy
from repro.core.engine import KubeAdaptorEngine
from repro.core.events import EventRegistry
from repro.core.gateway import BackpressurePolicy, DurableGateway
from repro.core.informer import InformerSet
from repro.core.injector import StreamSpec, WorkflowGateway
from repro.core.metrics import MetricsCollector
from repro.core.policy import POLICY_PRESETS
from repro.core.resources import AdmissionArbiter
from repro.core.schedulers import SCHEDULERS
from repro.core.sim import Sim
from repro.core.volumes import VolumeManager

ENGINES = {
    "kubeadaptor": KubeAdaptorEngine,
    "batchjob": BatchJobEngine,
    "argo": ArgoLikeEngine,
    "direct": DirectSubmitEngine,
}


@dataclass
class RunResult:
    metrics: MetricsCollector
    cluster: Cluster
    sim: Sim
    engine: object
    api_calls: int
    gateway: Optional[WorkflowGateway] = None
    arbiter: Optional[AdmissionArbiter] = None
    gate: Optional[DurableGateway] = None
    chaos: Optional[ChaosInjector] = None
    descheduler: Optional[Descheduler] = None
    autoscaler: Optional[Autoscaler] = None


class ControlPlane:
    """Builder/composer for one experiment stack of any engine."""

    def __init__(self, engine_name: str = "kubeadaptor",
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 cluster_cfg: cal.PaperCluster = cal.DEFAULT_CLUSTER,
                 payload_mode: str = "virtual", seed: int = 0,
                 speculative: bool = False,
                 scheduler: str = "topological",
                 admission_policy: str = "fifo",
                 sample_resources: bool = True,
                 sample_mode: str = "full",
                 usage_mode: str = "sampled",
                 retain_pod_log: bool = True,
                 lifecycle: Optional[str] = None,
                 queue: Optional[str] = None,
                 fold_completed: bool = False,
                 capture_trace: bool = True,
                 chaos: Optional[ChaosSchedule] = None,
                 placement: str = "first-fit",
                 deschedule: Optional[DeschedulePolicy] = None,
                 autoscale: Optional[AutoscalePolicy] = None,
                 gateway: Optional[BackpressurePolicy] = None,
                 wal_path: Optional[str] = None,
                 shard_index: int = 0):
        if engine_name not in ENGINES:
            raise ValueError(f"unknown engine {engine_name!r}; "
                             f"expected one of {sorted(ENGINES)}")
        if admission_policy not in POLICY_PRESETS:
            raise ValueError(f"unknown admission policy {admission_policy!r}; "
                             f"expected one of {sorted(POLICY_PRESETS)}")
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; "
                             f"expected one of {sorted(SCHEDULERS)}")
        self.engine_name = engine_name
        self.params = params
        self.sample_resources = sample_resources
        self.sim = Sim(queue=queue)
        self.cluster = Cluster(self.sim, params, cluster_cfg,
                               payload_mode=payload_mode, seed=seed,
                               retain_pod_log=retain_pod_log,
                               lifecycle=lifecycle, placement=placement)
        self.volumes = VolumeManager(self.sim, self.cluster, params)
        self.metrics = MetricsCollector(self.sim, self.cluster, params,
                                        sample_mode=sample_mode,
                                        usage_mode=usage_mode,
                                        fold_completed=fold_completed)
        self.arbiter: Optional[AdmissionArbiter] = None
        # seeded fault injection (ISSUE 7): chaos=None performs zero
        # draws — bit-identical to a chaos-free build
        self.chaos: Optional[ChaosInjector] = None
        if chaos is not None:
            self.chaos = ChaosInjector(self.sim, self.cluster, chaos)
        # periodic evict-to-rebalance daemon (ISSUE 8): None arms
        # nothing — zero events, bit-identical to a descheduler-free run
        self.descheduler: Optional[Descheduler] = None
        if deschedule is not None:
            self.descheduler = Descheduler(self.sim, self.cluster,
                                           deschedule)

        if engine_name == "kubeadaptor":
            self.informers = InformerSet(self.sim, self.cluster, params)
            self.events = EventRegistry(self.sim,
                                        batched=self.cluster.lifecycle == "fast")
            self.arbiter = AdmissionArbiter(
                self.informers, policy=admission_policy,
                on_defer=self.metrics.note_admission_deferred,
                on_quota_reject=self.metrics.note_quota_reject,
                evict=self.cluster.evict_pod,
                preempt_cooldown_s=params.preempt_cooldown_s)
            self.engine = KubeAdaptorEngine(
                self.sim, self.cluster, self.informers, self.events,
                self.volumes, self.metrics, params,
                scheduler_cls=SCHEDULERS[scheduler],
                speculative=speculative, arbiter=self.arbiter)
        else:
            self.informers = None
            self.events = None
            self.engine = ENGINES[engine_name](
                self.sim, self.cluster, self.volumes, self.metrics, params)

        # durable submission front door (ISSUE 10): gateway=None is
        # exactly the old wiring — zero events, zero draws, bit-identical
        if wal_path is not None and gateway is None:
            raise ValueError("wal_path requires a gateway policy")
        self.gate: Optional[DurableGateway] = None
        send = self.engine.submit
        if gateway is not None:
            self.gate = DurableGateway(self.sim, self.engine.submit, gateway,
                                       seed=seed, shard=shard_index,
                                       wal_path=wal_path, chaos=self.chaos,
                                       arbiter=self.arbiter,
                                       metrics=self.metrics)
            self.metrics.gateway_active = True
            send = self.gate.offer
        self.gateway = WorkflowGateway(self.sim, send, seed=seed,
                                       capture_trace=capture_trace)
        if self.gate is not None:
            self.gate.inner = self.gateway
            self.engine.on_workflow_done = self.gate.workflow_done
        else:
            self.engine.on_workflow_done = self.gateway.workflow_done

        # elastic node pools (ISSUE 9): None arms nothing — zero events,
        # zero draws, the full roster stays provisioned (bit-identical).
        # Built last so its depth signal can read the arbiter's queue.
        self.autoscaler: Optional[Autoscaler] = None
        if autoscale is not None:
            arbiter = self.arbiter
            pending_fn = ((lambda: len(arbiter.pending))
                          if arbiter is not None else None)
            self.autoscaler = Autoscaler(self.sim, self.cluster, autoscale,
                                         cluster_cfg=cluster_cfg,
                                         pending_fn=pending_fn)

    # -- tenancy knobs -------------------------------------------------------
    def add_stream(self, workflow: Workflow, repeats: int = 1,
                   tenant: str = "default", arrival: str = "serial",
                   concurrency: int = 1, rate: float = 1.0, burst: int = 1,
                   priority: int = 0, weight: float = 1.0,
                   quota_cpu_m: int = 0, quota_mem_mi: int = 0,
                   deadline_s: float = 0.0) -> StreamSpec:
        """Register one tenant workload.  ``quota_cpu_m``/``quota_mem_mi``
        are hard admission caps (0 = uncapped) enforced by the pipeline's
        Filter stage; ``deadline_s`` is the tenant's SLO — a completed
        workflow *hits* when submission->teardown stays within it
        (tracked per tenant by MetricsCollector, 0 = no SLO)."""
        spec = StreamSpec(workflow=workflow, repeats=repeats, tenant=tenant,
                          arrival=arrival, concurrency=concurrency, rate=rate,
                          burst=burst, priority=priority, weight=weight,
                          quota_cpu_m=quota_cpu_m, quota_mem_mi=quota_mem_mi,
                          deadline_s=deadline_s)
        if self.arbiter is not None:
            self.arbiter.set_tenant(tenant, priority=priority, weight=weight,
                                    quota_cpu_m=quota_cpu_m,
                                    quota_mem_mi=quota_mem_mi)
        if deadline_s > 0:
            self.metrics.set_tenant_deadline(tenant, deadline_s)
        return self.gateway.add_stream(spec)

    def add_trace(self, records, tenants: Optional[dict] = None, make=None):
        """Replay an arrival trace (see ``WorkflowGateway.load_trace``).

        ``records``: iterable of ``{"t", "tenant", "topology"}`` dicts.
        ``tenants``: optional ``{name: {"priority", "weight"}}`` map
        registered on the arbiter. ``make``: ``topology -> Workflow``
        factory; defaults to the paper topologies in configs/workflows.
        """
        if make is None:
            from repro.configs.workflows import get_workflow_spec
            from repro.core.dag import make_workflow
            cache: dict = {}

            def make(topo):
                wfb = cache.get(topo)
                if wfb is None:
                    wfb = cache[topo] = make_workflow(
                        topo, get_workflow_spec(topo))
                return wfb

        if tenants:
            for name, share in tenants.items():
                if self.arbiter is not None:
                    self.arbiter.set_tenant(
                        name, priority=int(share.get("priority", 0)),
                        weight=float(share.get("weight", 1.0)),
                        quota_cpu_m=int(share.get("quota_cpu_m", 0)),
                        quota_mem_mi=int(share.get("quota_mem_mi", 0)))
                if float(share.get("deadline_s", 0.0)) > 0:
                    self.metrics.set_tenant_deadline(
                        name, float(share["deadline_s"]))
        return self.gateway.load_trace(records, make)

    def record_trace(self, path: Optional[str] = None):
        """Capture the realized arrival trace; emits ``arrival_trace/v2``
        (with gateway rejection/retry/shed events) when the durable
        gateway is armed, ``v1`` otherwise."""
        return self.gateway.record_trace(path, gate=self.gate)

    # -- execution -----------------------------------------------------------
    def run(self, horizon_s: float = 500_000.0) -> RunResult:
        if self.sample_resources:
            self.metrics.start_sampling()
            self.gateway.on_drained = self.metrics.stop_sampling
        self.gateway.start()
        self.sim.run(until=horizon_s)
        if not self.sim.idle() and self.gateway.pending():
            raise RuntimeError(
                f"{self.engine_name} did not finish within horizon "
                f"({self.gateway.queued()} workflows queued, "
                f"{self.gateway.pending() - self.gateway.queued()} in flight)")
        return RunResult(metrics=self.metrics, cluster=self.cluster,
                         sim=self.sim, engine=self.engine,
                         api_calls=self.cluster.api_calls,
                         gateway=self.gateway, arbiter=self.arbiter,
                         gate=self.gate, chaos=self.chaos,
                         descheduler=self.descheduler,
                         autoscaler=self.autoscaler)


def run_experiment(engine_name: str, workflow: Workflow, repeats: int = 1,
                   params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                   cluster_cfg: cal.PaperCluster = cal.DEFAULT_CLUSTER,
                   payload_mode: str = "virtual", seed: int = 0,
                   speculative: bool = False,
                   sample_resources: bool = True,
                   horizon_s: float = 500_000.0) -> RunResult:
    """The paper's experiment: serial injection of ``repeats`` instances."""
    plane = ControlPlane(engine_name, params=params, cluster_cfg=cluster_cfg,
                         payload_mode=payload_mode, seed=seed,
                         speculative=speculative,
                         sample_resources=sample_resources)
    plane.gateway.load([workflow.with_instance(i) for i in range(repeats)])
    return plane.run(horizon_s=horizon_s)
