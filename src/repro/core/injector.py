"""Workflow injection module (§4.4) — the gRPC-fed side-car.

Components map to the paper's module: the Workflow Parser reads
ConfigMap JSON (configs/workflows.py), the Workflow Sending Module
pushes one workflow at a time over the in-process "gRPC" channel
(a small fixed latency), and the Next Workflow Trigger Module responds
to the engine's completion events by sending the next instance.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.dag import Workflow, make_workflow
from repro.core.sim import Sim

GRPC_LATENCY = 0.02


class WorkflowInjector:
    def __init__(self, sim: Sim, send_to: Callable[[Workflow], None],
                 grpc_latency: float = GRPC_LATENCY):
        self.sim = sim
        self.send_to = send_to
        self.grpc_latency = grpc_latency
        self.queue: List[Workflow] = []
        self.sent = 0
        self.on_drained: Optional[Callable[[], None]] = None

    # -- workflow parser -------------------------------------------------
    def load_configmap(self, name: str, data, repeats: int = 1):
        base = make_workflow(name, data)
        for i in range(repeats):
            self.queue.append(base.with_instance(i))

    def load(self, workflows: List[Workflow]):
        self.queue.extend(workflows)

    # -- sending module ----------------------------------------------------
    def start(self):
        self._send_next()

    def _send_next(self):
        if not self.queue:
            if self.on_drained:
                self.on_drained()
            return
        wf = self.queue.pop(0)
        self.sent += 1
        self.sim.after(self.grpc_latency, lambda: self.send_to(wf))

    # -- next-workflow trigger ----------------------------------------------
    def request_next(self, _wf: Optional[Workflow] = None):
        self._send_next()
