"""Workflow injection (§4.4) — from serial side-car to multi-tenant gateway.

The paper's injector maps to three sub-modules: the Workflow Parser
reads ConfigMap JSON (configs/workflows.py), the Workflow Sending
Module pushes workflows over the in-process "gRPC" channel (a small
fixed latency), and the Next Workflow Trigger Module responds to the
engine's completion events by sending the next instance.

Two front-ends share that machinery:

* ``WorkflowInjector`` — the paper's strictly-serial injector, kept
  verbatim for the single-stream reproduction experiments.
* ``WorkflowGateway`` — the multi-tenant generalization: N concurrent
  *streams* (one queue per tenant workload), each with a pluggable
  arrival process:

    serial      next-trigger, exactly the paper's behaviour
    concurrent  keep ``concurrency`` instances of the stream in flight
    poisson     seeded exponential inter-arrival times at ``rate``/s,
                ``burst`` instances per arrival, independent of
                completions (open-loop traffic)
    trace       exact replay of recorded ``(t, tenant, topology)``
                arrival records (``load_trace``) — open-loop like
                poisson, but driven by a real cluster log instead of a
                synthetic process

  Streams are drained from ``collections.deque`` (O(1) pops); the
  gateway allocates globally unique instance ids per workflow name so
  namespaces and metric keys never collide across tenants.

  The gateway also *captures*: every dispatch (any arrival mode) is
  logged at its pre-gRPC instant and ``record_trace()`` emits the run
  as an ``arrival_trace/v1`` document, so a live run's arrivals can be
  replayed exactly via ``load_trace`` — closing the ROADMAP's
  capture/replay loop.
"""
from __future__ import annotations

import random
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

from repro.core.dag import Workflow, make_workflow
from repro.core.sim import Sim

GRPC_LATENCY = 0.02

ARRIVAL_MODES = ("serial", "concurrent", "poisson", "trace")

# v1: tenants + arrivals.  v2 adds a "gateway" section (the durable
# gate's policy echo plus its reject/retry/shed decision log) so a
# --trace replay is exact under backpressure.  load_trace only reads
# "arrivals", so v1 and v2 files both replay.
TRACE_SCHEMAS = ("arrival_trace/v1", "arrival_trace/v2")


class WorkflowInjector:
    """The paper's serial injector: one workflow in flight at a time."""

    def __init__(self, sim: Sim, send_to: Callable[[Workflow], None],
                 grpc_latency: float = GRPC_LATENCY):
        self.sim = sim
        self.send_to = send_to
        self.grpc_latency = grpc_latency
        self.queue: Deque[Workflow] = deque()
        self.sent = 0
        self.on_drained: Optional[Callable[[], None]] = None

    # -- workflow parser -------------------------------------------------
    def load_configmap(self, name: str, data, repeats: int = 1):
        base = make_workflow(name, data)
        for i in range(repeats):
            self.queue.append(base.with_instance(i))

    def load(self, workflows: List[Workflow]):
        self.queue.extend(workflows)

    # -- sending module ----------------------------------------------------
    def start(self):
        self._send_next()

    def _send_next(self):
        if not self.queue:
            if self.on_drained:
                self.on_drained()
            return
        wf = self.queue.popleft()
        self.sent += 1
        self.sim.after(self.grpc_latency, lambda: self.send_to(wf))

    # -- next-workflow trigger ----------------------------------------------
    def request_next(self, _wf: Optional[Workflow] = None):
        self._send_next()


@dataclass
class StreamSpec:
    """One tenant workload: a workflow repeated under an arrival process."""

    workflow: Workflow
    repeats: int = 1
    tenant: str = "default"
    arrival: str = "serial"        # serial | concurrent | poisson
    concurrency: int = 1           # in-flight cap for "concurrent"
    rate: float = 1.0              # arrivals per second for "poisson"
    burst: int = 1                 # instances per poisson arrival
    priority: int = 0              # admission priority (higher wins)
    weight: float = 1.0            # fair-share weight
    quota_cpu_m: int = 0           # hard admission cap (0 = uncapped)
    quota_mem_mi: int = 0
    deadline_s: float = 0.0        # per-workflow SLO deadline (0 = none)

    def __post_init__(self):
        if self.arrival not in ARRIVAL_MODES:
            raise ValueError(f"unknown arrival mode {self.arrival!r}; "
                             f"expected one of {ARRIVAL_MODES}")
        if self.arrival == "poisson" and self.rate <= 0:
            raise ValueError("poisson arrival needs rate > 0")
        if self.concurrency < 1 or self.burst < 1 or self.repeats < 0:
            raise ValueError("concurrency/burst must be >= 1, repeats >= 0")
        if self.quota_cpu_m < 0 or self.quota_mem_mi < 0 or self.deadline_s < 0:
            raise ValueError("quota caps / deadline must be >= 0")


class _Stream:
    def __init__(self, spec: StreamSpec, queue: Deque[Workflow]):
        self.spec = spec
        self.queue = queue
        self.in_flight = 0
        self.sent = 0

    def drained(self) -> bool:
        return not self.queue and self.in_flight == 0


class WorkflowGateway:
    """Multi-stream workflow source feeding one engine ``submit``.

    The engine's ``on_workflow_done`` must be wired to
    :meth:`workflow_done`; the gateway routes each completion back to
    the owning stream (closed-loop modes) and fires ``on_drained`` once
    every stream's queue is empty and nothing is in flight.
    """

    def __init__(self, sim: Sim, send_to: Callable[[Workflow], None],
                 grpc_latency: float = GRPC_LATENCY, seed: int = 0,
                 capture_trace: bool = True):
        self.sim = sim
        self.send_to = send_to
        self.grpc_latency = grpc_latency
        self.rng = random.Random(seed)
        self.streams: List[_Stream] = []
        self.sent = 0
        self.on_drained: Optional[Callable[[], None]] = None
        self._by_ns: Dict[str, _Stream] = {}
        self._instances: Dict[str, int] = {}     # workflow name -> next id
        self._started = False
        # every dispatch as (virtual t, tenant, topology) — the raw
        # material of record_trace (one small tuple per workflow).
        # capture_trace=False skips the log (record_trace unavailable):
        # at 1M workflows the tuples alone cost ~100 MB per shard.
        self.capture_trace = capture_trace
        self.trace_log: List[tuple] = []

    # -- stream registration ----------------------------------------------
    def add_stream(self, spec: StreamSpec) -> StreamSpec:
        base = spec.workflow
        if base.tenant != spec.tenant:
            base = base.with_tenant(spec.tenant)
        q: Deque[Workflow] = deque()
        for _ in range(spec.repeats):
            nxt = self._instances.get(base.name, 0)
            self._instances[base.name] = nxt + 1
            q.append(base.with_instance(nxt))
        stream = _Stream(spec, q)
        self.streams.append(stream)
        if self._started:
            self._kick(stream)
        return spec

    def load(self, workflows: List[Workflow], **spec_kw):
        """Convenience: one serial stream over an explicit instance list."""
        if not workflows:
            return
        spec = StreamSpec(workflow=workflows[0], repeats=0, **spec_kw)
        stream = _Stream(spec, deque(workflows))
        for wf in workflows:
            nxt = self._instances.get(wf.name, 0)
            self._instances[wf.name] = max(nxt, wf.instance + 1)
        self.streams.append(stream)
        if self._started:
            self._kick(stream)

    def load_trace(self, records, make: Callable[[str], Workflow]):
        """Replay an arrival trace exactly: each record —
        ``{"t": seconds, "tenant": name, "topology": key}`` — submits
        one instance of ``make(topology)`` (re-tenanted) at its
        recorded virtual time.  Ties keep file order.  Returns the
        trace stream (its queue holds ``(t, workflow)`` pairs)."""
        arrivals = sorted(
            ((float(rec["t"]), i, rec) for i, rec in enumerate(records)),
            key=lambda a: (a[0], a[1]))
        q: Deque = deque()
        for t, _i, rec in arrivals:
            if t < 0:
                raise ValueError(f"trace arrival at negative t={t}")
            base = make(rec["topology"])
            tenant = rec.get("tenant", "default")
            if base.tenant != tenant:
                base = base.with_tenant(tenant)
            nxt = self._instances.get(base.name, 0)
            self._instances[base.name] = nxt + 1
            q.append((t, base.with_instance(nxt)))
        first = q[0][1] if q else Workflow("trace-empty", {})
        stream = _Stream(StreamSpec(workflow=first, repeats=0,
                                    arrival="trace"), q)
        self.streams.append(stream)
        if self._started:
            self._kick(stream)
        return stream

    # -- sending module ----------------------------------------------------
    def start(self):
        if self._started:
            return
        self._started = True
        for stream in self.streams:
            self._kick(stream)
        if not self.streams:
            self._check_drained()

    def _kick(self, stream: _Stream):
        mode = stream.spec.arrival
        if mode == "serial":
            self._send_one(stream)
        elif mode == "concurrent":
            for _ in range(stream.spec.concurrency):
                self._send_one(stream)
        elif mode == "poisson":
            self._schedule_arrival(stream)
        elif mode == "trace":
            self._schedule_trace(stream)

    def _send_one(self, stream: _Stream):
        if not stream.queue:
            self._check_drained()
            return
        wf = stream.queue.popleft()
        stream.in_flight += 1
        stream.sent += 1
        self.sent += 1
        self._by_ns[wf.namespace()] = stream
        if self.capture_trace:
            self.trace_log.append((self.sim.now(), wf.tenant, wf.name))
        self.sim.after(self.grpc_latency, lambda: self.send_to(wf))

    def _schedule_arrival(self, stream: _Stream):
        if not stream.queue:
            return
        gap = self.rng.expovariate(stream.spec.rate)

        def arrive():
            for _ in range(stream.spec.burst):
                if stream.queue:
                    self._send_one(stream)
            self._schedule_arrival(stream)

        self.sim.after(gap, arrive)

    def _schedule_trace(self, stream: _Stream):
        if not stream.queue:
            self._check_drained()
            return
        due = stream.queue[0][0]

        def arrive():
            # every record due at this instant arrives in file order
            while stream.queue and stream.queue[0][0] <= self.sim.t:
                _t, wf = stream.queue.popleft()
                stream.in_flight += 1
                stream.sent += 1
                self.sent += 1
                self._by_ns[wf.namespace()] = stream
                if self.capture_trace:
                    self.trace_log.append(
                        (self.sim.now(), wf.tenant, wf.name))
                self.sim.after(self.grpc_latency,
                               lambda w=wf: self.send_to(w))
            self._schedule_trace(stream)

        self.sim.at(due, arrive, note="trace-arrival")

    # -- trace capture (arrival_trace/v1 + v2) ------------------------------
    def record_trace(self, path: Optional[str] = None, gate=None) -> dict:
        """Emit the run's dispatches as an ``arrival_trace/v1`` document
        (the exact format ``load_trace`` / ``ControlPlane.add_trace`` /
        ``bench_scale --trace`` replay).  Each dispatch is recorded at
        its pre-gRPC instant, so a replay reproduces every submission
        time exactly (round-trip pinned by tests/test_policy_pipeline).

        The ``topology`` key is the workflow's base name — a replay's
        ``make`` factory must resolve it (the default factory knows the
        paper topologies).  Tenant shares (priority / weight / quota
        caps / deadline) come from the registered stream specs.

        ``gate``: a ``DurableGateway`` — upgrades the document to
        ``arrival_trace/v2``, adding the gate's policy echo and its
        reject/retry/shed decision log (``gateway.events``) so a replay
        under the same policy reproduces every admission decision.
        Without a gate the schema stays ``v1`` byte-for-byte.
        """
        if not self.capture_trace and self.sent:
            raise RuntimeError("record_trace needs capture_trace=True — "
                               "this gateway was built with capture off")
        tenants: Dict[str, dict] = {}
        for stream in self.streams:
            spec = stream.spec
            share = {"priority": spec.priority, "weight": spec.weight}
            if spec.quota_cpu_m:
                share["quota_cpu_m"] = spec.quota_cpu_m
            if spec.quota_mem_mi:
                share["quota_mem_mi"] = spec.quota_mem_mi
            if spec.deadline_s:
                share["deadline_s"] = spec.deadline_s
            tenants[spec.tenant] = share
        doc = {
            "schema": "arrival_trace/v1",
            "tenants": tenants,
            "arrivals": [{"t": t, "tenant": tenant, "topology": topo}
                         for t, tenant, topo in self.trace_log],
        }
        if gate is not None:
            doc["schema"] = "arrival_trace/v2"
            doc["gateway"] = {"policy": gate.snapshot()["policy"],
                              "events": gate.trace_events()}
        if path is not None:
            import json
            with open(path, "w") as f:
                json.dump(doc, f, indent=2)
                f.write("\n")
        return doc

    # -- next-workflow trigger (completion routing) -------------------------
    def workflow_done(self, wf: Workflow):
        stream = self._by_ns.pop(wf.namespace(), None)
        if stream is None:
            self._check_drained()
            return
        stream.in_flight -= 1
        if stream.spec.arrival in ("serial", "concurrent"):
            self._send_one(stream)
        else:
            self._check_drained()

    # legacy alias so the gateway is a drop-in for WorkflowInjector
    request_next = workflow_done

    # -- drain bookkeeping ---------------------------------------------------
    def queued(self) -> int:
        return sum(len(s.queue) for s in self.streams)

    def pending(self) -> int:
        return self.queued() + sum(s.in_flight for s in self.streams)

    def _check_drained(self):
        if self.on_drained and all(s.drained() for s in self.streams):
            cb, self.on_drained = self.on_drained, None
            cb()
