"""Informer: list-watch local cache + listers + event handlers (§3.2).

The KubeAdaptor's central performance mechanism: instead of polling the
apiserver, each Informer subscribes to a watch stream once, mirrors the
objects into a local cache, and fires registered callbacks on state
changes. Listers read the cache at ZERO apiserver cost — compare
``Cluster.api_calls`` between KubeAdaptor and the polling baselines to
see the pressure difference the paper describes.

Scale-out fast path (ISSUE 2): the informer consumes the cluster's
*batched* watch stream (one sim event per kind per delivery instant)
and applies each batch in one cache-sync event; listers serve a
generation-cached list instead of copying the cache per call; handlers
dispatch from per-event-type callback lists built at registration time.
Every cache write is a snapshot, which lets the pod informer maintain
exact running aggregates — non-terminal requested cpu/mem, total and
per tenant — so admission's ``requested()`` is O(1) instead of a cache
scan.

Zero-copy views (ISSUE 5): snapshots are the cluster's
generation-stamped copy-on-write records (``_FastCopy.snapshot``) —
one materialized copy per actual state change, shared by the watch
event, the cache entry, the listers and resync.  A cache write whose
object is identical to the cached entry (the steady-state resync
case) is skipped outright: no generation bump, no lister
invalidation, no aggregate churn, no reservation-sync candidate.  The
skip is exact — an unchanged entry cannot change any aggregate, any
lister's contents, or any reservation's droppability.

Chaos-plane interaction (ISSUE 7): node kill/drain/restore emit node
MODIFIED watch events — the only producers of such events besides
``fail_node``/``restore_node`` — so the node cache, the
generation-cached node lister, and ``ResourceGatherer.allocatable()``
(keyed on ``nodes.generation``) all see cordons the same way they see
any other node change, and the engine's node-update handler re-wakes
admission when capacity returns.  Pods failed by a node loss arrive
as ordinary pod MODIFIED events (phase Failed, ``node_lost=True``),
so the non-terminal requested-resource aggregates shed the lost pods
with no special casing.  Normal runs emit zero node events, which is
why registering the node-update handler costs nothing in bit-identity.

Resync now *reconciles*: keys whose objects vanished from the listed
set without a DELETED watch event (a missed event) are dropped and
their ``on_delete`` handlers fired. A key must be stale for two
consecutive resyncs before it is dropped — one resync interval is far
longer than the watch+informer pipeline, so an in-flight DELETED event
can never race the reconciler and double-fire.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Set

from repro.core import calibration as cal
from repro.core.cluster import (ADDED, DELETED, PENDING, RUNNING, Cluster,
                                WatchEvent)
from repro.core.sim import Sim

_NON_TERMINAL = (PENDING, RUNNING)


def _key(kind: str, obj: Any) -> Any:
    if kind == "pod":
        return (obj.namespace, obj.name)
    if kind == "pvc":
        return (obj.namespace, obj.name)
    return obj.name


class Informer:
    """One resource kind's cache (podInformer / nodeInformer / ...)."""

    def __init__(self, sim: Sim, cluster: Cluster, kind: str,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS):
        self.sim = sim
        self.cluster = cluster
        self.kind = kind
        self.p = params
        self.cache: Dict[Any, Any] = {}
        self.events_seen = 0
        self.generation = 0                  # bumps on every cache write
        self._add_cbs: List[Callable] = []
        self._update_cbs: List[Callable] = []
        self._delete_cbs: List[Callable] = []
        self._lister_gen = -1
        self._lister_cache: Dict[Optional[str], List[Any]] = {}
        self._stale_once: Set[Any] = set()   # reconcile grace (see resync)
        # exact running aggregates over the pod cache (snapshots make
        # cache writes the only mutation point, so these always equal a
        # full scan — pinned by tests/test_scale_core.py)
        self._track_pods = kind == "pod"
        self.nonterminal_cpu = 0
        self.nonterminal_mem = 0
        self.nonterminal_cpu_by_tenant: Dict[str, int] = {}
        self.nonterminal_mem_by_tenant: Dict[str, int] = {}
        # keys written (set or pop) since the arbiter last reconciled
        # its reservation ledger — lets the sync touch only keys whose
        # droppability can have changed instead of scanning the ledger
        # (single consumer: policy.ReservationLedger.sync clears)
        self.touched: List[Any] = []
        self._list_fn = {
            "pod": cluster.list_pods,
            "node": cluster.list_nodes,
            "namespace": cluster.list_namespaces,
            "pvc": cluster.list_pvcs,
        }.get(kind, lambda: [])
        cluster.watch_batch(kind, self._on_watch_batch)
        self._initial_list()
        self._schedule_resync()

    # ---- cache writes (the only mutation points) ------------------------
    def _cache_set(self, k: Any, obj: Any):
        old = self.cache.get(k)
        if old is obj:
            return                 # unchanged shared view: nothing to do
        self.generation += 1
        if self._track_pods:
            self.touched.append(k)
            if old is not None and old.phase in _NON_TERMINAL:
                self._untrack(old)
            if obj.phase in _NON_TERMINAL:
                self._track(obj)
        self.cache[k] = obj

    def _cache_pop(self, k: Any) -> Optional[Any]:
        old = self.cache.pop(k, None)
        if old is not None:
            self.generation += 1
            if self._track_pods:
                self.touched.append(k)
                if old.phase in _NON_TERMINAL:
                    self._untrack(old)
        return old

    def _track(self, pod: Any):
        self.nonterminal_cpu += pod.cpu_m
        self.nonterminal_mem += pod.mem_mi
        t = pod.tenant
        by = self.nonterminal_cpu_by_tenant
        by[t] = by.get(t, 0) + pod.cpu_m
        by = self.nonterminal_mem_by_tenant
        by[t] = by.get(t, 0) + pod.mem_mi

    def _untrack(self, pod: Any):
        self.nonterminal_cpu -= pod.cpu_m
        self.nonterminal_mem -= pod.mem_mi
        t = pod.tenant
        self.nonterminal_cpu_by_tenant[t] -= pod.cpu_m
        self.nonterminal_mem_by_tenant[t] -= pod.mem_mi

    # ---- list-watch ------------------------------------------------------
    def _initial_list(self):
        for obj in self._list_fn():
            self._cache_set(_key(self.kind, obj), obj.snapshot())

    def _on_watch_batch(self, evs: List[WatchEvent]):
        # watch_latency already applied by the cluster; informer adds its
        # own processing/cache-sync latency before handlers observe it.
        self.sim.after(self.p.informer_latency, self._apply_batch,
                       note=f"informer:{self.kind}", args=(evs,))

    def _apply_batch(self, evs: List[WatchEvent]):
        """Apply one delivery batch: the fused loop is ``_apply`` per
        event with the cache write inlined (identical event order,
        callbacks and bookkeeping — the function hops were the 10k-tier
        informer profile)."""
        self.events_seen += len(evs)
        cache = self.cache
        track = self._track_pods
        touched = self.touched
        add_cbs, upd_cbs = self._add_cbs, self._update_cbs
        del_cbs = self._delete_cbs
        pod_kind = self.kind == "pod"
        for ev in evs:
            obj = ev.obj
            k = (obj.namespace, obj.name) if pod_kind else _key(self.kind, obj)
            type_ = ev.type
            if type_ == DELETED:
                old = cache.pop(k, None)
                if old is None:
                    continue     # already reconciled away — don't double-fire
                self.generation += 1
                if track:
                    touched.append(k)
                    if old.phase in _NON_TERMINAL:
                        self._untrack(old)
                cbs = del_cbs
            else:
                old = cache.get(k)
                if old is not obj:
                    self.generation += 1
                    if track:
                        touched.append(k)
                        old_live = (old is not None
                                    and old.phase in _NON_TERMINAL)
                        new_live = obj.phase in _NON_TERMINAL
                        # a live->live transition of one pod with
                        # unchanged requests/tenant (Pending->Running,
                        # every pod's hottest update) nets zero on
                        # every aggregate — skip the churn
                        if old_live and new_live \
                                and old.cpu_m == obj.cpu_m \
                                and old.mem_mi == obj.mem_mi \
                                and old.tenant == obj.tenant:
                            pass
                        else:
                            if old_live:
                                self._untrack(old)
                            if new_live:
                                self._track(obj)
                    cache[k] = obj
                cbs = add_cbs if type_ == ADDED else upd_cbs
            for cb in cbs:
                cb(obj)

    def _apply(self, ev: WatchEvent):
        """Single-event reference path (kept for tests/direct callers;
        the batch loop above is its inlined equivalent)."""
        self.events_seen += 1
        k = _key(self.kind, ev.obj)
        type_ = ev.type
        if type_ == DELETED:
            if self._cache_pop(k) is None:
                return       # already reconciled away — don't double-fire
            cbs = self._delete_cbs
        else:
            self._cache_set(k, ev.obj)
            cbs = self._add_cbs if type_ == ADDED else self._update_cbs
        for cb in cbs:
            cb(ev.obj)

    def _schedule_resync(self):
        def resync():
            self._resync_reconcile()      # self-sync §3.2 + stale-key GC
            self._schedule_resync()
        self.sim.after(self.p.resync_interval, resync, daemon=True,
                       note=f"resync:{self.kind}")

    def _resync_reconcile(self):
        listed: Set[Any] = set()
        for obj in self._list_fn():
            k = _key(self.kind, obj)
            listed.add(k)
            # zero-copy: an object unchanged since its last snapshot
            # resyncs to the identical shared view, which _cache_set
            # skips outright
            self._cache_set(k, obj.snapshot())
        stale = [k for k in self.cache if k not in listed]
        drop = [k for k in stale if k in self._stale_once]
        self._stale_once = set(stale).difference(drop)
        for k in drop:
            obj = self._cache_pop(k)
            for cb in self._delete_cbs:
                cb(obj)

    # ---- lister: local-cache reads, no apiserver cost -------------------
    def lister(self, namespace: Optional[str] = None) -> List[Any]:
        """Cached snapshot list, invalidated on cache mutation. Treat
        the returned list as read-only — it is shared between calls."""
        if self._lister_gen != self.generation:
            self._lister_cache.clear()
            self._lister_gen = self.generation
        objs = self._lister_cache.get(namespace)
        if objs is None:
            if namespace is not None and self.kind in ("pod", "pvc"):
                objs = [o for o in self.cache.values()
                        if o.namespace == namespace]
            else:
                objs = list(self.cache.values())
            self._lister_cache[namespace] = objs
        return objs

    def get(self, key) -> Optional[Any]:
        return self.cache.get(key)

    def add_handlers(self, on_add=None, on_update=None, on_delete=None):
        if on_add:
            self._add_cbs.append(on_add)
        if on_update:
            self._update_cbs.append(on_update)
        if on_delete:
            self._delete_cbs.append(on_delete)


class InformerSet:
    """The paper's podInformer + nodeInformer + namespaceInformer."""

    def __init__(self, sim: Sim, cluster: Cluster,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS):
        self.pods = Informer(sim, cluster, "pod", params)
        self.nodes = Informer(sim, cluster, "node", params)
        self.namespaces = Informer(sim, cluster, "namespace", params)
        self.pvcs = Informer(sim, cluster, "pvc", params)
