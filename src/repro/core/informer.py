"""Informer: list-watch local cache + listers + event handlers (§3.2).

The KubeAdaptor's central performance mechanism: instead of polling the
apiserver, each Informer subscribes to a watch stream once, mirrors the
objects into a local cache, and fires registered callbacks on state
changes. Listers read the cache at ZERO apiserver cost — compare
``Cluster.api_calls`` between KubeAdaptor and the polling baselines to
see the pressure difference the paper describes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core import calibration as cal
from repro.core.cluster import (ADDED, DELETED, MODIFIED, Cluster, WatchEvent)
from repro.core.sim import Sim


def _key(kind: str, obj: Any) -> Any:
    if kind == "pod":
        return (obj.namespace, obj.name)
    if kind == "pvc":
        return (obj.namespace, obj.name)
    return obj.name


@dataclass
class Handlers:
    on_add: Optional[Callable] = None
    on_update: Optional[Callable] = None
    on_delete: Optional[Callable] = None


class Informer:
    """One resource kind's cache (podInformer / nodeInformer / ...)."""

    def __init__(self, sim: Sim, cluster: Cluster, kind: str,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS):
        self.sim = sim
        self.cluster = cluster
        self.kind = kind
        self.p = params
        self.cache: Dict[Any, Any] = {}
        self.handlers: List[Handlers] = []
        self.events_seen = 0
        cluster.watch(kind, self._on_watch_event)
        self._initial_list()
        self._schedule_resync()

    def _initial_list(self):
        for obj in {"pod": self.cluster.list_pods,
                    "node": self.cluster.list_nodes,
                    "namespace": self.cluster.list_namespaces}.get(
                        self.kind, lambda: [])():
            self.cache[_key(self.kind, obj)] = obj

    def _on_watch_event(self, ev: WatchEvent):
        # watch_latency already applied by the cluster; informer adds its own
        # processing/cache-sync latency before handlers observe the change.
        self.sim.after(self.p.informer_latency, lambda: self._apply(ev))

    def _apply(self, ev: WatchEvent):
        self.events_seen += 1
        k = _key(self.kind, ev.obj)
        if ev.type == DELETED:
            self.cache.pop(k, None)
        else:
            self.cache[k] = ev.obj
        for h in self.handlers:
            cb = {ADDED: h.on_add, MODIFIED: h.on_update, DELETED: h.on_delete}[ev.type]
            if cb:
                cb(ev.obj)

    def _schedule_resync(self):
        def resync():
            self._initial_list()          # re-list into cache (self-sync §3.2)
            self._schedule_resync()
        self.sim.after(self.p.resync_interval, resync, daemon=True)

    # ---- lister: local-cache reads, no apiserver cost -------------------
    def lister(self, namespace: Optional[str] = None) -> List[Any]:
        objs = list(self.cache.values())
        if namespace is not None and self.kind in ("pod", "pvc"):
            objs = [o for o in objs if o.namespace == namespace]
        return objs

    def get(self, key) -> Optional[Any]:
        return self.cache.get(key)

    def add_handlers(self, on_add=None, on_update=None, on_delete=None):
        self.handlers.append(Handlers(on_add, on_update, on_delete))


class InformerSet:
    """The paper's podInformer + nodeInformer + namespaceInformer."""

    def __init__(self, sim: Sim, cluster: Cluster,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS):
        self.pods = Informer(sim, cluster, "pod", params)
        self.nodes = Informer(sim, cluster, "node", params)
        self.namespaces = Informer(sim, cluster, "namespace", params)
        self.pvcs = Informer(sim, cluster, "pvc", params)
