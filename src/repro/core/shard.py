"""Sharded multi-process control plane (ISSUE 6).

One event loop tops out around 10^5 workflows: PR 5's 100k tier runs a
single ``Sim`` at ~8k events/s and ~1.8 GiB RSS.  The 1M-workflow
target partitions the *control plane itself*: tenants are hashed onto
N arbiter shards, each shard owns a disjoint slice of the cluster's
nodes and runs a complete stack — ``Sim`` loop, informers, admission
arbiter, gateway — in a forked worker process.  Shards share nothing
at runtime; results return over the pool's result pipe as compact
picklable records (``MetricsPartial`` + scalar counters), and the
parent merges them into global summaries via the mergeable stats
layer (``core/stats``, ``core/metrics``).

Determinism:

* ``shard_of(tenant, workers) = crc32(tenant) % workers`` — a stable,
  documented hash (NOT Python's randomized ``hash``), so a tenant
  lands on the same shard in every process and on every run.
* ``shard_seed(root, i)`` spawns each shard's RNG seed from the root
  seed by sha256 — shards are decorrelated but fully reproducible,
  and no seed depends on wallclock, pid, or worker scheduling.
* ``processes=False`` runs the same per-shard function sequentially
  in-process; by construction it is bit-identical to the multi-process
  mode (pinned by tests/test_shard_plane.py), which makes the fork
  path testable without fork-sensitive asserts.

Failure recovery (ISSUE 7): the PR-6 fork path was a blocking
``Pool.map`` — a worker dying mid-shard (OOM kill, segfault, spot
reclaim of the parent's host) hung the parent forever.  Workers now
run as individual ``Process``es reporting over one-way pipes: a
heartbeat thread proves liveness, exceptions serialize back as
structured error messages, and the parent detects dead processes,
stale heartbeats and a global join timeout.  ``on_shard_failure``
picks the policy: ``"raise"`` surfaces a ``ShardFailure`` naming the
shard and its tenants; ``"restart"`` respawns the shard from its
recorded spec (same tenant partition, same spawned seed — the rerun
is deterministic, so the merged result is unchanged); ``"degrade"``
merges the surviving shards and flags the result ``degraded=True``
with the failure manifest.  Chaos schedules fan out with the same
spawning discipline: ``ChaosSchedule.spawn(i)`` derives each shard's
decorrelated chaos stream, and per-shard chaos counters merge by
summation (``ShardedRunResult.chaos_counters``).

Throughput accounting on a sharded run: shards execute in waves of
``shard_procs`` OS processes (default ``os.cpu_count()``), so each
event loop runs unoversubscribed.  The aggregate ``events_per_sec``
is Σ shard events / max(shard loop wall) — the standard weak-scaling
aggregate ("N unoversubscribed loops side by side"); per-shard rows
and the true end-to-end ``wall_s`` are always reported alongside so
the definition is transparent, and ``loop_cpu_s`` gives the
CPU-second basis.
"""
from __future__ import annotations

import hashlib
import os
import zlib
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

from repro.core import calibration as cal
from repro.core.chaos import ChaosSchedule
from repro.core.autoscaler import AutoscalePolicy
from repro.core.descheduler import DeschedulePolicy
from repro.core.gateway import BackpressurePolicy, merge_gateway_snapshots
from repro.core.metrics import MetricsPartial
from repro.core.runner import ControlPlane
from repro.core.stats import StreamingStat

__all__ = ["shard_of", "shard_seed", "partition_nodes", "ShardSpec",
           "ShardFailure", "ShardedControlPlane", "ShardedRunResult"]


class ShardFailure(RuntimeError):
    """A shard worker failed (died, raised, or timed out).  Structured:
    names the shard, the tenants stranded on it, and the reason — the
    base signal for the restart/degrade recovery modes."""

    def __init__(self, shard: int, tenants: List[str], reason: str):
        self.shard = shard
        self.tenants = list(tenants)
        self.reason = reason
        super().__init__(
            f"shard {shard} failed ({reason}); stranded tenants: "
            f"{', '.join(self.tenants) or '(none)'}")


def shard_of(tenant: str, workers: int) -> int:
    """Deterministic tenant -> shard index (stable across processes)."""
    if workers <= 1:
        return 0
    return zlib.crc32(tenant.encode("utf-8")) % workers


def shard_seed(root_seed: int, index: int) -> int:
    """Spawn shard ``index``'s seed from the root seed (sha256-based:
    decorrelated streams, no wallclock/pid dependence)."""
    digest = hashlib.sha256(
        f"repro-shard/{root_seed}/{index}".encode("ascii")).digest()
    return int.from_bytes(digest[:8], "big")


def partition_nodes(n_nodes: int, workers: int) -> List[int]:
    """Disjoint node-slice sizes per shard (first shards absorb the
    remainder; sums to ``n_nodes``)."""
    base, rem = divmod(n_nodes, workers)
    return [base + (1 if i < rem else 0) for i in range(workers)]


@dataclass
class ShardSpec:
    """Everything one worker process needs to build and run its shard
    (picklable: crosses the pool task pipe)."""
    index: int
    workers: int
    seed: int
    n_nodes: int
    engine_name: str = "kubeadaptor"
    params: cal.ClusterParams = None
    cluster_cfg: cal.PaperCluster = None      # template; n_nodes overrides
    payload_mode: str = "virtual"
    speculative: bool = False
    scheduler: str = "topological"
    admission_policy: str = "fifo"
    sample_resources: bool = True
    sample_mode: str = "full"
    usage_mode: str = "sampled"
    retain_pod_log: bool = True
    lifecycle: Optional[str] = None
    queue: Optional[str] = None
    fold_completed: bool = False
    capture_trace: bool = True
    streams: List[dict] = field(default_factory=list)
    trace_records: List[dict] = field(default_factory=list)
    trace_tenants: Dict[str, dict] = field(default_factory=dict)
    horizon_s: float = 500_000.0
    record_bindings: bool = False
    profile: bool = False
    chaos: Optional[ChaosSchedule] = None     # already spawned per shard
    placement: str = "first-fit"              # scatter-cycle node pick
    deschedule: Optional[DeschedulePolicy] = None  # per-shard daemon
    autoscale: Optional[AutoscalePolicy] = None    # already spawned per shard
    # durable submission front door (ISSUE 10): same frozen policy on
    # every shard (the gate stream seed decorrelates); wal_dir arms the
    # per-shard file sink ({wal_dir}/shard-{index}.wal) so a restarted
    # incarnation replays its own submission log with exactly-once dedup
    gateway: Optional[BackpressurePolicy] = None
    wal_dir: Optional[str] = None


def _spec_tenants(spec: ShardSpec) -> List[str]:
    """Tenants routed to this shard (for ShardFailure manifests)."""
    tenants = {s["tenant"] for s in spec.streams}
    tenants.update(r["tenant"] for r in spec.trace_records)
    tenants.update(spec.trace_tenants)
    return sorted(tenants)


def _build_shard_plane(spec: ShardSpec) -> ControlPlane:
    params = spec.params if spec.params is not None else cal.DEFAULT_PARAMS
    cfg = spec.cluster_cfg if spec.cluster_cfg is not None \
        else cal.DEFAULT_CLUSTER
    plane = ControlPlane(
        spec.engine_name, params=params,
        cluster_cfg=replace(cfg, n_nodes=spec.n_nodes),
        payload_mode=spec.payload_mode, seed=spec.seed,
        speculative=spec.speculative, scheduler=spec.scheduler,
        admission_policy=spec.admission_policy,
        sample_resources=spec.sample_resources,
        sample_mode=spec.sample_mode, usage_mode=spec.usage_mode,
        retain_pod_log=spec.retain_pod_log, lifecycle=spec.lifecycle,
        queue=spec.queue, fold_completed=spec.fold_completed,
        capture_trace=spec.capture_trace, chaos=spec.chaos,
        placement=spec.placement, deschedule=spec.deschedule,
        autoscale=spec.autoscale, gateway=spec.gateway,
        wal_path=(os.path.join(spec.wal_dir, f"shard-{spec.index}.wal")
                  if spec.wal_dir and spec.gateway is not None else None),
        shard_index=spec.index)
    for stream in spec.streams:
        plane.add_stream(**stream)
    if spec.trace_records:
        plane.add_trace(spec.trace_records, tenants=spec.trace_tenants)
    return plane


def _run_shard(spec: ShardSpec, die_at: Optional[float] = None) -> dict:
    """Build, run, and compact one shard.  Runs in a forked worker
    (``processes=True``) or inline (``processes=False``) — identical
    code path either way, so the two modes are bit-identical by
    construction for everything the sim computes.

    ``die_at`` (forked test hook, REPRO_SHARD_KILL=<i>@<t>): hard-exit
    at virtual time ``t`` — a mid-run SIGKILL that leaves a partially
    written WAL behind for the restarted incarnation to replay."""
    import resource as _resource
    import time as _time

    import repro.core.cluster as _cluster_mod

    plane = _build_shard_plane(spec)
    if die_at is not None:
        plane.sim.at(die_at, lambda: os._exit(42), daemon=True,
                     note="test:shard-kill")

    bindings: List[Tuple[str, str]] = []
    if spec.record_bindings:
        inner = plane.cluster._bind

        def recording_bind(pod, node):
            bindings.append((pod.tenant,
                             f"{pod.namespace}/{pod.name}->{node.name}"
                             f"@{plane.sim.now():.4f}"))
            return inner(pod, node)

        plane.cluster._bind = recording_bind

    copies0 = _cluster_mod.SNAPSHOTS_MADE
    profiler = None
    if spec.profile:
        import cProfile
        profiler = cProfile.Profile()
        profiler.enable()
    t0 = _time.perf_counter()
    res = plane.run(horizon_s=spec.horizon_s)
    wall = _time.perf_counter() - t0
    profile_text = None
    if profiler is not None:
        import io
        import pstats
        profiler.disable()
        buf = io.StringIO()
        pstats.Stats(profiler, stream=buf).sort_stats(
            "cumulative").print_stats(20)
        profile_text = buf.getvalue()

    partial = res.metrics.export_partial()
    record = {
        "shard": spec.index,
        "seed": spec.seed,
        "nodes": spec.n_nodes,
        "tenants": sorted(partial.tenant_aggs),
        "wall_s": wall,
        "loop_wall_s": res.sim.run_wall_s,
        "loop_cpu_s": getattr(res.sim, "run_cpu_s", 0.0),
        "last_event_t": res.sim.last_event_t,
        "events": res.sim.events_processed,
        "pods_created": getattr(res.cluster, "pods_created", 0),
        "api_calls": res.cluster.api_calls,
        "informer_copies": _cluster_mod.SNAPSHOTS_MADE - copies0,
        "peak_pending_pods": getattr(res.cluster, "max_pending_pods", 0),
        "queue": res.sim.queue_name,
        "usage_mode": res.metrics.usage_mode,
        "lifecycle": getattr(res.cluster, "lifecycle", "chained"),
        "completed_workflows": partial.completed,
        "failed_workflows": partial.failed,
        "arbiter": (res.arbiter.counters()
                    if res.arbiter is not None else {}),
        "chaos": (res.chaos.counters() if res.chaos is not None else None),
        # placement observables (ISSUE 8): per-shard hotspot profile
        # (merged exactly by ShardedRunResult.hotspot_summary) plus
        # descheduler accounting when the daemon was armed
        "node_hotspot": res.cluster.hotspot_summary(),
        "rebalances": getattr(res.cluster, "rebalances", 0),
        "descheduler": (res.descheduler.counters()
                        if res.descheduler is not None else None),
        # provisioned-capacity cost accounting (ISSUE 9): always
        # recorded (fixed rosters report flat provisioning); merged
        # exactly by ShardedRunResult.cost_summary
        "cost": res.cluster.cost_summary(),
        "autoscaler": (res.autoscaler.counters()
                       if res.autoscaler is not None else None),
        # durable front door (ISSUE 10): per-shard qstat snapshot
        # (merged exactly by ShardedRunResult.gateway_summary)
        "gateway": (res.gate.snapshot() if res.gate is not None else None),
        # per-process high-water mark: each worker process runs exactly
        # one shard, so this is the shard's own RSS
        "peak_rss_mib": _resource.getrusage(
            _resource.RUSAGE_SELF).ru_maxrss / 1024.0,
        "metrics_partial": partial,
        "exec_stat": getattr(res.cluster, "exec_stat", None),
        "profile": profile_text,
        "bindings": bindings if spec.record_bindings else None,
    }
    if res.gate is not None:
        res.gate.close()
    return record


def _shard_worker_main(spec: ShardSpec, conn, heartbeat_s: float,
                       die: bool = False) -> None:
    """Forked worker entrypoint: run one shard, stream liveness.

    A daemon thread sends ``("heartbeat", index)`` every
    ``heartbeat_s`` (the sim loop's pure-Python stretches yield the GIL
    every switch interval and the native scheduler releases it outright,
    so beats flow while the shard computes).  The shard's result or a
    serialized exception goes back over the same pipe — the parent
    never blocks on a silent worker again.  ``die`` is the test hook
    (REPRO_SHARD_KILL): ``True`` hard-exits before running (simulated
    SIGKILL at launch); a float hard-exits at that virtual time
    mid-run (the WAL-replay crash scenario).
    """
    import threading
    import traceback as _traceback

    die_at = die if isinstance(die, float) else None
    if die is True:
        os._exit(42)

    lock = threading.Lock()
    stop = threading.Event()

    def beat():
        while not stop.wait(heartbeat_s):
            with lock:
                try:
                    conn.send(("heartbeat", spec.index))
                except OSError:
                    return

    threading.Thread(target=beat, daemon=True).start()
    try:
        record = _run_shard(spec, die_at=die_at)
    except BaseException as exc:
        stop.set()
        with lock:
            try:
                conn.send(("error", {
                    "shard": spec.index,
                    "exc_type": type(exc).__name__,
                    "message": str(exc),
                    "traceback": _traceback.format_exc(),
                }))
            except OSError:
                pass
        os._exit(1)
    stop.set()
    with lock:
        conn.send(("result", record))
    conn.close()


@dataclass
class ShardedRunResult:
    """Merged view over the shard records.

    ``shards`` keeps every per-shard record (ordered by shard index);
    scalar totals are sums across shards, pending peaks are maxima,
    ``metrics`` is the merged ``MetricsPartial`` (global
    ``tenant_summary()`` / ``usage_summary()``), ``exec_stat`` the
    merged pod-execution stat.  ``loop_wall_s`` is the max shard loop
    wall (the weak-scaling denominator — see module docstring);
    ``wall_s`` is the parent's true end-to-end wall.

    ``degraded`` is True when ``on_shard_failure="degrade"`` merged a
    partial fleet; ``failures`` lists the dropped shards
    (``{"shard", "tenants", "reason", "restarts"}``).
    """
    workers: int
    shards: List[dict]
    metrics: MetricsPartial
    exec_stat: Optional[StreamingStat]
    wall_s: float
    degraded: bool = False
    failures: List[dict] = field(default_factory=list)

    @property
    def events(self) -> int:
        return sum(s["events"] for s in self.shards)

    @property
    def pods_created(self) -> int:
        return sum(s["pods_created"] for s in self.shards)

    @property
    def api_calls(self) -> int:
        return sum(s["api_calls"] for s in self.shards)

    @property
    def informer_copies(self) -> int:
        return sum(s["informer_copies"] for s in self.shards)

    @property
    def completed_workflows(self) -> int:
        return sum(s["completed_workflows"] for s in self.shards)

    @property
    def failed_workflows(self) -> int:
        return sum(s["failed_workflows"] for s in self.shards)

    @property
    def loop_wall_s(self) -> float:
        return max((s["loop_wall_s"] for s in self.shards), default=0.0)

    @property
    def loop_cpu_s(self) -> float:
        return sum(s["loop_cpu_s"] for s in self.shards)

    @property
    def sim_makespan_s(self) -> float:
        return max((s["last_event_t"] for s in self.shards), default=0.0)

    @property
    def events_per_sec(self) -> float:
        lw = self.loop_wall_s
        return self.events / lw if lw > 0 else 0.0

    @property
    def peak_pending_pods(self) -> int:
        return max((s["peak_pending_pods"] for s in self.shards), default=0)

    @property
    def peak_pending_admission(self) -> int:
        return max((s["arbiter"].get("max_pending", 0)
                    for s in self.shards), default=0)

    @property
    def peak_pending_gateway(self) -> int:
        return max((s["gateway"]["peak_pending"]
                    for s in self.shards if s.get("gateway")), default=0)

    @property
    def peak_shard_rss_mib(self) -> float:
        return max((s["peak_rss_mib"] for s in self.shards), default=0.0)

    def arbiter_totals(self) -> Dict[str, int]:
        """Summed arbiter counters (max_pending is a per-shard peak and
        is excluded here — read ``peak_pending_admission``)."""
        out: Dict[str, int] = {}
        for s in self.shards:
            for key, val in s["arbiter"].items():
                if key == "max_pending":
                    continue
                out[key] = out.get(key, 0) + val
        return out

    def chaos_counters(self) -> Dict[str, float]:
        """Summed chaos counters across shards (empty dict when no
        shard ran with a chaos schedule) — exactly mergeable because
        every counter is a per-shard sum."""
        out: Dict[str, float] = {}
        for s in self.shards:
            c = s.get("chaos")
            if not c:
                continue
            for key, val in c.items():
                out[key] = out.get(key, 0) + val
        return out

    @property
    def rebalances(self) -> int:
        return sum(s.get("rebalances", 0) for s in self.shards)

    def descheduler_counters(self) -> Dict[str, float]:
        """Summed descheduler counters across shards (empty dict when
        no shard armed a daemon).  Config echoes (interval/threshold)
        are identical per shard, so keeping the last value is exact."""
        out: Dict[str, float] = {}
        for s in self.shards:
            c = s.get("descheduler")
            if not c:
                continue
            for key, val in c.items():
                if key in ("interval_s", "util_threshold", "victim"):
                    out[key] = val
                else:
                    out[key] = out.get(key, 0) + val
        return out

    def cost_summary(self) -> Dict[str, float]:
        """Exact merge of the per-shard provisioned-capacity costs:
        the shards' rosters are disjoint slices of the whole cluster,
        so area integrals and flip counts add, peaks/lows add too
        (each shard's extremum is over its own slice — concurrent
        daemon ticks make the cluster-wide extremum the sum), and the
        utilization-over-provisioned ratios are recomputed from the
        pooled areas."""
        acc: Dict[str, float] = {}
        sum_keys = ("node_seconds", "cpu_mcore_seconds", "mem_mib_seconds",
                    "used_cpu_mcore_seconds", "used_mem_mib_seconds",
                    "provisioned_peak_nodes", "provisioned_low_nodes",
                    "provision_flips")
        for s in self.shards:
            c = s.get("cost")
            if not c:
                continue
            for key in sum_keys:
                acc[key] = acc.get(key, 0.0) + c.get(key, 0.0)
        if not acc:
            return {}
        cpu_s = acc.get("cpu_mcore_seconds", 0.0)
        mem_s = acc.get("mem_mib_seconds", 0.0)
        acc["cpu_util_over_provisioned"] = (
            acc.get("used_cpu_mcore_seconds", 0.0) / cpu_s
            if cpu_s > 0 else 0.0)
        acc["mem_util_over_provisioned"] = (
            acc.get("used_mem_mib_seconds", 0.0) / mem_s
            if mem_s > 0 else 0.0)
        return acc

    def autoscaler_counters(self) -> Dict[str, float]:
        """Summed autoscaler counters across shards (empty dict when
        no shard armed a daemon).  Config echoes are identical per
        shard, so keeping the last value is exact."""
        out: Dict[str, float] = {}
        for s in self.shards:
            c = s.get("autoscaler")
            if not c:
                continue
            for key, val in c.items():
                if key in ("interval_s", "pending_threshold",
                           "sustain_s", "idle_s"):
                    out[key] = val
                else:
                    out[key] = out.get(key, 0) + val
        return out

    def hotspot_summary(self) -> Dict[str, float]:
        """Exact merge of the per-shard utilization profiles: the
        union of shards is the whole cluster, so mean/variance combine
        by the standard pooled-population identities and max/min by
        max/min (both the peak and the time-weighted mean axes)."""
        total_n = 0
        acc = {"peak": [0.0, 0.0, 0.0, float("inf")],
               "util": [0.0, 0.0, 0.0, float("inf")]}
        keys = {"peak": ("mean_peak_util", "peak_util_variance",
                         "max_peak_util", "min_peak_util"),
                "util": ("mean_util", "util_variance",
                         "max_mean_util", "min_mean_util")}
        for s in self.shards:
            h = s.get("node_hotspot")
            if not h or not h.get("nodes"):
                continue
            n = h["nodes"]
            total_n += n
            for ax, (mk, vk, xk, nk) in keys.items():
                a = acc[ax]
                a[0] += n * h[mk]
                a[1] += n * (h[vk] + h[mk] ** 2)
                a[2] = max(a[2], h[xk])
                a[3] = min(a[3], h[nk])
        out = {"nodes": total_n}
        for ax, (mk, vk, xk, nk) in keys.items():
            a = acc[ax]
            if not total_n:
                out.update({mk: 0.0, vk: 0.0, xk: 0.0, nk: 0.0})
                continue
            mean = a[0] / total_n
            out[mk] = mean
            out[vk] = max(0.0, a[1] / total_n - mean * mean)
            out[xk] = a[2]
            out[nk] = a[3]
        return out

    def gateway_summary(self) -> dict:
        """Merged qstat snapshot across shards (empty dict when no
        shard armed a gateway) — exact by construction: counters and
        gauges sum over the disjoint tenant partition, per-shard peaks
        and the retry horizon take the max."""
        return merge_gateway_snapshots(
            s.get("gateway") for s in self.shards)

    def recovery_summary(self) -> Dict[str, float]:
        """Merged disruption/recovery accounting (see
        ``MetricsPartial.recovery_summary``)."""
        return self.metrics.recovery_summary()

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        return self.metrics.tenant_summary()

    def usage_summary(self) -> Dict[str, Dict[str, float]]:
        return self.metrics.usage_summary()

    def bindings(self) -> Dict[str, List[str]]:
        """Per-tenant binding sequences (``record_bindings=True`` runs
        only) — shard-internal order preserved per tenant."""
        out: Dict[str, List[str]] = {}
        for s in self.shards:
            if not s["bindings"]:
                continue
            for tenant, line in s["bindings"]:
                out.setdefault(tenant, []).append(line)
        return out


class ShardedControlPlane:
    """Tenant-partitioned fan-out of ``ControlPlane``.

    Mirrors the ``ControlPlane`` builder API (``add_stream`` /
    ``add_trace`` / ``run``), but each tenant's streams land on shard
    ``shard_of(tenant, workers)``; each shard gets a disjoint node
    slice (``partition_nodes``), its own spawned seed, and a full
    independent stack in a forked worker (``processes=True``) or run
    inline sequentially (``processes=False`` — bit-identical, for
    tests).  ``workers=1`` callers should use ``ControlPlane``
    directly; this class still accepts it (single shard, full
    cluster) for uniform benchmark plumbing.
    """

    def __init__(self, workers: int,
                 engine_name: str = "kubeadaptor",
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 cluster_cfg: cal.PaperCluster = cal.DEFAULT_CLUSTER,
                 payload_mode: str = "virtual", seed: int = 0,
                 speculative: bool = False,
                 scheduler: str = "topological",
                 admission_policy: str = "fifo",
                 sample_resources: bool = True,
                 sample_mode: str = "full",
                 usage_mode: str = "sampled",
                 retain_pod_log: bool = True,
                 lifecycle: Optional[str] = None,
                 queue: Optional[str] = None,
                 fold_completed: bool = False,
                 capture_trace: bool = True,
                 processes: bool = True,
                 shard_procs: Optional[int] = None,
                 record_bindings: bool = False,
                 profile: bool = False,
                 chaos: Optional[ChaosSchedule] = None,
                 placement: str = "first-fit",
                 deschedule: Optional[DeschedulePolicy] = None,
                 autoscale: Optional[AutoscalePolicy] = None,
                 gateway: Optional[BackpressurePolicy] = None,
                 wal_dir: Optional[str] = None,
                 on_shard_failure: str = "raise",
                 shard_timeout_s: Optional[float] = None,
                 heartbeat_s: float = 2.0,
                 heartbeat_timeout_s: float = 60.0,
                 max_shard_restarts: int = 1):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if cluster_cfg.n_nodes < workers:
            raise ValueError(f"{cluster_cfg.n_nodes} nodes cannot be "
                             f"sliced across {workers} shards")
        if on_shard_failure not in ("raise", "restart", "degrade"):
            raise ValueError(f"unknown on_shard_failure "
                             f"{on_shard_failure!r}; expected "
                             f"'raise', 'restart', or 'degrade'")
        if wal_dir is not None and gateway is None:
            raise ValueError("wal_dir requires a gateway policy")
        self.workers = workers
        self.processes = processes
        self.shard_procs = shard_procs
        self.on_shard_failure = on_shard_failure
        self.shard_timeout_s = shard_timeout_s
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.max_shard_restarts = max_shard_restarts
        slices = partition_nodes(cluster_cfg.n_nodes, workers)
        self.specs = [ShardSpec(
            index=i, workers=workers, seed=shard_seed(seed, i),
            n_nodes=slices[i], engine_name=engine_name, params=params,
            cluster_cfg=cluster_cfg, payload_mode=payload_mode,
            speculative=speculative, scheduler=scheduler,
            admission_policy=admission_policy,
            sample_resources=sample_resources, sample_mode=sample_mode,
            usage_mode=usage_mode, retain_pod_log=retain_pod_log,
            lifecycle=lifecycle, queue=queue,
            fold_completed=fold_completed, capture_trace=capture_trace,
            record_bindings=record_bindings, profile=profile,
            chaos=chaos.spawn(i) if chaos is not None else None,
            placement=placement, deschedule=deschedule,
            autoscale=(autoscale.spawn(i, workers)
                       if autoscale is not None else None),
            gateway=gateway, wal_dir=wal_dir)
            for i in range(workers)]

    # -- tenancy knobs (ControlPlane API, routed by tenant hash) ----------
    def add_stream(self, workflow, repeats: int = 1,
                   tenant: str = "default", arrival: str = "serial",
                   concurrency: int = 1, rate: float = 1.0, burst: int = 1,
                   priority: int = 0, weight: float = 1.0,
                   quota_cpu_m: int = 0, quota_mem_mi: int = 0,
                   deadline_s: float = 0.0) -> int:
        """Register one tenant workload; returns the owning shard."""
        shard = shard_of(tenant, self.workers)
        self.specs[shard].streams.append(dict(
            workflow=workflow, repeats=repeats, tenant=tenant,
            arrival=arrival, concurrency=concurrency, rate=rate,
            burst=burst, priority=priority, weight=weight,
            quota_cpu_m=quota_cpu_m, quota_mem_mi=quota_mem_mi,
            deadline_s=deadline_s))
        return shard

    def add_trace(self, records, tenants: Optional[dict] = None):
        """Partition an arrival trace by tenant hash (record order is
        preserved within each shard)."""
        tenants = tenants or {}
        for rec in records:
            shard = shard_of(rec["tenant"], self.workers)
            self.specs[shard].trace_records.append(rec)
        for name, share in tenants.items():
            self.specs[shard_of(name, self.workers)].trace_tenants[name] = \
                share
        return self

    # -- execution --------------------------------------------------------
    def run(self, horizon_s: float = 500_000.0) -> ShardedRunResult:
        import time as _time
        for spec in self.specs:
            spec.horizon_s = horizon_s
        t0 = _time.perf_counter()
        if self.processes and self.workers > 1:
            records, failures = self._run_forked()
        else:
            records, failures = self._run_inline()
        wall = _time.perf_counter() - t0
        records.sort(key=lambda r: r["shard"])

        merged = MetricsPartial()
        exec_stat: Optional[StreamingStat] = None
        for rec in records:
            merged.merge(rec["metrics_partial"])
            st = rec["exec_stat"]
            if st is not None:
                if exec_stat is None:
                    exec_stat = StreamingStat()
                exec_stat.merge(st)
        return ShardedRunResult(workers=self.workers, shards=records,
                                metrics=merged, exec_stat=exec_stat,
                                wall_s=wall, degraded=bool(failures),
                                failures=failures)

    def _failure_info(self, index: int, reason: str,
                      restarts: int) -> dict:
        return {"shard": index,
                "tenants": _spec_tenants(self.specs[index]),
                "reason": reason, "restarts": restarts}

    def _run_inline(self) -> Tuple[List[dict], List[dict]]:
        """Sequential in-process execution with the same
        ``on_shard_failure`` policy as the fork path.  Restarting a
        deterministic in-process exception will fail again (documented
        — restart is for environmental deaths, which only the fork
        path can exhibit), after which the policy falls through to
        raise."""
        records: List[dict] = []
        failures: List[dict] = []
        for spec in self.specs:
            attempt = 0
            while True:
                try:
                    records.append(_run_shard(spec))
                    break
                except Exception as exc:
                    reason = f"{type(exc).__name__}: {exc}"
                    if (self.on_shard_failure == "restart"
                            and attempt < self.max_shard_restarts):
                        attempt += 1
                        continue
                    if self.on_shard_failure == "degrade":
                        failures.append(self._failure_info(
                            spec.index, reason, attempt))
                        break
                    raise ShardFailure(spec.index, _spec_tenants(spec),
                                       reason) from exc
        return records, failures

    def _run_forked(self) -> Tuple[List[dict], List[dict]]:
        """Fan the shard specs out as one ``Process`` per shard (waves
        of ``shard_procs``, so no loop is oversubscribed), supervised
        over one-way pipes.  A shard fails when its worker sends an
        error, dies without a result, goes heartbeat-silent for
        ``heartbeat_timeout_s``, or the global ``shard_timeout_s``
        join deadline passes — then ``on_shard_failure`` decides:
        raise ShardFailure, respawn the same spec (deterministic, so
        the merged result is unchanged), or drop the shard and merge
        the survivors flagged degraded."""
        import multiprocessing as mp
        import time as _time
        from multiprocessing import connection as mp_conn

        ctx = mp.get_context("fork")
        wave = min(self.shard_procs or os.cpu_count() or 1, self.workers)
        kill_env = os.environ.get("REPRO_SHARD_KILL")
        kill_shard, _, _kill_t = (kill_env or "").partition("@")
        kill_at = float(_kill_t) if _kill_t else None
        deadline = (_time.monotonic() + self.shard_timeout_s
                    if self.shard_timeout_s is not None else None)

        todo = list(range(self.workers))
        restarts: Dict[int, int] = {}
        live: Dict[int, list] = {}      # index -> [proc, conn, last_beat]
        records: Dict[int, dict] = {}
        failures: List[dict] = []

        def launch(i: int) -> None:
            parent, child = ctx.Pipe(duplex=False)
            # REPRO_SHARD_KILL=<index>[@<t>] (test hook): the shard's
            # first incarnation hard-exits — pre-run (simulated SIGKILL
            # at launch), or at virtual time <t> mid-run (leaving a
            # torn WAL for the restart to replay).  Restarted
            # incarnations survive, so restart is testable.
            die: object = kill_shard == str(i) and not restarts.get(i)
            if die and kill_at is not None:
                die = kill_at
            proc = ctx.Process(target=_shard_worker_main,
                               args=(self.specs[i], child,
                                     self.heartbeat_s, die))
            proc.start()
            child.close()
            live[i] = [proc, parent, _time.monotonic()]

        def reap(i: int) -> None:
            proc, conn, _ = live.pop(i)
            try:
                conn.close()
            except OSError:
                pass
            if proc.is_alive():
                proc.terminate()
            proc.join(timeout=10.0)

        def handle_failure(i: int, reason: str) -> None:
            reap(i)
            n = restarts.get(i, 0)
            if (self.on_shard_failure == "restart"
                    and n < self.max_shard_restarts):
                restarts[i] = n + 1
                todo.insert(0, i)
                return
            info = self._failure_info(i, reason, n)
            if self.on_shard_failure == "degrade":
                failures.append(info)
                return
            for j in list(live):
                reap(j)
            raise ShardFailure(i, info["tenants"], reason)

        def drain(i: int) -> Optional[str]:
            """Pull pending messages off shard i's pipe; returns a
            failure reason, or None while healthy / once its result
            landed (a dead worker's buffered result still counts)."""
            proc, conn, _ = live[i]
            try:
                while conn.poll():
                    msg = conn.recv()
                    if msg[0] == "heartbeat":
                        live[i][2] = _time.monotonic()
                    elif msg[0] == "result":
                        records[i] = msg[1]
                        reap(i)
                        return None
                    elif msg[0] == "error":
                        return (f"{msg[1]['exc_type']}: "
                                f"{msg[1]['message']}")
            except (EOFError, OSError):
                return (f"worker died without result "
                        f"(exit code {proc.exitcode})")
            return None

        while todo or live:
            while todo and len(live) < wave:
                launch(todo.pop(0))
            conns = {entry[1]: i for i, entry in live.items()}
            for conn in mp_conn.wait(list(conns),
                                     timeout=min(1.0, self.heartbeat_s)):
                i = conns[conn]
                if i not in live:
                    continue
                reason = drain(i)
                if reason is not None:
                    handle_failure(i, reason)
            now = _time.monotonic()
            for i in list(live):
                proc, _, last = live[i]
                if not proc.is_alive():
                    reason = drain(i) if i in live else None
                    if i in live:       # no buffered result salvaged it
                        handle_failure(
                            i, reason or f"worker died without result "
                                         f"(exit code {proc.exitcode})")
                elif now - last > self.heartbeat_timeout_s:
                    handle_failure(
                        i, f"no heartbeat for "
                           f"{self.heartbeat_timeout_s:.0f}s")
            if deadline is not None and _time.monotonic() > deadline:
                for i in list(live):
                    handle_failure(
                        i, f"shard join timeout "
                           f"({self.shard_timeout_s:.0f}s)")
                while todo:             # never-launched shards at deadline
                    i = todo.pop()
                    info = self._failure_info(
                        i, "not started before shard join timeout",
                        restarts.get(i, 0))
                    if self.on_shard_failure == "degrade":
                        failures.append(info)
                    else:
                        for j in list(live):
                            reap(j)
                        raise ShardFailure(i, info["tenants"],
                                           info["reason"])
        return [records[i] for i in sorted(records)], failures
