"""Baseline workflow submission approaches (§5.3).

BatchJobEngine — the paper's "Batch Job": a shell script drives kubectl
level by level. Every operation is a kubectl CLI round-trip; a level's
pods are polled with `kubectl get` until ALL succeed, then deleted, and
only then does the next level start (the barrier the paper criticizes:
ready successors wait for the slowest sibling).

ArgoLikeEngine — an Argo-workflow-controller model: one reconcile loop
per workflow at ``argo_reconcile`` cadence. Cycle k detects completions
(API list + controller processing), deletes completed pods (podGC
onPodCompletion), and *requeues* the DAG so newly-unblocked steps are
created in cycle k+1 — the two-phase step transition that dominates
Argo's lifecycle numbers in the paper.

DirectSubmitEngine — the motivation (Fig 1): all task pods thrown at
the cluster at once; the disordered scheduler then executes them in an
order unrelated to the DAG. Used to demonstrate the inconsistency
KubeAdaptor exists to fix (tests + consistency benchmark).

All baselines talk straight to the apiserver (no informer), so
``Cluster.api_calls`` also reproduces the apiserver-pressure claim.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core import calibration as cal
from repro.core.cluster import (FAILED, PENDING, RUNNING, SUCCEEDED, Cluster,
                                PodObj)
from repro.core.dag import Task, Workflow
from repro.core.metrics import MetricsCollector
from repro.core.sim import Sim
from repro.core.volumes import VolumeManager


def _mk_pod(engine: str, ns: str, wf: Workflow, task: Task,
            volumes: VolumeManager, pvc: Optional[str]) -> PodObj:
    labels = {"engine": engine, "task": task.id}
    if task.virtual:
        labels["virtual"] = "1"
    cpu, mem = task.resource_request()
    payload = None
    if task.payload is not None:
        vol = volumes.volume(pvc) if pvc else None
        payload = (lambda t=task, v=vol: t.payload(v, t))
    return PodObj(name=task.id, namespace=ns, task_id=task.id,
                  workflow=wf.name, cpu_m=cpu, mem_mi=mem,
                  duration_s=task.run_time(), payload=payload,
                  volume=pvc, labels=labels)


class _TrackingMixin:
    """Watch-based start/finish bookkeeping (metrics only, no control)."""

    def _track(self, cluster: Cluster, metrics: MetricsCollector, engine: str):
        def on_event(ev):
            pod = ev.obj
            if pod.labels.get("engine") != engine:
                return
            ws = self._by_ns.get(pod.namespace)
            if ws is None:
                return
            if ev.type == "MODIFIED" and pod.phase == RUNNING:
                metrics.note_start(ws["wf"], pod.task_id)
            if ev.type == "MODIFIED" and pod.phase == SUCCEEDED:
                metrics.note_finish(ws["wf"], pod.task_id)
        cluster.watch("pod", on_event)


class BatchJobEngine(_TrackingMixin):
    name = "batchjob"

    def __init__(self, sim: Sim, cluster: Cluster, volumes: VolumeManager,
                 metrics: MetricsCollector,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 on_workflow_done: Optional[Callable] = None):
        self.sim = sim
        self.cluster = cluster
        self.volumes = volumes
        self.metrics = metrics
        self.p = params
        self.on_workflow_done = on_workflow_done
        self._by_ns: Dict[str, Dict] = {}
        self._track(cluster, metrics, self.name)

    def submit(self, wf: Workflow):
        ns = wf.namespace()
        ws = {"wf": wf, "levels": wf.levels(), "level": 0, "pvc": None}
        self._by_ns[ns] = ws
        self.metrics.note_submitted(wf)
        # kubectl create namespace && kubectl apply pvc
        self.sim.after(self.p.kubectl_latency, lambda: self.cluster.create_namespace(
            ns, cb=lambda _n: self._ns_ready(ws)))

    def _ns_ready(self, ws):
        self.metrics.note_ns_created(ws["wf"])
        ws["pvc"] = self.volumes.provision(
            ws["wf"].namespace(), cb=lambda _p: self._run_level(ws))

    def _run_level(self, ws):
        wf: Workflow = ws["wf"]
        if ws["level"] >= len(ws["levels"]):
            self._finish(ws)
            return
        tasks = [wf.tasks[t] for t in ws["levels"][ws["level"]]]

        # one `kubectl apply -f level.yaml` for the whole batch
        def apply():
            for t in tasks:
                self.cluster.create_pod(_mk_pod(self.name, wf.namespace(), wf,
                                                t, self.volumes, ws["pvc"]))
            self.sim.after(self.p.batch_poll_interval,
                           lambda: self._poll_level(ws, tasks))

        self.sim.after(self.p.kubectl_latency, apply)

    def _poll_level(self, ws, tasks: List[Task]):
        """`kubectl get pod <name>` per task — the paper's 'continual
        checking of the status of the task pod' (width-dependent cost)."""
        wf: Workflow = ws["wf"]
        ns = wf.namespace()
        states: Dict[str, str] = {}
        # one CLI round-trip + one status fetch per pod in the level
        cost = self.p.kubectl_latency + self.p.batch_pod_poll * len(tasks)

        def check():
            for t in tasks:
                pods = {p.name: p for p in self.cluster.list_pods(ns)}
                p = pods.get(t.id)
                states[t.id] = p.phase if p is not None else "Missing"
            done()

        def done():
            failed = [t for t in tasks if states.get(t.id) == FAILED]
            if failed:
                for t in failed:   # kubectl delete + re-apply
                    self.cluster.delete_pod(
                        ns, t.id,
                        cb=lambda _x, t=t: self.cluster.create_pod(
                            _mk_pod(self.name, ns, wf, t, self.volumes,
                                    ws["pvc"])))
                self.sim.after(self.p.batch_poll_interval,
                               lambda: self._poll_level(ws, tasks))
            elif all(states.get(t.id) == SUCCEEDED for t in tasks):
                self._delete_level(ws, tasks)
            else:
                self.sim.after(self.p.batch_poll_interval,
                               lambda: self._poll_level(ws, tasks))

        self.sim.after(cost, check)

    def _delete_level(self, ws, tasks: List[Task]):
        wf: Workflow = ws["wf"]
        ns = wf.namespace()
        remaining = {t.id for t in tasks}

        def deleted(pod):
            if pod is not None:
                remaining.discard(pod.name)
            if not remaining:
                ws["level"] += 1
                self._run_level(ws)

        def delete_all():   # one `kubectl delete -f level.yaml`
            for t in tasks:
                self.cluster.delete_pod(ns, t.id, cb=deleted)

        self.sim.after(self.p.kubectl_latency, delete_all)

    def _finish(self, ws):
        wf: Workflow = ws["wf"]
        def gone(_n):
            self.metrics.note_ns_deleted(wf)
            self.volumes.release(wf.namespace())
            if self.on_workflow_done:
                self.on_workflow_done(wf)
        self.sim.after(self.p.kubectl_latency,
                       lambda: self.cluster.delete_namespace(wf.namespace(), cb=gone))


class ArgoLikeEngine(_TrackingMixin):
    name = "argo"

    def __init__(self, sim: Sim, cluster: Cluster, volumes: VolumeManager,
                 metrics: MetricsCollector,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 on_workflow_done: Optional[Callable] = None):
        self.sim = sim
        self.cluster = cluster
        self.volumes = volumes
        self.metrics = metrics
        self.p = params
        self.on_workflow_done = on_workflow_done
        self._by_ns: Dict[str, Dict] = {}
        self._track(cluster, metrics, self.name)

    def submit(self, wf: Workflow):
        ns = wf.namespace()
        ws = {"wf": wf, "completed": set(), "created": set(),
              "to_create": [], "pvc": None, "done": False}
        self._by_ns[ns] = ws
        self.metrics.note_submitted(wf)
        # CRD submission + controller pickup
        self.sim.after(self.p.argo_workflow_init,
                       lambda: self.cluster.create_namespace(
                           ns, cb=lambda _n: self._ns_ready(ws)))

    def _ns_ready(self, ws):
        self.metrics.note_ns_created(ws["wf"])
        ws["pvc"] = self.volumes.provision(
            ws["wf"].namespace(), cb=lambda _p: self._bootstrap(ws))

    def _bootstrap(self, ws):
        ws["to_create"] = self._ready(ws)
        self._reconcile(ws)

    def _ready(self, ws) -> List[str]:
        wf: Workflow = ws["wf"]
        out = []
        for tid, t in wf.tasks.items():
            if tid in ws["completed"] or tid in ws["created"]:
                continue
            if all(d in ws["completed"] for d in t.inputs):
                out.append(tid)
        return out

    def _reconcile(self, ws):
        """One controller cycle: API list + process + act; requeue."""
        if ws["done"]:
            return
        wf: Workflow = ws["wf"]
        ns = wf.namespace()

        def process():
            # phase 1: create pods queued by the PREVIOUS cycle — the
            # controller instantiates step templates one at a time
            delay = 0.0
            for tid in ws["to_create"]:
                if tid not in ws["created"]:
                    ws["created"].add(tid)
                    self.sim.after(delay, lambda t=tid: self.cluster.create_pod(
                        _mk_pod(self.name, ns, wf, wf.tasks[t],
                                self.volumes, ws["pvc"])))
                    delay += self.p.argo_pod_overhead
            ws["to_create"] = []
            # phase 2: detect completions, GC their pods, queue successors
            pods = {p.name: p for p in self.cluster.list_pods(ns)}
            for name, pod in pods.items():
                if pod.phase == SUCCEEDED and name not in ws["completed"]:
                    ws["completed"].add(name)
                    self.cluster.delete_pod(ns, name)
                elif pod.phase == FAILED:
                    ws["created"].discard(name)       # retried next cycle
                    self.cluster.delete_pod(ns, name)
            ws["to_create"] = self._ready(ws)
            if len(ws["completed"]) == len(wf.tasks):
                self._finish(ws)
            else:
                self.sim.after(self.p.argo_reconcile, lambda: self._reconcile(ws))

        # API list + DAG-processing overhead per cycle
        self.sim.after(self.p.api_latency + self.p.argo_controller_overhead,
                       process)

    def _finish(self, ws):
        ws["done"] = True
        wf: Workflow = ws["wf"]
        def gone(_n):
            self.metrics.note_ns_deleted(wf)
            self.volumes.release(wf.namespace())
            if self.on_workflow_done:
                self.on_workflow_done(wf)
        self.cluster.delete_namespace(wf.namespace(), cb=gone)


class DirectSubmitEngine(_TrackingMixin):
    """Fig 1's problem: submit everything, let the scheduler 'decide'."""

    name = "direct"

    def __init__(self, sim: Sim, cluster: Cluster, volumes: VolumeManager,
                 metrics: MetricsCollector,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 on_workflow_done: Optional[Callable] = None):
        self.sim = sim
        self.cluster = cluster
        self.volumes = volumes
        self.metrics = metrics
        self.p = params
        self.on_workflow_done = on_workflow_done
        self._by_ns: Dict[str, Dict] = {}
        self._track(cluster, metrics, self.name)

    def submit(self, wf: Workflow):
        ns = wf.namespace()
        ws = {"wf": wf, "deleted": set(), "done": False}
        self._by_ns[ns] = ws
        self.metrics.note_submitted(wf)
        self.cluster.create_namespace(ns, cb=lambda _n: self._all_in(ws))

    def _all_in(self, ws):
        wf: Workflow = ws["wf"]
        self.metrics.note_ns_created(wf)
        for t in wf.tasks.values():
            self.cluster.create_pod(_mk_pod(self.name, wf.namespace(), wf, t,
                                            self.volumes, None))
        self._poll(ws)

    def _poll(self, ws):
        wf: Workflow = ws["wf"]
        ns = wf.namespace()
        pods = self.cluster.list_pods(ns)
        for p in pods:
            if p.phase == SUCCEEDED:
                self.cluster.delete_pod(ns, p.name)
                ws["deleted"].add(p.name)
        if len(ws["deleted"]) == len(wf.tasks) and not ws["done"]:
            ws["done"] = True
            def gone(_n):
                self.metrics.note_ns_deleted(wf)
                if self.on_workflow_done:
                    self.on_workflow_done(wf)
            self.cluster.delete_namespace(ns, cb=gone)
            return
        self.sim.after(self.p.batch_poll_interval, lambda: self._poll(ws))
