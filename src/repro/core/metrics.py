"""Metrics: pod timelines, workflow lifecycles, resource-usage sampling.

Definitions follow the paper exactly:
  * task-pod execution time  = pod deletion - pod creation (Fig 7),
  * workflow lifecycle       = namespace creation -> namespace deletion
                               (Fig 8: "from creation to death of the
                               workflow namespace"),
  * resource usage rate      = requested(running pods) / allocatable,
                               sampled every 0.5 s (Figs 9-14),
  * order consistency        = pod start order is a topological
                               linearization of the DAG (Fig 6).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import calibration as cal
from repro.core.cluster import Cluster, SUCCEEDED
from repro.core.dag import Workflow
from repro.core.sim import Sim


@dataclass
class WorkflowRecord:
    name: str
    instance: int
    ns_created: float = -1.0
    ns_deleted: float = -1.0
    starts: List[Tuple[float, str]] = field(default_factory=list)   # (t, task)
    finishes: Dict[str, float] = field(default_factory=dict)
    retries: int = 0

    @property
    def lifecycle(self) -> float:
        return self.ns_deleted - self.ns_created


class MetricsCollector:
    def __init__(self, sim: Sim, cluster: Cluster,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS):
        self.sim = sim
        self.cluster = cluster
        self.p = params
        self.workflows: Dict[Tuple[str, int], WorkflowRecord] = {}
        self.samples: List[Tuple[float, int, int]] = []   # (t, cpu_m, mem_mi)
        self._sampling = False

    # ---- lifecycle bookkeeping (engines call these) ---------------------
    def wf_record(self, wf: Workflow) -> WorkflowRecord:
        key = (wf.name, wf.instance)
        if key not in self.workflows:
            self.workflows[key] = WorkflowRecord(wf.name, wf.instance)
        return self.workflows[key]

    def note_ns_created(self, wf: Workflow):
        self.wf_record(wf).ns_created = self.sim.now()

    def note_ns_deleted(self, wf: Workflow):
        self.wf_record(wf).ns_deleted = self.sim.now()

    def note_start(self, wf: Workflow, task_id: str):
        self.wf_record(wf).starts.append((self.sim.now(), task_id))

    def note_finish(self, wf: Workflow, task_id: str):
        self.wf_record(wf).finishes[task_id] = self.sim.now()

    # ---- resource sampling ------------------------------------------------
    def start_sampling(self):
        if self._sampling:
            return
        self._sampling = True

        def sample():
            cpu, mem = self.cluster.used()
            self.samples.append((self.sim.now(), cpu, mem))
            if self._sampling:
                self.sim.after(self.p.sample_period, sample, daemon=True)

        sample()

    def stop_sampling(self):
        self._sampling = False

    # ---- derived metrics (the figures) -------------------------------------
    def pod_exec_times(self, workflow: Optional[str] = None,
                       include_virtual: bool = False) -> List[float]:
        out = []
        for pod in self.cluster.pod_log:
            if workflow is not None and pod.workflow != workflow:
                continue
            if not include_virtual and pod.labels.get("virtual") == "1":
                continue
            if pod.deleted > 0 and pod.phase == SUCCEEDED:
                out.append(pod.deleted - pod.created)
        return out

    def avg_pod_exec_time(self, workflow: Optional[str] = None) -> float:
        xs = self.pod_exec_times(workflow)
        return sum(xs) / len(xs) if xs else float("nan")

    def lifecycles(self, name: str) -> List[float]:
        return [r.lifecycle for (n, _), r in self.workflows.items()
                if n == name and r.ns_deleted > 0]

    def avg_lifecycle(self, name: str) -> float:
        xs = self.lifecycles(name)
        return sum(xs) / len(xs) if xs else float("nan")

    def total_time(self, name: str) -> float:
        recs = [r for (n, _), r in self.workflows.items() if n == name]
        if not recs:
            return float("nan")
        return max(r.ns_deleted for r in recs) - min(r.ns_created for r in recs)

    def order_consistent(self, wf: Workflow) -> bool:
        """Start order must be a topological linearization of the DAG
        AND every dependency must have FINISHED before the dependent starts."""
        rec = self.wf_record(wf)
        started_at = {t: ts for ts, t in rec.starts}
        for ts, tid in rec.starts:
            for dep in wf.tasks[tid].inputs:
                if dep not in rec.finishes or rec.finishes[dep] > ts + 1e-9:
                    return False
                if dep not in started_at or started_at[dep] > ts + 1e-9:
                    return False
        return len(rec.starts) >= len(wf.tasks)

    def usage_rate_over(self, t0: float, t1: float) -> Tuple[float, float]:
        """Average (cpu_rate, mem_rate) over [t0, t1] vs allocatable."""
        cpu_a, mem_a = self.cluster.allocatable()
        window = [(t, c, m) for t, c, m in self.samples if t0 <= t <= t1]
        if not window or cpu_a == 0:
            return 0.0, 0.0
        cpu = sum(c for _, c, _ in window) / len(window) / cpu_a
        mem = sum(m for _, _, m in window) / len(window) / mem_a
        return cpu, mem

    def first_lifecycle_usage(self, name: str) -> Tuple[float, float]:
        recs = sorted((r for (n, _), r in self.workflows.items() if n == name),
                      key=lambda r: r.ns_created)
        if not recs:
            return 0.0, 0.0
        r = recs[0]
        return self.usage_rate_over(r.ns_created, r.ns_deleted)
