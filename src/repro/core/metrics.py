"""Metrics: pod timelines, workflow lifecycles, resource-usage sampling.

Definitions follow the paper exactly:
  * task-pod execution time  = pod deletion - pod creation (Fig 7),
  * workflow lifecycle       = namespace creation -> namespace deletion
                               (Fig 8: "from creation to death of the
                               workflow namespace"),
  * resource usage rate      = requested(running pods) / allocatable,
                               sampled every 0.5 s (Figs 9-14),
  * order consistency        = pod start order is a topological
                               linearization of the DAG (Fig 6).

Multi-tenant extensions (beyond-paper): every record carries its
tenant; ``note_submitted`` timestamps gateway hand-off so queueing
delay (submission -> namespace creation) is measurable; the sampler
also breaks bound node usage down per tenant; ``tenant_summary``
aggregates makespan / queueing delay / lifecycle / admission
deferrals per tenant for the multi-tenant benchmarks — plus, with the
admission pipeline (ISSUE 4), per-tenant quota-reject counts,
preempted-pod counts, and the per-stream SLO: ``set_tenant_deadline``
registers a deadline and the summary reports its hit-rate over
completed workflows (submission -> namespace teardown).

Scale tier (ISSUE 2): ``sample_mode="streaming"`` replaces the
unbounded per-sample lists with flat-memory accumulators
(``core/stats.StreamingStat``: count/mean/max + fixed reservoir for
percentiles) — at 1000 workflows the sampler would otherwise grow
without bound. Paper-scale runs keep the default ``"full"`` mode, so
``samples``/``usage_rate_over`` behave exactly as before.

Event-driven usage accounting (ISSUE 3): the 0.5 s sampler is a
polling daemon — 1801 sim events per 900 s run, scaling with sim time
rather than load, and only ever an approximation of the underlying
step function.  ``usage_mode="event"`` drops the daemon entirely: the
cluster fires ``on_usage_change`` at every bind/release and the
collector keeps exact ``StepAccumulator``s (cluster cpu/mem + per
tenant), from which mean/peak/p95 rates are derived in closed form via
``usage_summary()``.  The default stays ``"sampled"`` (both
``sample_mode`` flavours unchanged); tests pin that the two modes
agree on mean/peak and that removing the daemon moves no scheduling
decision.

Sharded control plane (ISSUE 6): per-tenant aggregation is
*foldable* and *mergeable*.  ``fold_completed=True`` collapses each
``WorkflowRecord`` into a compact per-tenant ``TenantAgg`` the moment
its namespace is deleted (O(tenants) memory instead of O(workflows) —
the 1M-workflow tier would otherwise hold a million records).
``export_partial()`` emits a picklable ``MetricsPartial`` (tenant
aggregates + usage-rate accumulators) that travels over the shard
result pipe; ``MetricsPartial.merge`` unions shard partials (tenants
are shard-disjoint, so per-tenant merge is key-union; usage windows
concatenate via ``StepAccumulator.merge``) and reproduces the global
``tenant_summary`` / ``usage_summary`` shapes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

from repro.core import calibration as cal
from repro.core.cluster import Cluster, SUCCEEDED
from repro.core.dag import Workflow
from repro.core.sim import Sim
from repro.core.stats import StepAccumulator, StreamingStat


@dataclass
class WorkflowRecord:
    name: str
    instance: int
    tenant: str = "default"
    submitted_at: float = -1.0
    first_create: float = -1.0     # first task-pod creation (post-admission)
    ns_created: float = -1.0
    ns_deleted: float = -1.0
    starts: List[Tuple[float, str]] = field(default_factory=list)   # (t, task)
    finishes: Dict[str, float] = field(default_factory=dict)
    retries: int = 0
    preempted: int = 0             # task pods evicted by the Preempt stage
    node_lost: int = 0             # task pods lost to node kills/drains
    rebalanced: int = 0            # task pods offloaded by the descheduler
    failed: bool = False           # retry budget exhausted (fail-workflow)
    failure: str = ""

    @property
    def lifecycle(self) -> float:
        return self.ns_deleted - self.ns_created

    @property
    def queue_delay(self) -> float:
        """Gateway hand-off -> first task-pod creation. Namespace/PVC
        setup is never arbiter-gated, so only the first *pod* creation
        reflects admission wait under contention."""
        if self.submitted_at < 0 or self.first_create < 0:
            return float("nan")
        return self.first_create - self.submitted_at


@dataclass
class TenantAgg:
    """Compact per-tenant aggregate — everything ``tenant_summary``
    derives from the record list, folded to O(1) scalars so completed
    ``WorkflowRecord``s can be dropped (``fold_completed``) and shard
    partials merged.  Field bases mirror ``tenant_summary`` exactly:
    makespan spans records with a deleted namespace *including* failed
    ones; queue-delay / lifecycle / deadline hits cover completed
    (non-failed) records only; preempted/retries span all records."""
    workflows: int = 0
    completed: int = 0
    failed: int = 0
    mk_t0: float = math.inf       # min submission (fallback ns_created)
    mk_t1: float = -math.inf      # max namespace deletion
    qd_sum: float = 0.0
    qd_n: int = 0
    lc_sum: float = 0.0
    lc_n: int = 0
    preempted: int = 0
    node_lost: int = 0
    rebalanced: int = 0
    retries: int = 0
    deadline_hits: int = 0

    def fold(self, rec: "WorkflowRecord", deadline_s: float = 0.0):
        self.workflows += 1
        self.preempted += rec.preempted
        self.node_lost += rec.node_lost
        self.rebalanced += rec.rebalanced
        self.retries += rec.retries
        if rec.failed:
            self.failed += 1
        if rec.ns_deleted > 0:
            t0 = rec.submitted_at if rec.submitted_at >= 0 else rec.ns_created
            if t0 < self.mk_t0:
                self.mk_t0 = t0
            if rec.ns_deleted > self.mk_t1:
                self.mk_t1 = rec.ns_deleted
            if not rec.failed:
                self.completed += 1
                qd = rec.queue_delay
                if qd == qd:                       # drop NaN
                    self.qd_sum += qd
                    self.qd_n += 1
                self.lc_sum += rec.lifecycle
                self.lc_n += 1
                if (deadline_s > 0 and rec.submitted_at >= 0
                        and rec.ns_deleted - rec.submitted_at
                        <= deadline_s + 1e-9):
                    self.deadline_hits += 1

    def merge(self, other: "TenantAgg") -> "TenantAgg":
        self.workflows += other.workflows
        self.completed += other.completed
        self.failed += other.failed
        self.mk_t0 = min(self.mk_t0, other.mk_t0)
        self.mk_t1 = max(self.mk_t1, other.mk_t1)
        self.qd_sum += other.qd_sum
        self.qd_n += other.qd_n
        self.lc_sum += other.lc_sum
        self.lc_n += other.lc_n
        self.preempted += other.preempted
        self.node_lost += other.node_lost
        self.rebalanced += other.rebalanced
        self.retries += other.retries
        self.deadline_hits += other.deadline_hits
        return self

    def summary_row(self, deferrals: int = 0, quota_rejects: int = 0,
                    deadline_s: float = 0.0,
                    gateway: Optional[Dict[str, int]] = None
                    ) -> Dict[str, float]:
        """One ``tenant_summary`` row — same keys, same NaN semantics.
        ``gateway`` (``{"rejects", "retries", "shed"}``) adds the
        submission-edge columns; ``None`` keeps the legacy key set."""
        row = {
            "workflows": float(self.workflows),
            "completed": float(self.completed),
            "failed": float(self.failed),
            "makespan": (self.mk_t1 - self.mk_t0
                         if self.mk_t1 > -math.inf else float("nan")),
            "avg_queue_delay": (self.qd_sum / self.qd_n
                                if self.qd_n else float("nan")),
            "avg_lifecycle": (self.lc_sum / self.lc_n
                              if self.lc_n else float("nan")),
            "admission_deferrals": float(deferrals),
            "quota_rejects": float(quota_rejects),
            "preempted": float(self.preempted),
            "node_lost": float(self.node_lost),
            "rebalanced": float(self.rebalanced),
        }
        if gateway is not None:
            row["gateway_rejects"] = float(gateway.get("rejects", 0))
            row["gateway_retries"] = float(gateway.get("retries", 0))
            row["gateway_shed"] = float(gateway.get("shed", 0))
        if deadline_s > 0:
            row["deadline_s"] = deadline_s
            row["deadline_hits"] = float(self.deadline_hits)
            row["deadline_hit_rate"] = (self.deadline_hits / self.completed
                                        if self.completed else float("nan"))
        return row


@dataclass
class MetricsPartial:
    """Picklable shard extract of a ``MetricsCollector``.

    ``usage`` holds *rate-normalized* accumulators (levels divided by
    the exporting shard's allocatable), so merging concatenates the
    shards' utilization-rate step functions: the merged mean is the
    time-weighted mean utilization across shard slices (equal to the
    cluster-wide rate for equal slices), the merged peak is the max
    per-slice peak.  Tenants are shard-disjoint under the crc32
    partition, so tenant maps merge by key-union (same-key collisions
    still compose correctly via ``TenantAgg.merge``).
    """
    tenant_aggs: Dict[str, TenantAgg] = field(default_factory=dict)
    admission_deferrals: Dict[str, int] = field(default_factory=dict)
    quota_rejects: Dict[str, int] = field(default_factory=dict)
    # submission-edge outcomes from the DurableGateway (ISSUE 10);
    # gateway_active gates the extra tenant_summary columns so
    # gateway-free runs keep the legacy key set bit-for-bit
    gateway_active: bool = False
    gateway_rejects: Dict[str, int] = field(default_factory=dict)
    gateway_retries: Dict[str, int] = field(default_factory=dict)
    gateway_shed: Dict[str, int] = field(default_factory=dict)
    tenant_deadlines: Dict[str, float] = field(default_factory=dict)
    usage: Dict[str, StepAccumulator] = field(default_factory=dict)
    usage_basis: str = "event"
    # chaos recovery: disruption -> replacement-create times (seconds),
    # exactly mergeable like every other StreamingStat (Chan variance,
    # reservoir union) — empty outside chaos runs
    resched: StreamingStat = field(default_factory=StreamingStat)

    def merge(self, other: "MetricsPartial") -> "MetricsPartial":
        self.resched.merge(other.resched)
        for tenant, agg in other.tenant_aggs.items():
            mine = self.tenant_aggs.get(tenant)
            if mine is None:
                self.tenant_aggs[tenant] = replace(agg)
            else:
                mine.merge(agg)
        for src, dst in ((other.admission_deferrals, self.admission_deferrals),
                         (other.quota_rejects, self.quota_rejects),
                         (other.gateway_rejects, self.gateway_rejects),
                         (other.gateway_retries, self.gateway_retries),
                         (other.gateway_shed, self.gateway_shed)):
            for tenant, n in src.items():
                dst[tenant] = dst.get(tenant, 0) + n
        self.gateway_active = self.gateway_active or other.gateway_active
        self.tenant_deadlines.update(other.tenant_deadlines)
        for key, acc in other.usage.items():
            mine = self.usage.get(key)
            if mine is None:
                self.usage[key] = _copy_acc(acc)
            else:
                mine.merge(acc)
        return self

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        return {
            tenant: self.tenant_aggs[tenant].summary_row(
                deferrals=self.admission_deferrals.get(tenant, 0),
                quota_rejects=self.quota_rejects.get(tenant, 0),
                deadline_s=self.tenant_deadlines.get(tenant, 0.0),
                gateway=({"rejects": self.gateway_rejects.get(tenant, 0),
                          "retries": self.gateway_retries.get(tenant, 0),
                          "shed": self.gateway_shed.get(tenant, 0)}
                         if self.gateway_active else None))
            for tenant in sorted(self.tenant_aggs)
        }

    def usage_summary(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for key, acc in self.usage.items():
            out[key] = {"basis": self.usage_basis, "changes": acc.changes,
                        "mean_rate": acc.mean(),
                        "peak_rate": acc.peak,
                        "p95_rate": acc.percentile(95)}
        return out

    @property
    def completed(self) -> int:
        return sum(a.completed for a in self.tenant_aggs.values())

    @property
    def failed(self) -> int:
        return sum(a.failed for a in self.tenant_aggs.values())

    @property
    def workflows(self) -> int:
        return sum(a.workflows for a in self.tenant_aggs.values())

    def recovery_summary(self) -> Dict[str, float]:
        """Recovery accounting rollup: node_lost/preempted splits from
        the tenant aggregates plus time-to-reschedule percentiles."""
        st = self.resched
        out = {
            "node_lost": float(sum(a.node_lost
                                   for a in self.tenant_aggs.values())),
            "preempted": float(sum(a.preempted
                                   for a in self.tenant_aggs.values())),
            "rebalanced": float(sum(a.rebalanced
                                    for a in self.tenant_aggs.values())),
            "rescheduled": float(st.count),
        }
        if st.count:
            out["resched_mean_s"] = st.mean
            out["resched_p50_s"] = st.percentile(50)
            out["resched_p95_s"] = st.percentile(95)
            out["resched_max_s"] = st.max
        return out


def _copy_acc(acc: StepAccumulator) -> StepAccumulator:
    out = StepAccumulator(t0=acc.start_t, level=acc.level)
    out.peak = acc.peak
    out.last_t = acc.last_t
    out.level_dur = dict(acc.level_dur)
    out.changes = acc.changes
    return out


def _rate_acc(acc: StepAccumulator, alloc: float) -> StepAccumulator:
    """Rebase an absolute-level accumulator to utilization rates
    (divide by allocatable) on a window starting at 0."""
    out = StepAccumulator(t0=0.0, level=acc.level / alloc if alloc else 0.0)
    out.peak = acc.peak / alloc if alloc else 0.0
    out.last_t = acc.total_time
    out.level_dur = {lv / alloc: d for lv, d in acc.level_dur.items()} \
        if alloc else {}
    out.changes = acc.changes
    return out


class _ContentionTracker:
    """Exact contended-window integrals for ``usage_mode="event"``:
    per-tenant bound-CPU·seconds accumulated ONLY while every tracked
    tenant holds resources — the event-driven equivalent of filtering
    the 0.5 s samples to instants where all tenants appear."""

    __slots__ = ("tenants", "levels", "active", "last_t",
                 "cpu_seconds", "contended_time")

    def __init__(self, tenants, t0: float):
        self.tenants = list(tenants)
        self.levels = {t: 0 for t in self.tenants}
        self.active = False
        self.last_t = t0
        self.cpu_seconds = {t: 0.0 for t in self.tenants}
        self.contended_time = 0.0

    def update(self, t: float, holding: Dict[str, int]):
        if self.active and t > self.last_t:
            dt = t - self.last_t
            self.contended_time += dt
            for tenant in self.tenants:
                self.cpu_seconds[tenant] += self.levels[tenant] * dt
        self.last_t = t
        levels = self.levels
        for tenant in self.tenants:
            levels[tenant] = holding.get(tenant, 0)
        self.active = all(levels[t] > 0 for t in self.tenants)

    def means(self) -> Dict[str, float]:
        if self.contended_time <= 0.0:
            return {}
        return {t: s / self.contended_time
                for t, s in self.cpu_seconds.items()}


class MetricsCollector:
    def __init__(self, sim: Sim, cluster: Cluster,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 sample_mode: str = "full",
                 usage_mode: str = "sampled",
                 fold_completed: bool = False):
        if sample_mode not in ("full", "streaming"):
            raise ValueError(f"unknown sample_mode {sample_mode!r}")
        if usage_mode not in ("sampled", "event"):
            raise ValueError(f"unknown usage_mode {usage_mode!r}")
        self.sim = sim
        self.cluster = cluster
        self.p = params
        self.sample_mode = sample_mode
        self.usage_mode = usage_mode
        self.fold_completed = fold_completed
        self.tenant_aggs: Dict[str, TenantAgg] = {}
        self.workflows: Dict[Tuple[str, int], WorkflowRecord] = {}
        self.samples: List[Tuple[float, int, int]] = []   # (t, cpu_m, mem_mi)
        self.tenant_samples: List[Tuple[float, Dict[str, int]]] = []
        self.cpu_stat = StreamingStat()
        self.mem_stat = StreamingStat()
        self.tenant_cpu_stats: Dict[str, StreamingStat] = {}
        self.admission_deferrals: Dict[str, int] = {}
        self.quota_rejects: Dict[str, int] = {}       # tenant -> count
        # submission-edge outcomes (DurableGateway, ISSUE 10); the
        # flag gates the extra tenant_summary columns
        self.gateway_active = False
        self.gateway_rejects: Dict[str, int] = {}
        self.gateway_retries: Dict[str, int] = {}
        self.gateway_shed: Dict[str, int] = {}
        self.tenant_deadlines: Dict[str, float] = {}  # tenant -> SLO seconds
        # chaos recovery: disruption -> replacement-create latency
        self.resched_stat = StreamingStat()
        self._sampling = False
        # event-driven accounting: exact step accumulators fed by the
        # cluster's bind/release hook — no polling daemon
        self.cpu_acc: Optional[StepAccumulator] = None
        self.mem_acc: Optional[StepAccumulator] = None
        self.tenant_cpu_accs: Dict[str, StepAccumulator] = {}
        self.tenant_mem_accs: Dict[str, StepAccumulator] = {}
        self._contention: Optional[_ContentionTracker] = None
        self._usage_closed = False
        if usage_mode == "event":
            self.cpu_acc = StepAccumulator(t0=sim.now())
            self.mem_acc = StepAccumulator(t0=sim.now())
            cluster.on_usage_change = self._usage_changed

    def _usage_changed(self, tenant: Optional[str]):
        t = self.sim.t
        self.cpu_acc.set(t, self.cluster.cpu_in_use)
        self.mem_acc.set(t, self.cluster.mem_in_use)
        if self._contention is not None:
            self._contention.update(t, self.cluster.tenant_holding_cpu)
        if tenant is not None:
            acc = self.tenant_cpu_accs.get(tenant)
            if acc is None:
                # window-align with the cluster accumulators (t0 at
                # collector start): tenant means are over the whole run,
                # leading idle time included — unlike sampled-mode
                # tenant stats, which are means over active samples only
                acc = self.tenant_cpu_accs[tenant] = \
                    StepAccumulator(t0=self.cpu_acc.start_t)
                self.tenant_mem_accs[tenant] = \
                    StepAccumulator(t0=self.mem_acc.start_t)
            acc.set(t, self.cluster.tenant_holding_cpu.get(tenant, 0))
            self.tenant_mem_accs[tenant].set(
                t, self.cluster.tenant_holding_mem.get(tenant, 0))

    # ---- lifecycle bookkeeping (engines call these) ---------------------
    def wf_record(self, wf: Workflow) -> WorkflowRecord:
        key = (wf.name, wf.instance)
        if key not in self.workflows:
            self.workflows[key] = WorkflowRecord(wf.name, wf.instance,
                                                 tenant=wf.tenant)
        return self.workflows[key]

    def note_submitted(self, wf: Workflow) -> WorkflowRecord:
        rec = self.wf_record(wf)
        rec.submitted_at = self.sim.now()
        return rec               # engines cache it for the _rec fast paths

    def note_first_create(self, wf: Workflow):
        rec = self.wf_record(wf)
        if rec.first_create < 0:
            rec.first_create = self.sim.now()

    # -- record-based fast paths: one wf_record lookup per WORKFLOW
    # (engines keep the record on their per-workflow state) instead of
    # one tuple-key dict probe per pod event
    def note_first_create_rec(self, rec: WorkflowRecord):
        if rec.first_create < 0:
            rec.first_create = self.sim.now()

    def note_start_rec(self, rec: WorkflowRecord, task_id: str):
        rec.starts.append((self.sim.now(), task_id))

    def note_finish_rec(self, rec: WorkflowRecord, task_id: str):
        rec.finishes[task_id] = self.sim.now()

    def note_admission_deferred(self, tenant: str):
        self.admission_deferrals[tenant] = \
            self.admission_deferrals.get(tenant, 0) + 1

    def note_quota_reject(self, tenant: str):
        self.quota_rejects[tenant] = self.quota_rejects.get(tenant, 0) + 1

    def note_gateway(self, kind: str, tenant: str):
        d = {"reject": self.gateway_rejects,
             "retry": self.gateway_retries,
             "shed": self.gateway_shed}[kind]
        d[tenant] = d.get(tenant, 0) + 1

    def _gateway_row(self, tenant: str) -> Optional[Dict[str, int]]:
        if not self.gateway_active:
            return None
        return {"rejects": self.gateway_rejects.get(tenant, 0),
                "retries": self.gateway_retries.get(tenant, 0),
                "shed": self.gateway_shed.get(tenant, 0)}

    def set_tenant_deadline(self, tenant: str, deadline_s: float):
        """Register the tenant's SLO: a completed workflow *hits* when
        submission -> namespace teardown stays within ``deadline_s``."""
        self.tenant_deadlines[tenant] = deadline_s

    def note_failed(self, wf: Workflow, reason: str = ""):
        rec = self.wf_record(wf)
        rec.failed = True
        rec.failure = reason

    def note_rescheduled(self, dt: float):
        """A node-loss-disrupted task got its replacement pod created
        ``dt`` seconds after the disruption (time-to-reschedule)."""
        self.resched_stat.add(dt)

    def note_ns_created(self, wf: Workflow):
        self.wf_record(wf).ns_created = self.sim.now()

    def note_ns_deleted(self, wf: Workflow):
        rec = self.wf_record(wf)
        rec.ns_deleted = self.sim.now()
        if self.fold_completed:
            agg = self.tenant_aggs.get(rec.tenant)
            if agg is None:
                agg = self.tenant_aggs[rec.tenant] = TenantAgg()
            agg.fold(rec, self.tenant_deadlines.get(rec.tenant, 0.0))
            del self.workflows[(rec.name, rec.instance)]

    def note_start(self, wf: Workflow, task_id: str):
        self.wf_record(wf).starts.append((self.sim.now(), task_id))

    def note_finish(self, wf: Workflow, task_id: str):
        self.wf_record(wf).finishes[task_id] = self.sim.now()

    # ---- resource sampling ------------------------------------------------
    def start_sampling(self):
        if self._sampling:
            return
        self._sampling = True
        if self.usage_mode == "event":
            return                 # accumulators run from construction

        streaming = self.sample_mode == "streaming"

        def sample():
            cpu, mem = self.cluster.used()
            # cluster-maintained per-tenant holdings; zero entries are
            # stripped to match the old holding-pod scan exactly
            by_tenant = {t: c for t, c
                         in self.cluster.tenant_holding_cpu.items() if c}
            if streaming:
                self.cpu_stat.add(cpu)
                self.mem_stat.add(mem)
                for t, c in by_tenant.items():
                    stat = self.tenant_cpu_stats.get(t)
                    if stat is None:
                        stat = self.tenant_cpu_stats[t] = StreamingStat()
                    stat.add(c)
            else:
                self.samples.append((self.sim.now(), cpu, mem))
                self.tenant_samples.append((self.sim.now(), by_tenant))
            if self._sampling:
                self.sim.after(self.p.sample_period, sample, daemon=True,
                               note="resource-sampler")

        sample()

    def stop_sampling(self):
        self._sampling = False
        if self.usage_mode == "event" and not self._usage_closed:
            # freeze the window at the stop instant — the clock may be
            # parked at the run horizon afterwards (Sim.run semantics),
            # and trailing idle time is not part of the measured run
            self._close_accs()
            self._usage_closed = True
            self.cluster.on_usage_change = None

    def _close_accs(self):
        if self._usage_closed:
            return
        # last_event_t, not t: after a bounded run the clock parks at the
        # horizon (Sim.run semantics) — trailing idle time up to an
        # arbitrary horizon must not dilute the usage integral.  During
        # event execution the two are identical.
        t = getattr(self.sim, "last_event_t", self.sim.t)
        self.cpu_acc.close(t)
        self.mem_acc.close(t)
        for acc in self.tenant_cpu_accs.values():
            acc.close(t)
        for acc in self.tenant_mem_accs.values():
            acc.close(t)

    # ---- derived metrics (the figures) -------------------------------------
    def pod_exec_times(self, workflow: Optional[str] = None,
                       include_virtual: bool = False) -> List[float]:
        if not self.cluster.retain_pod_log:
            raise RuntimeError(
                "pod_exec_times needs the per-pod log; this cluster was "
                "built with retain_pod_log=False — use "
                "cluster.exec_stat (streaming) instead")
        out = []
        for pod in self.cluster.pod_log:
            if workflow is not None and pod.workflow != workflow:
                continue
            if not include_virtual and pod.labels.get("virtual") == "1":
                continue
            if pod.deleted > 0 and pod.phase == SUCCEEDED:
                out.append(pod.deleted - pod.created)
        return out

    def avg_pod_exec_time(self, workflow: Optional[str] = None) -> float:
        xs = self.pod_exec_times(workflow)
        return sum(xs) / len(xs) if xs else float("nan")

    def lifecycles(self, name: str) -> List[float]:
        return [r.lifecycle for (n, _), r in self.workflows.items()
                if n == name and r.ns_deleted > 0]

    def avg_lifecycle(self, name: str) -> float:
        xs = self.lifecycles(name)
        return sum(xs) / len(xs) if xs else float("nan")

    def total_time(self, name: str) -> float:
        recs = [r for (n, _), r in self.workflows.items() if n == name]
        if not recs:
            return float("nan")
        return max(r.ns_deleted for r in recs) - min(r.ns_created for r in recs)

    def order_consistent(self, wf: Workflow) -> bool:
        """Start order must be a topological linearization of the DAG
        AND every dependency must have FINISHED before the dependent starts."""
        rec = self.wf_record(wf)
        started_at = {t: ts for ts, t in rec.starts}
        for ts, tid in rec.starts:
            for dep in wf.tasks[tid].inputs:
                if dep not in rec.finishes or rec.finishes[dep] > ts + 1e-9:
                    return False
                if dep not in started_at or started_at[dep] > ts + 1e-9:
                    return False
        return len(rec.starts) >= len(wf.tasks)

    def overall_usage(self) -> Tuple[float, float]:
        """Run-wide average (cpu_rate, mem_rate) vs allocatable; works
        in both sample modes (streaming keeps only the accumulators)
        and in event mode (exact step-function integral)."""
        cpu_a, mem_a = self.cluster.allocatable()
        if cpu_a == 0:
            return 0.0, 0.0
        if self.usage_mode == "event":
            self._close_accs()
            return self.cpu_acc.mean() / cpu_a, self.mem_acc.mean() / mem_a
        if self.sample_mode == "streaming":
            if not self.cpu_stat.count:
                return 0.0, 0.0
            return self.cpu_stat.mean / cpu_a, self.mem_stat.mean / mem_a
        if not self.samples:
            return 0.0, 0.0
        n = len(self.samples)
        cpu = sum(c for _, c, _ in self.samples) / n / cpu_a
        mem = sum(m for _, _, m in self.samples) / n / mem_a
        return cpu, mem

    def usage_summary(self) -> Dict[str, Dict[str, float]]:
        """Mean/peak/p95 usage rates vs allocatable, per resource.

        ``usage_mode="event"``: exact closed-form over the bind/release
        step function (``basis="event"``, plus the change count).
        ``"sampled"``: derived from the 0.5 s samples (full mode) or
        the streaming accumulators — the historical approximation.
        """
        cpu_a, mem_a = self.cluster.allocatable()
        if cpu_a == 0:
            return {}
        if self.usage_mode == "event":
            self._close_accs()
            out = {}
            for key, acc, alloc in (("cpu", self.cpu_acc, cpu_a),
                                    ("mem", self.mem_acc, mem_a)):
                out[key] = {"basis": "event", "changes": acc.changes,
                            "mean_rate": acc.mean() / alloc,
                            "peak_rate": acc.peak / alloc,
                            "p95_rate": acc.percentile(95) / alloc}
            return out
        out = {}
        if self.sample_mode == "streaming":
            pairs = (("cpu", self.cpu_stat, cpu_a),
                     ("mem", self.mem_stat, mem_a))
            for key, st, alloc in pairs:
                if not st.count:
                    continue
                out[key] = {"basis": "sampled", "samples": st.count,
                            "mean_rate": st.mean / alloc,
                            "peak_rate": st.max / alloc,
                            "p95_rate": st.percentile(95) / alloc}
            return out
        if self.samples:
            n = len(self.samples)
            for key, idx, alloc in (("cpu", 1, cpu_a), ("mem", 2, mem_a)):
                xs = sorted(s[idx] for s in self.samples)
                out[key] = {"basis": "sampled", "samples": n,
                            "mean_rate": sum(xs) / n / alloc,
                            "peak_rate": xs[-1] / alloc,
                            "p95_rate": xs[min(n - 1, round(0.95 * (n - 1)))]
                                        / alloc}
        return out

    def usage_rate_over(self, t0: float, t1: float) -> Tuple[float, float]:
        """Average (cpu_rate, mem_rate) over [t0, t1] vs allocatable."""
        cpu_a, mem_a = self.cluster.allocatable()
        window = [(t, c, m) for t, c, m in self.samples if t0 <= t <= t1]
        if not window or cpu_a == 0:
            return 0.0, 0.0
        cpu = sum(c for _, c, _ in window) / len(window) / cpu_a
        mem = sum(m for _, _, m in window) / len(window) / mem_a
        return cpu, mem

    def first_lifecycle_usage(self, name: str) -> Tuple[float, float]:
        recs = sorted((r for (n, _), r in self.workflows.items() if n == name),
                      key=lambda r: r.ns_created)
        if not recs:
            return 0.0, 0.0
        r = recs[0]
        return self.usage_rate_over(r.ns_created, r.ns_deleted)

    # ---- per-tenant aggregates (multi-tenant control plane) ---------------
    def tenant_records(self, tenant: str) -> List[WorkflowRecord]:
        return [r for r in self.workflows.values() if r.tenant == tenant]

    def tenant_makespan(self, tenant: str) -> float:
        """First submission -> last namespace deletion for the tenant."""
        recs = [r for r in self.tenant_records(tenant) if r.ns_deleted > 0]
        if not recs:
            return float("nan")
        t0 = min(r.submitted_at if r.submitted_at >= 0 else r.ns_created
                 for r in recs)
        return max(r.ns_deleted for r in recs) - t0

    def tenant_mean_cpu(self, tenant: str) -> float:
        """Time/sample-averaged bound CPU (milli-cores) for one tenant,
        available in every accounting mode: the exact step-function
        mean in ``usage_mode="event"``, the streaming accumulator mean
        in streaming-sample mode, the per-sample mean otherwise."""
        if self.usage_mode == "event":
            acc = self.tenant_cpu_accs.get(tenant)
            if acc is None:
                return 0.0
            self._close_accs()
            return acc.mean()
        if self.sample_mode == "streaming":
            stat = self.tenant_cpu_stats.get(tenant)
            return stat.mean if stat is not None and stat.count else 0.0
        if not self.tenant_samples:
            return 0.0
        return (sum(s.get(tenant, 0) for _, s in self.tenant_samples)
                / len(self.tenant_samples))

    def track_contention(self, tenants: List[str]):
        """Arm exact contended-CPU tracking for ``usage_mode="event"``
        (call before the run; the sampled modes derive contention from
        ``tenant_samples`` and need no arming)."""
        if self.usage_mode != "event":
            return
        self._contention = _ContentionTracker(tenants, self.cpu_acc.start_t)

    def tenant_mean_mem(self, tenant: str) -> float:
        """Time-averaged bound memory (Mi) for one tenant — exact step
        function mean, ``usage_mode="event"`` only (the sampled modes
        never tracked per-tenant memory)."""
        acc = self.tenant_mem_accs.get(tenant)
        if acc is None:
            return 0.0
        self._close_accs()
        return acc.mean()

    def contended_cpu(self, tenants: List[str]) -> Dict[str, float]:
        """Time-averaged bound CPU (milli-cores) per tenant over the
        window where ALL the given tenants hold resources — i.e. while
        they actually contend. Empty dict if they never overlapped.
        In event mode reads the exact tracker armed by
        ``track_contention``; otherwise filters the 0.5 s samples."""
        if self.usage_mode == "event":
            if self._contention is None or \
                    set(tenants) - set(self._contention.tenants):
                raise RuntimeError(
                    "contended_cpu in usage_mode='event' needs "
                    "track_contention(tenants) armed before the run")
            return self._contention.means()
        window = [s for _, s in self.tenant_samples
                  if all(s.get(t) for t in tenants)]
        if not window:
            return {}
        return {t: sum(s[t] for s in window) / len(window) for t in tenants}

    def _folded_aggs(self) -> Dict[str, TenantAgg]:
        """Per-tenant aggregates: folded completions + a non-mutating
        fold of whatever records are still live (insertion order, so
        float sums match the record-list path bit-for-bit)."""
        aggs = {t: replace(a) for t, a in self.tenant_aggs.items()}
        for rec in self.workflows.values():
            agg = aggs.get(rec.tenant)
            if agg is None:
                agg = aggs[rec.tenant] = TenantAgg()
            agg.fold(rec, self.tenant_deadlines.get(rec.tenant, 0.0))
        return aggs

    def export_partial(self) -> MetricsPartial:
        """Compact picklable extract for the shard result pipe."""
        usage: Dict[str, StepAccumulator] = {}
        basis = "event"
        cpu_a, mem_a = self.cluster.allocatable()
        if self.usage_mode == "event":
            self._close_accs()
            usage["cpu"] = _rate_acc(self.cpu_acc, cpu_a)
            usage["mem"] = _rate_acc(self.mem_acc, mem_a)
        return MetricsPartial(
            tenant_aggs=self._folded_aggs(),
            admission_deferrals=dict(self.admission_deferrals),
            quota_rejects=dict(self.quota_rejects),
            gateway_active=self.gateway_active,
            gateway_rejects=dict(self.gateway_rejects),
            gateway_retries=dict(self.gateway_retries),
            gateway_shed=dict(self.gateway_shed),
            tenant_deadlines=dict(self.tenant_deadlines),
            usage=usage, usage_basis=basis,
            resched=self.resched_stat)

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        if self.fold_completed:
            # record list is partial by design — go through the aggs
            return {
                tenant: agg.summary_row(
                    deferrals=self.admission_deferrals.get(tenant, 0),
                    quota_rejects=self.quota_rejects.get(tenant, 0),
                    deadline_s=self.tenant_deadlines.get(tenant, 0.0),
                    gateway=self._gateway_row(tenant))
                for tenant, agg in sorted(self._folded_aggs().items())
            }
        out: Dict[str, Dict[str, float]] = {}
        for tenant in sorted({r.tenant for r in self.workflows.values()}):
            recs = self.tenant_records(tenant)
            done = [r for r in recs if r.ns_deleted > 0 and not r.failed]
            delays = [r.queue_delay for r in done
                      if r.queue_delay == r.queue_delay]      # drop NaN
            lifecycles = [r.lifecycle for r in done]
            out[tenant] = {
                "workflows": float(len(recs)),
                "completed": float(len(done)),
                "failed": float(sum(1 for r in recs if r.failed)),
                "makespan": self.tenant_makespan(tenant),
                "avg_queue_delay": (sum(delays) / len(delays)
                                    if delays else float("nan")),
                "avg_lifecycle": (sum(lifecycles) / len(lifecycles)
                                  if lifecycles else float("nan")),
                "admission_deferrals":
                    float(self.admission_deferrals.get(tenant, 0)),
                "quota_rejects": float(self.quota_rejects.get(tenant, 0)),
                "preempted": float(sum(r.preempted for r in recs)),
                "node_lost": float(sum(r.node_lost for r in recs)),
                "rebalanced": float(sum(r.rebalanced for r in recs)),
            }
            gw = self._gateway_row(tenant)
            if gw is not None:
                out[tenant]["gateway_rejects"] = float(gw["rejects"])
                out[tenant]["gateway_retries"] = float(gw["retries"])
                out[tenant]["gateway_shed"] = float(gw["shed"])
            # per-stream SLO: deadline hit-rate over *completed* runs
            # (failed/unfinished workflows are neither hit nor miss —
            # they surface in "failed"); submission -> teardown wall
            deadline = self.tenant_deadlines.get(tenant, 0.0)
            if deadline > 0:
                hits = sum(
                    1 for r in done
                    if r.submitted_at >= 0
                    and r.ns_deleted - r.submitted_at <= deadline + 1e-9)
                out[tenant]["deadline_s"] = deadline
                out[tenant]["deadline_hits"] = float(hits)
                out[tenant]["deadline_hit_rate"] = (
                    hits / len(done) if done else float("nan"))
        return out
