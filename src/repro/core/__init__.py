"""The paper's primary contribution: the KubeAdaptor docking framework."""
from repro.core.calibration import (DEFAULT_CLUSTER, DEFAULT_PARAMS,
                                    ClusterParams, PaperCluster)
from repro.core.cluster import Cluster, PodObj
from repro.core.dag import Task, Workflow, make_workflow, parse_configmap
from repro.core.engine import KubeAdaptorEngine
from repro.core.injector import StreamSpec, WorkflowGateway, WorkflowInjector
from repro.core.resources import (ADMISSION_POLICIES, AdmissionArbiter,
                                  ResourceGatherer)
from repro.core.runner import (ENGINES, ControlPlane, RunResult,
                               run_experiment)
from repro.core.sim import Sim

__all__ = [
    "ClusterParams", "PaperCluster", "DEFAULT_PARAMS", "DEFAULT_CLUSTER",
    "Cluster", "PodObj", "Task", "Workflow", "make_workflow",
    "parse_configmap", "KubeAdaptorEngine", "ENGINES", "RunResult",
    "run_experiment", "Sim", "ControlPlane", "StreamSpec", "WorkflowGateway",
    "WorkflowInjector", "AdmissionArbiter", "ResourceGatherer",
    "ADMISSION_POLICIES",
]
