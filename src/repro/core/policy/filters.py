"""Filter stage plugins: hard per-tenant admission caps.

``TenantQuotaFilter`` enforces the ROADMAP's "hard caps enforced at
admission, not just shares": a tenant's *admitted* usage — informer
visible non-terminal pods plus not-yet-visible reservations — may
never exceed its registered quota, under ANY ordering policy.  The
check runs at the exact point a walk's headroom fit-check passes, so
with no quotas registered (``arbiter`` short-circuits before the
filter is consulted) legacy runs cannot diverge.

Scope: the cap gates *admission*.  Retried pods and speculative twins
re-reserve without re-admission (fault tolerance must not deadlock on
a full quota); they are bounded by the admission the original pod
passed, plus at most one twin.
"""
from __future__ import annotations

from repro.core.policy.pipeline import AdmissionFilter, AdmissionRequest


class TenantQuotaFilter(AdmissionFilter):
    name = "tenant-quota"

    def permits(self, req: AdmissionRequest) -> bool:
        arb = self.arb
        share = arb.tenant(req.tenant)
        qc, qm = share.quota_cpu_m, share.quota_mem_mi
        if not qc and not qm:
            return True
        pods = arb.inf.pods
        ledger = arb.ledger
        tenant = req.tenant
        if qc:
            used = (pods.nonterminal_cpu_by_tenant.get(tenant, 0)
                    + ledger.cpu_by_tenant.get(tenant, 0))
            if used + req.cpu > qc:
                return False
        if qm:
            used = (pods.nonterminal_mem_by_tenant.get(tenant, 0)
                    + ledger.mem_by_tenant.get(tenant, 0))
            if used + req.mem > qm:
                return False
        return True
