"""Stage contracts of the pluggable admission pipeline.

The arbiter (core/resources.py) is a thin driver over four stages,
mirroring the Kubernetes scheduler framework's extension points:

    QueueOrder   which pending request is considered next, and the
                 specialized grant walk over that order
    Filter       hard per-request admission gates consulted inside the
                 walks (tenant quota caps); a filtered request stays
                 pending but never bars other tenants' grants
    Reserve      the reservation ledger charging headroom for pods in
                 the informer-latency window (policy/reservations.py),
                 shared by every policy
    Permit       grant bookkeeping — the arbiter fires the engine's
                 create callback and updates tenant/grant counters
    Preempt      after an evaluate that left a starved high-priority
                 request pending, evict lower-priority RUNNING pods
                 (policy/preemption.py)

``QueueOrder`` subclasses with a specialized ``walk`` run the fast
path; plugins that only implement ``order``/``may_backfill`` run the
generic re-sort loop (the reference semantics every walk must match
bit-for-bit — pinned by tests/test_policy_pipeline.py against hashes
recorded on the pre-pipeline monolith). See policy/README.md for the
full contract a new plugin must honour.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.core.dag import Task


@dataclass(slots=True)
class AdmissionRequest:
    namespace: str
    tenant: str
    task: Task
    create: Callable[[Task], None]
    seq: int
    cpu: int = 0                   # cached task.resource_request()
    mem: int = 0
    deferred: bool = False
    quota_rejected: bool = False   # counted once per request

    def key(self) -> Tuple[str, str]:
        return (self.namespace, self.task.id)


@dataclass
class TenantShare:
    priority: int = 0
    weight: float = 1.0
    quota_cpu_m: int = 0           # hard cap on admitted cpu (0 = none)
    quota_mem_mi: int = 0          # hard cap on admitted mem (0 = none)
    granted: int = 0               # pods admitted over the run
    deferred: int = 0              # requests that had to wait at least once
    quota_rejects: int = 0         # requests ever bounced off the cap
    preempted: int = 0             # RUNNING pods evicted from this tenant

    @property
    def has_quota(self) -> bool:
        return bool(self.quota_cpu_m or self.quota_mem_mi)


class QueueOrder:
    """Ordering stage: owns the policy's index structures and walk.

    The arbiter calls ``on_add``/``on_remove`` as requests enter and
    leave the pending set, and ``walk(ac, am)`` once per evaluate on
    the fast path.  A subclass that does not override ``walk`` runs
    through the generic re-sort loop via ``order``/``may_backfill``
    (the pre-scale-out reference semantics).
    """

    name = "queue-order"
    # ranking depends on state every grant changes — the generic loop
    # must re-order after each grant (fair-share/drf set this)
    dynamic_order = False

    def bind(self, arbiter) -> "QueueOrder":
        self.arb = arbiter
        return self

    # -- index maintenance (fast path) ----------------------------------
    def on_add(self, req: AdmissionRequest):
        pass

    def on_remove(self, req: AdmissionRequest):
        pass

    # -- fast path: specialized walk; overriding enables it -------------
    walk = None                    # type: Optional[Callable]

    # -- starvation probe for the Preempt stage --------------------------
    def starvation_candidate(self) -> Optional[AdmissionRequest]:
        """Highest-urgency pending request the last walk could not
        grant, or None.  Only priority-aware orders implement this —
        preemption needs a victim/beneficiary priority relation."""
        return None

    # -- generic-loop contract (reference + custom policies) -------------
    def order(self, pending: List[AdmissionRequest],
              arbiter) -> List[AdmissionRequest]:
        return sorted(pending, key=lambda r: r.seq)

    def may_backfill(self, blocked: AdmissionRequest,
                     candidate: AdmissionRequest, arbiter) -> bool:
        return True


class LegacyOrder(QueueOrder):
    """Adapter for pre-pipeline policy objects (``order`` +
    ``may_backfill`` and nothing else) — they keep running through the
    generic loop exactly as before."""

    def __init__(self, policy):
        self.policy = policy
        self.name = getattr(policy, "name", type(policy).__name__)
        self.dynamic_order = getattr(policy, "dynamic_order", False)

    def order(self, pending, arbiter):
        return self.policy.order(pending, arbiter)

    def may_backfill(self, blocked, candidate, arbiter):
        return self.policy.may_backfill(blocked, candidate, arbiter)


class AdmissionFilter:
    """Filter stage: a hard gate on individual grants.

    ``permits`` is consulted inside the walks at the exact point the
    headroom fit-check passes.  A rejected request stays pending and is
    re-checked on later evaluates; rejection must NOT bar other
    requests (unlike a headroom block under priority ordering) — a
    tenant at its cap starves only itself.
    """

    name = "filter"

    def bind(self, arbiter) -> "AdmissionFilter":
        self.arb = arbiter
        return self

    def permits(self, req: AdmissionRequest) -> bool:
        return True


@dataclass
class PipelineSpec:
    """Resolved composition of one admission pipeline."""

    order: str = "fifo"            # QUEUE_ORDERS key
    preempt: bool = False          # enable the Preempt stage
    name: str = ""                 # preset name (defaults to order)

    def __post_init__(self):
        if not self.name:
            self.name = self.order
