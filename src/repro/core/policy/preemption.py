"""Preempt stage: evict lower-priority RUNNING pods for a starved
high-priority request.

Runs after every evaluate that leaves requests pending.  A request is
*starved* when all of:

  1. it is the ordering plugin's ``starvation_candidate()`` — the
     highest-priority oldest pending request (priority-aware orders
     only; fifo/fair-share return None and never preempt);
  2. it has been deferred at least once (waited a full evaluate);
  3. it is blocked by shared headroom, not by its tenant's own quota
     cap (evicting other tenants cannot help a capped tenant);
  4. tenants with strictly lower priority currently hold resources.

Victims are chosen deterministically — lowest tenant priority first,
then latest-started first (minimize wasted work), then name — and a
plan executes only if it fully covers the beneficiary's deficit
(matching kube-scheduler's "preemption must make the pod schedulable"
rule).  Eviction goes through the arbiter's ``evict`` callback
(``Cluster.evict_pod``); the evicted pod surfaces as a FAILED pod with
``evicted=True`` and the engine returns its task to the ready pool
WITHOUT charging the retry budget.  Freed headroom becomes visible to
admission through the normal informer path, and the beneficiary's
class is walked first on the next evaluate, so the freed room cannot
be stolen by lower classes (priority ordering bars them behind the
still-blocked request).

A per-tenant cooldown (``ClusterParams.preempt_cooldown_s``) bounds
eviction churn while a plan's deletions are still in flight.  Every
executed plan is appended to ``arbiter.preemption_log`` with the
condition snapshot it fired under — the starvation invariant is
asserted over this log by tests/test_policy_pipeline.py.
"""
from __future__ import annotations

from repro.core.cluster import RUNNING


class Preemptor:
    def __init__(self, cooldown_s: float = 5.0):
        self.cooldown_s = cooldown_s
        self._last_plan_t: dict = {}         # beneficiary tenant -> sim t

    def bind(self, arbiter) -> "Preemptor":
        self.arb = arbiter
        return self

    def maybe_preempt(self):
        arb = self.arb
        if arb.evict is None or not arb.pending:
            return
        cand = arb.order_plugin.starvation_candidate()
        if cand is None or not cand.deferred:
            return
        if not arb._permits(cand):
            return                           # capped: eviction can't help
        prio = arb.tenant(cand.tenant).priority
        # cheap gate: does any strictly-lower-priority tenant hold
        # resources at all? O(tenants), runs on every starved evaluate
        by_tenant = arb.inf.pods.nonterminal_cpu_by_tenant
        if not any(cpu > 0 and arb.tenant(t).priority < prio
                   for t, cpu in by_tenant.items()):
            return
        sim = arb.inf.pods.sim
        now = sim.now()
        last = self._last_plan_t.get(cand.tenant)
        if last is not None and now - last < self.cooldown_s:
            return
        ac, am = arb.available()
        need_cpu = cand.cpu - ac
        need_mem = cand.mem - am
        if need_cpu <= 0 and need_mem <= 0:
            return                           # not actually blocked
        victims = self._plan(prio, need_cpu, need_mem)
        if victims is None:
            return                           # can't cover the deficit
        self._last_plan_t[cand.tenant] = now
        evicted = []
        for pod in victims:
            if arb.evict(pod.namespace, pod.name):
                evicted.append(pod)
                arb.preemptions += 1
                arb.tenant(pod.tenant).preempted += 1
        arb.preemption_log.append({
            "t": now,
            "tenant": cand.tenant,
            "priority": prio,
            "task": cand.task.id,
            "namespace": cand.namespace,
            "deficit_cpu_m": max(need_cpu, 0),
            "deficit_mem_mi": max(need_mem, 0),
            "victims": [(p.namespace, p.name,
                         p.tenant) for p in evicted],
        })

    def _plan(self, prio: int, need_cpu: int, need_mem: int):
        """Smallest deterministic victim prefix covering the deficit,
        or None when even evicting every eligible pod would not."""
        arb = self.arb
        cands = []
        for pod in arb.inf.pods.lister():
            if pod.phase != RUNNING or pod.labels.get("virtual") == "1":
                continue
            vt = pod.tenant
            vprio = arb.tenant(vt).priority
            if vprio >= prio:
                continue
            cands.append((vprio, -pod.started, pod.namespace, pod.name, pod))
        cands.sort(key=lambda c: c[:4])
        victims = []
        for _vprio, _neg_started, _ns, _name, pod in cands:
            if need_cpu <= 0 and need_mem <= 0:
                break
            victims.append(pod)
            need_cpu -= pod.cpu_m
            need_mem -= pod.mem_mi
        if need_cpu > 0 or need_mem > 0:
            return None
        return victims
