"""Pluggable admission pipeline (see README.md for the stage contract).

Policy names resolve through two registries:

* ``QUEUE_ORDERS`` — ordering plugins: fifo, fifo-merge, priority,
  fair-share, drf.
* ``POLICY_PRESETS`` — every name ``ControlPlane(admission_policy=...)``
  accepts: fifo/priority/fair-share/drf plus composite presets that
  switch on extra stages (``quota`` = fifo-merge ordering — per-tenant
  FIFO queues, O(1) per capped tenant per round — with caps expected
  from the tenancy knobs; ``preempt`` = priority ordering with the
  Preempt stage armed).

The Filter stage (quota caps) is always present but short-circuits
until a tenant registers a cap, so orderings and caps compose freely —
``fair-share`` with quotas is valid, ``quota`` without caps degrades
to fifo.
"""
from __future__ import annotations

from repro.core.policy.filters import TenantQuotaFilter
from repro.core.policy.ordering import (QUEUE_ORDERS, DominantShareOrder,
                                        FairShareOrder, FifoMergeOrder,
                                        FifoOrder, PriorityOrder)
from repro.core.policy.pipeline import (AdmissionFilter, AdmissionRequest,
                                        LegacyOrder, PipelineSpec, QueueOrder,
                                        TenantShare)
from repro.core.policy.preemption import Preemptor
from repro.core.policy.reservations import ReservationLedger

POLICY_PRESETS = {
    "fifo": PipelineSpec(order="fifo"),
    "priority": PipelineSpec(order="priority"),
    "fair-share": PipelineSpec(order="fair-share"),
    "drf": PipelineSpec(order="drf"),
    "quota": PipelineSpec(order="fifo-merge", name="quota"),
    "preempt": PipelineSpec(order="priority", preempt=True, name="preempt"),
}


def resolve_policy(policy) -> PipelineSpec:
    """Accept a preset name, a PipelineSpec, a QueueOrder (class or
    instance), or a legacy order/may_backfill object."""
    if isinstance(policy, str):
        if policy not in POLICY_PRESETS:
            raise KeyError(policy)
        return POLICY_PRESETS[policy]
    if isinstance(policy, PipelineSpec):
        return policy
    return policy            # instantiated by the arbiter (see make_order)


def make_order(policy) -> QueueOrder:
    """Instantiate the QueueOrder for any accepted ``policy`` form."""
    spec = resolve_policy(policy)
    if isinstance(spec, PipelineSpec):
        return QUEUE_ORDERS[spec.order]()
    if isinstance(spec, type):
        spec = spec()
    if isinstance(spec, QueueOrder):
        return spec
    return LegacyOrder(spec)  # pre-pipeline policy object


__all__ = [
    "AdmissionFilter", "AdmissionRequest", "DominantShareOrder",
    "FairShareOrder", "FifoMergeOrder", "FifoOrder", "LegacyOrder",
    "PipelineSpec", "POLICY_PRESETS", "Preemptor", "PriorityOrder",
    "QUEUE_ORDERS", "QueueOrder", "ReservationLedger", "TenantQuotaFilter",
    "TenantShare", "make_order", "resolve_policy",
]
