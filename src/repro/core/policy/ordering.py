"""QueueOrder plugins: the admission-ordering stage.

fifo / priority / fair-share are the pre-pipeline monolith's three
policies, relocated here with their specialized O(1)-ish walks intact —
the executed grant sequence is bit-identical to the monolith (pinned
hashes in tests/test_policy_pipeline.py and tests/test_scale_core.py).
``drf`` is the first post-pipeline plugin: dominant-resource fair
share, closing the "fair-share ranks by CPU only" gap — a tenant's
rank is its *dominant* share, max(cpu/allocatable_cpu,
mem/allocatable_mem), divided by its weight.

Every walk reproduces the generic re-sort loop's grant sequence
EXACTLY (same order, same deferral counts): fifo walks the seq-ordered
pending dict; priority walks a bisect-maintained (-priority, seq) list
and stops once a blocked higher class makes further grants illegal;
fair-share and drf lazily merge per-tenant FIFO queues through a heap,
identical to sorting every request by (ratio, seq).  All stop early
when headroom is below the smallest pending request.  The Filter stage
hooks into each walk at the exact point the headroom fit-check passes
(``arb._permits``); with no quotas registered it is a constant-time
no-op, so legacy runs cannot diverge.
"""
from __future__ import annotations

import heapq
from bisect import bisect_left, insort
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.policy.pipeline import AdmissionRequest, QueueOrder


class FifoOrder(QueueOrder):
    name = "fifo"

    def order(self, pending: List[AdmissionRequest],
              arbiter) -> List[AdmissionRequest]:
        return sorted(pending, key=lambda r: r.seq)

    def may_backfill(self, blocked, candidate, arbiter) -> bool:
        # FIFO is work-conserving: smaller later tasks may slip past a
        # blocked one (the paper gatherer's greedy behaviour)
        return True

    def walk(self, ac: int, am: int):
        # generic fifo: one pass in seq order, always-backfill — i.e.
        # first-fit down the queue. The pending dict IS seq-ordered, so
        # walk it directly; pending deletion is deferred past the loop
        # (grants never mutate the dict — the engine's create path only
        # schedules sim events and charges reservations).
        arb = self.arb
        if arb._no_fit_possible(ac, am):
            return
        grants: List[AdmissionRequest] = []
        for req in arb.pending.values():
            if req.cpu <= ac and req.mem <= am and arb._permits(req):
                grants.append(req)
                arb._counters_remove(req)
                if arb._create_bookkeep(req):
                    ac -= req.cpu
                    am -= req.mem
                    if arb._no_fit_possible(ac, am):
                        break      # nothing further can fit
        for req in grants:
            del arb.pending[req.key()]


class PriorityOrder(QueueOrder):
    name = "priority"

    def __init__(self):
        # (-tenant priority, seq, request), bisect-sorted
        self._order: List[Tuple[int, int, AdmissionRequest]] = []

    def order(self, pending: List[AdmissionRequest],
              arbiter) -> List[AdmissionRequest]:
        def rank(r: AdmissionRequest):
            return (-arbiter.tenant(r.tenant).priority, r.seq)
        return sorted(pending, key=rank)

    def may_backfill(self, blocked, candidate, arbiter) -> bool:
        # never jump a *higher*-priority blocked request — a stream of
        # small low-priority tasks must not starve a big high-priority
        # one; backfill within the same class is fine (FIFO there)
        return (arbiter.tenant(candidate.tenant).priority
                >= arbiter.tenant(blocked.tenant).priority)

    def on_add(self, req: AdmissionRequest):
        insort(self._order,
               (-self.arb.tenant(req.tenant).priority, req.seq, req))

    def on_remove(self, req: AdmissionRequest):
        order = self._order
        # seq is unique, so tuple comparison never reaches the
        # request; a 2-tuple probe sorts just before its entry
        i = bisect_left(order, (-self.arb.tenant(req.tenant).priority,
                                req.seq))
        if i < len(order) and order[i][2] is req:
            del order[i]
        else:   # priority changed since insert: find by identity
            for j, entry in enumerate(order):
                if entry[2] is req:
                    del order[j]
                    break

    def starvation_candidate(self) -> Optional[AdmissionRequest]:
        # head of the (-priority, seq) order = the highest-priority
        # oldest pending request; after a walk it is blocked by
        # headroom or quota (anything fitting was granted)
        arb = self.arb
        order = self._order
        while order:
            req = order[0][2]
            if arb.pending.get(req.key()) is not req:
                del order[0]       # ghost entry from a grant/forget
                continue
            return req
        return None

    def walk(self, ac: int, am: int):
        # generic priority: one pass in (-priority, seq) order; a
        # blocked request bars every strictly-lower class behind it, so
        # the walk may stop at the first lower class after a block.
        # A quota-capped request is skipped WITHOUT barring lower
        # classes — it starves on its own cap, not on shared headroom.
        arb = self.arb
        if arb._no_fit_possible(ac, am):
            return
        order = self._order
        grants: List[AdmissionRequest] = []
        max_blocked_prio: Optional[int] = None
        i = 0
        while i < len(order):
            req = order[i][2]
            if arb.pending.get(req.key()) is not req:
                del order[i]       # ghost entry from a priority change
                continue
            prio = arb.tenant(req.tenant).priority
            if max_blocked_prio is not None and prio < max_blocked_prio:
                break              # all remaining are lower still
            if req.cpu <= ac and req.mem <= am:
                if not arb._permits(req):
                    i += 1
                    continue
                del order[i]
                grants.append(req)
                arb._counters_remove(req)
                if arb._create_bookkeep(req):
                    ac -= req.cpu
                    am -= req.mem
                    if arb._no_fit_possible(ac, am):
                        break
                continue           # entries shifted left: same index
            if max_blocked_prio is None or prio > max_blocked_prio:
                max_blocked_prio = prio
            i += 1
        for req in grants:
            del arb.pending[req.key()]


class _TenantMergeOrder(QueueOrder):
    """Shared lazy-merge walk over per-tenant FIFO queues.

    The generic dynamic-order loop re-sorts all requests by
    (ratio, seq) and grants the first fit, once per grant.  The merge
    pops requests in exactly that order (seq ties across equal-ratio
    tenants included) without materializing it.  Subclasses define the
    per-round usage snapshot and the tenant ranking over it.

    Batched multi-grant (ISSUE 5): one walk call grants EVERY fitting
    request.  The pre-batched walk re-entered per grant — a fresh
    usage snapshot (O(tenants) dict copies + a ledger sync) and a full
    heap rebuild each time, the per-grant constant that capped the
    >100k-workflow tier.  The single-pass walk instead updates
    incrementally after each grant, which is EXACTLY the generic
    loop's re-sort semantics because within one evaluate:

    * headroom only shrinks, so a request that already failed its
      fit-check can never fit later in the pass — re-checking it (what
      a round restart does) cannot grant it;
    * a grant changes only the GRANTING tenant's usage (the informer
      cache cannot move mid-evaluate; the only ledger change is the
      grant's own reservation), and ``_rank`` depends only on the
      tenant's own usage entry — so re-ranking the whole heap equals
      re-ranking that one tenant;
    * ``_walk_sync`` after each grant runs the same O(changes) ledger
      sync the per-round ``_round_usage`` ran (its only live candidate
      is the reservation just charged), so quota/rank state matches
      the round-restart value even when the reservation is immediately
      dropped against a stale non-terminal cache entry;
    * the granting tenant re-enters at its HEAD (not past the granted
      position): its earlier requests must be re-probed under the
      tenant's increased usage — a quota cap that now binds at the
      head sits the tenant out for the rest of the pass, exactly as a
      round restart would.

    Equivalence is pinned by the fast==generic tests for fair-share,
    drf, and the capped merge walks (tests/test_scale_core.py,
    tests/test_policy_pipeline.py, tests/test_informer_views.py).
    Contract for subclasses: ``_rank(tenant, usage)`` and
    ``_walk_rank(tenant)`` must read only ``tenant``'s own usage —
    that locality is what makes frozen-at-push heap ranks exact.
    """

    dynamic_order = True
    # False = strict FIFO inside a tenant: nothing passes a blocked
    # head for ANY reason (the fifo-merge/quota discipline)
    intra_tenant_backfill = True

    def __init__(self):
        # per-tenant FIFO of requests (lazy-deleted during the walk)
        self._by_tenant: Dict[str, Deque[AdmissionRequest]] = {}

    def on_add(self, req: AdmissionRequest):
        self._by_tenant.setdefault(req.tenant, deque()).append(req)

    # fair-share per-tenant deques are lazy-deleted during the walk:
    # on_remove is a no-op

    def _round_usage(self):
        """Usage snapshot for the generic order() reference path; must
        trigger the same reservation sync the walk's ``_walk_sync``
        does."""
        raise NotImplementedError

    def _rank(self, tenant: str, usage) -> float:
        raise NotImplementedError

    # -- walk-path ranking: live references instead of per-walk copies.
    # A rank read at heap-push time equals the copied-snapshot rank at
    # the same instant, and between pushes only the GRANTING tenant's
    # entries move (reserve() updates the ledger maps in place), so
    # frozen-at-push heap entries stay exactly the generic pass's
    # ranks.  Ledger re-sync after a grant is O(changes): its only
    # live candidate is the reservation the grant just charged.
    def _walk_sync(self):
        arb = self.arb
        arb.ledger.sync(arb.inf.pods)

    def _walk_rank(self, tenant: str) -> float:
        raise NotImplementedError

    def order(self, pending: List[AdmissionRequest],
              arbiter) -> List[AdmissionRequest]:
        usage = self._round_usage()

        def rank(r: AdmissionRequest):
            return (self._rank(r.tenant, usage), r.seq)
        ordered = sorted(pending, key=rank)
        if not arbiter._quota_active:
            return ordered
        # head-of-line under caps, mirroring the walk: once a tenant's
        # first-ranked request is quota-blocked (checked BEFORE the
        # headroom fit, same as the walk's pop), the tenant
        # contributes nothing more this pass.  _permits is the
        # counting probe — both paths count the same blocked heads,
        # once per request.
        out: List[AdmissionRequest] = []
        capped: set = set()
        for r in ordered:
            if r.tenant in capped:
                continue
            if not arbiter._permits(r):
                capped.add(r.tenant)
                continue
            out.append(r)
        return out

    def may_backfill(self, blocked, candidate, arbiter) -> bool:
        return True

    def walk(self, ac: int, am: int):
        arb = self.arb
        pending = arb.pending
        by_tenant = self._by_tenant
        if not pending:
            return
        # one sync per WALK (the per-round re-sync it replaces is a
        # no-op mid-evaluate except for grant reservations, which are
        # re-synced at O(changes) after each grant)
        self._walk_sync()
        if arb._no_fit_possible(ac, am):
            return
        rank = self._walk_rank
        heap = []
        for tenant, q in by_tenant.items():
            while q and pending.get(q[0].key()) is not q[0]:
                q.popleft()        # granted/forgotten leftovers
            if q:
                heap.append((rank(tenant), q[0].seq, tenant, 0))
        heapq.heapify(heap)
        backfill = self.intra_tenant_backfill
        while heap:
            ratio, _seq, tenant, idx = heapq.heappop(heap)
            q = by_tenant[tenant]
            req = q[idx]           # push-time staleness check keeps
            #                        entries live
            if not arb._permits(req):
                # quota head-of-line (checked before the headroom
                # fit): the tenant sits out this pass — its queue
                # is NOT re-scanned behind the capped head (at a
                # 1000-workflow backlog that rescan made every
                # evaluate O(pending))
                continue
            if req.cpu <= ac and req.mem <= am:
                if arb._grant(req):
                    ac -= req.cpu
                    am -= req.mem
                # batched multi-grant: keep walking instead of
                # re-entering.  Only this tenant's rank can have
                # changed; it restarts at its head (see class doc).
                self._walk_sync()
                if arb._no_fit_possible(ac, am):
                    return
                while q and pending.get(q[0].key()) is not q[0]:
                    q.popleft()
                if q:
                    heapq.heappush(heap, (rank(tenant), q[0].seq, tenant, 0))
                continue
            if not backfill:
                continue           # strict FIFO within the tenant
            nxt = idx + 1
            while nxt < len(q) and pending.get(q[nxt].key()) is not q[nxt]:
                nxt += 1
            if nxt < len(q):
                heapq.heappush(heap, (ratio, q[nxt].seq, tenant, nxt))


class FifoMergeOrder(_TenantMergeOrder):
    """FIFO admission realized as a k-way merge of per-tenant queues —
    the ``quota`` preset's ordering.  Discipline: strict FIFO inside a
    tenant (nothing passes a blocked head — a tenant at its quota cap
    or out of headroom waits in line), arrival order across tenant
    heads, work-conserving across tenants.  Unlike the global ``fifo``
    walk, a capped tenant costs O(1) per round instead of an
    O(own-backlog) rescan per evaluate, which is what lets hard quotas
    run at the 1000-workflow tier."""

    name = "fifo-merge"
    intra_tenant_backfill = False

    def _round_usage(self):
        # ranking ignores usage, but the quota filter reads the
        # reservation ledger + informer aggregates: sync once per
        # walk, the same cadence every dynamic-order policy keeps
        arb = self.arb
        arb.ledger.sync(arb.inf.pods)
        return None

    def _rank(self, tenant: str, usage) -> float:
        return 0.0                 # heap ties on head seq = arrival order

    def _walk_rank(self, tenant: str) -> float:
        return 0.0

    def order(self, pending: List[AdmissionRequest],
              arbiter) -> List[AdmissionRequest]:
        # generic-loop reference: only tenant HEADS are candidates
        # (strict intra-tenant FIFO), merged in arrival order; a
        # quota-blocked head drops its tenant from the pass (counted
        # by the _permits probe, exactly like the walk's pop)
        self._round_usage()
        heads: Dict[str, AdmissionRequest] = {}
        for r in pending:
            h = heads.get(r.tenant)
            if h is None or r.seq < h.seq:
                heads[r.tenant] = r
        out = sorted(heads.values(), key=lambda r: r.seq)
        if arbiter._quota_active:
            out = [r for r in out if arbiter._permits(r)]
        return out


class FairShareOrder(_TenantMergeOrder):
    """Weighted max-min: most-underserved tenant (in-use cpu / weight)
    goes first; FIFO inside a tenant."""

    name = "fair-share"

    def _round_usage(self):
        return self.arb.tenant_usage_cpu()

    def _rank(self, tenant: str, usage) -> float:
        share = self.arb.tenant(tenant)
        return usage.get(tenant, 0) / max(share.weight, 1e-9)

    def _walk_rank(self, tenant: str) -> float:
        arb = self.arb
        held = (arb.inf.pods.nonterminal_cpu_by_tenant.get(tenant, 0)
                + arb.ledger.cpu_by_tenant.get(tenant, 0))
        return held / max(arb.tenant(tenant).weight, 1e-9)


class DominantShareOrder(_TenantMergeOrder):
    """Dominant-resource fairness (DRF): rank tenants by their dominant
    share — max(cpu held / allocatable cpu, mem held / allocatable mem)
    — divided by weight.  A memory-hog tenant can no longer monopolize
    the cluster by looking underserved on the CPU axis."""

    name = "drf"

    def _round_usage(self):
        cpu_map, mem_map = self.arb.tenant_usage()
        cpu_a, mem_a = self.arb.allocatable()
        return (cpu_map, mem_map, max(cpu_a, 1), max(mem_a, 1))

    def _rank(self, tenant: str, usage) -> float:
        cpu_map, mem_map, cpu_a, mem_a = usage
        share = self.arb.tenant(tenant)
        dominant = max(cpu_map.get(tenant, 0) / cpu_a,
                       mem_map.get(tenant, 0) / mem_a)
        return dominant / max(share.weight, 1e-9)

    def _walk_rank(self, tenant: str) -> float:
        arb = self.arb
        pods = arb.inf.pods
        ledger = arb.ledger
        cpu_a, mem_a = arb.allocatable()
        cpu = (pods.nonterminal_cpu_by_tenant.get(tenant, 0)
               + ledger.cpu_by_tenant.get(tenant, 0))
        mem = (pods.nonterminal_mem_by_tenant.get(tenant, 0)
               + ledger.mem_by_tenant.get(tenant, 0))
        dominant = max(cpu / max(cpu_a, 1), mem / max(mem_a, 1))
        return dominant / max(arb.tenant(tenant).weight, 1e-9)


QUEUE_ORDERS = {
    "fifo": FifoOrder,
    "fifo-merge": FifoMergeOrder,
    "priority": PriorityOrder,
    "fair-share": FairShareOrder,
    "drf": DominantShareOrder,
}
