"""Reserve stage: the reservation ledger shared by every policy.

A pod granted admission is invisible to the informer cache for one
watch+informer latency window; without a ledger two workflows could
double-spend the same headroom inside it.  The ledger charges cpu/mem
for every pod whose creation is in flight and reconciles against the
informer cache by *candidates only*: a reservation can become droppable
only if its cache entry was written since the last sync (the pod
informer's ``touched`` list — this ledger is its single consumer) or it
was added since then, so the sync is O(changes) while producing exactly
the full scan's drop set (the argument that carried the 10k-workflow
tier, see ``sync``).

Per-tenant cpu AND mem running totals are kept so quota filtering and
dominant-resource ranking read tenant usage at O(1).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.cluster import PENDING, RUNNING


class ReservationLedger:
    def __init__(self):
        # (ns, pod name) -> (tenant, cpu, mem, reserved_at)
        self.reserved: Dict[Tuple[str, str], Tuple[str, int, int, float]] = {}
        self.cpu = 0
        self.mem = 0
        self.cpu_by_tenant: Dict[str, int] = {}
        self.mem_by_tenant: Dict[str, int] = {}
        self._fresh: List[Tuple[str, str]] = []     # added since last sync

    def reserve(self, namespace: str, name: str, tenant: str,
                cpu: int, mem: int, now: float):
        """Charge headroom for a pod whose creation is in flight but
        not yet visible in the informer cache (idempotent per pod
        name).  The timestamp lets ``release_if_current`` tell which
        incarnation of a reused pod name a reservation belongs to."""
        key = (namespace, name)
        if key not in self.reserved:
            self.reserved[key] = (tenant, cpu, mem, now)
            self.cpu += cpu
            self.mem += mem
            by_c, by_m = self.cpu_by_tenant, self.mem_by_tenant
            by_c[tenant] = by_c.get(tenant, 0) + cpu
            by_m[tenant] = by_m.get(tenant, 0) + mem
            self._fresh.append(key)

    def _uncharge(self, held: Tuple[str, int, int, float]):
        tenant, cpu, mem, _t = held
        self.cpu -= cpu
        self.mem -= mem
        by_c, by_m = self.cpu_by_tenant, self.mem_by_tenant
        left = by_c[tenant] - cpu
        if left:
            by_c[tenant] = left
        else:
            del by_c[tenant]
        left = by_m[tenant] - mem
        if left:
            by_m[tenant] = left
        else:
            del by_m[tenant]

    def drop(self, key: Tuple[str, str]):
        held = self.reserved.pop(key, None)
        if held is not None:
            self._uncharge(held)

    def release_if_current(self, key: Tuple[str, str], pod_created: float):
        """A pod was removed from the apiserver: drop its reservation
        unless the reservation was made *after* the removed pod was
        created — then it belongs to a replacement incarnation (a
        retried pod re-created under the same name before the old
        DELETED event reached the informer) and must survive."""
        held = self.reserved.get(key)
        if held is not None and held[3] <= pod_created:
            self.drop(key)

    def drop_namespace(self, namespace: str):
        for key in [k for k in self.reserved if k[0] == namespace]:
            self.drop(key)

    def sync(self, pods_informer):
        """Drop reservations for pods the informer now sees as
        non-terminal — from that point the informer aggregates account
        for them.  (A FAILED/SUCCEEDED cache entry can be a *previous*
        incarnation of a retried pod name, so it doesn't count.)

        Only candidate keys are checked instead of the whole ledger:
        any key already checked and kept, with an untouched cache
        entry, would be kept again — exactly the full scan's drop set,
        at O(changes) cost."""
        touched = pods_informer.touched
        fresh = self._fresh
        reserved = self.reserved
        if not reserved:
            if touched:
                touched.clear()
            if fresh:
                fresh.clear()
            return
        cache = pods_informer.cache
        for candidates in (touched, fresh):
            for key in candidates:
                held = reserved.get(key)
                if held is None:
                    continue
                pod = cache.get(key)
                if pod is not None and pod.phase in (PENDING, RUNNING):
                    del reserved[key]
                    self._uncharge(held)
        if touched:
            touched.clear()
        if fresh:
            fresh.clear()
