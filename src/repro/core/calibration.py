"""Frozen control-plane latency constants (one global set, all figures).

Derivation (see EXPERIMENTS.md §Calibration): the KubeAdaptor column of
the paper fixes the per-pod overhead budget — avg task-pod execution
time ~12.8s with a 10s stress payload leaves ~2.8s of pod lifecycle
overhead, split between container start (image check + create + NFS
mount) and deletion, with the informer contributing its ~50ms cache
latency. Baseline-specific constants come from the tools' documented
behaviour (kubectl round-trips for Batch Job; Argo's controller
reconcile cadence) and were tuned ONCE against the Montage lifecycle
column only — every other number in EXPERIMENTS.md (other 3 workflows,
task-exec times, resource rates, 100-run totals) is emergent.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ClusterParams:
    # apiserver + informer
    api_latency: float = 0.05          # per CRUD round-trip
    watch_latency: float = 0.02        # apiserver -> watch stream
    informer_latency: float = 0.05     # watch -> local cache + handler
    resync_interval: float = 30.0      # informer periodic resync
    # scheduler (the level-2 "K8s" scheduler: disordered by design)
    sched_cycle: float = 0.08
    # pod lifecycle
    pod_start_latency: float = 1.20    # image-present check + container create
    pvc_mount_latency: float = 0.30    # NFS dynamic-volume mount per pod
    pod_delete_latency: float = 1.15   # container teardown
    # namespace / storage
    ns_create_latency: float = 0.40
    ns_delete_latency: float = 0.60
    pvc_create_latency: float = 0.50   # StorageClass dynamic provisioning
    # Batch Job baseline (kubectl-driven, level-synchronized)
    kubectl_latency: float = 1.20      # CLI spawn + apiserver round-trip
    batch_poll_interval: float = 3.0   # kubectl-get status polling
    batch_pod_poll: float = 0.70       # per-pod status fetch within a poll
    # Argo-like baseline (controller reconcile loop)
    argo_reconcile: float = 7.0        # resync/requeue cadence per step
    argo_controller_overhead: float = 1.0   # DAG processing per cycle
    argo_pod_overhead: float = 0.5     # per-pod template instantiation
    argo_workflow_init: float = 2.0    # CRD submission + controller pickup
    # fault tolerance / stragglers
    max_retries: int = 3
    on_retry_exhausted: str = "raise"   # "raise": RuntimeError tears down the
                                        # whole run (historical behaviour);
                                        # "fail-workflow": mark the workflow
                                        # failed, clean up its namespace, let
                                        # every other workflow finish
    create_retry_backoff: float = 0.25  # wait before re-creating after
                                        # AlreadyExists delete+retry (§4.5);
                                        # avoids hot-looping the apiserver
    preempt_cooldown_s: float = 5.0     # min gap between preemption plans
                                        # per starved tenant (bounds
                                        # eviction churn while a plan's
                                        # deletions are still in flight)
    # transient apiserver faults (chaos plane, ISSUE 7): capped
    # exponential backoff with jitter for retryable "Unavailable"
    # errors on pod create/delete — generalizes the AlreadyExists
    # delete+retry above
    api_fault_backoff_s: float = 0.25   # base delay, doubled per attempt
    api_fault_backoff_max_s: float = 8.0
    max_api_fault_retries: int = 8      # then RuntimeError (outage, not blip)
    straggler_factor: float = 1.5      # speculative copy beyond x expected
    straggler_min_wait: float = 5.0
    # metrics
    sample_period: float = 0.5         # resource usage sampling (paper: 0.5s)


@dataclass(frozen=True)
class PaperCluster:
    """§5.1: 1 master + 6 workers, 8-core/16GB each; master unschedulable."""
    n_nodes: int = 6
    node_cpu_m: int = 8000             # 48000m allocatable total (Fig 9)
    node_mem_mi: int = 15312           # 91872Mi allocatable total (Fig 10)

    def nodes(self) -> Tuple[Tuple[str, int, int], ...]:
        return tuple((f"node{i+1}", self.node_cpu_m, self.node_mem_mi)
                     for i in range(self.n_nodes))


@dataclass(frozen=True)
class NodeClass:
    """One capacity class in a heterogeneous cluster (the K3s-style
    edge-zoo: big/small boxes, cpu- vs mem-skewed shapes).  ``weight``
    is the class's relative share of the node count."""
    name: str
    cpu_m: int
    mem_mi: int
    weight: int = 1


@dataclass(frozen=True)
class HeteroCluster:
    """Heterogeneous cluster config: ``n_nodes`` machines drawn from
    ``classes`` by deterministic weighted round-robin (node ``i`` gets
    the ``i mod cycle``-th entry of the weight-expanded class cycle),
    so a fixed config always yields the same node list and any
    ``dataclasses.replace(cfg, n_nodes=k)`` slice (the shard
    partition) is a prefix-consistent mix of the same classes.

    Drop-in for ``PaperCluster`` everywhere a cluster config is
    consumed: ``nodes()`` has the same shape and the per-node
    capacities flow through ``Cluster`` unchanged (node state, the
    native free/ready mirrors and ``allocatable()`` are all per-node
    already).  Every class must fit the paper task (1200m/1200Mi) or
    its nodes can never bind a pod."""
    n_nodes: int = 6
    classes: Tuple[NodeClass, ...] = (
        NodeClass("big", 16000, 30624, weight=1),
        NodeClass("small", 4000, 7656, weight=2),
    )

    def class_cycle(self) -> Tuple[NodeClass, ...]:
        cycle: Tuple[NodeClass, ...] = ()
        for c in self.classes:
            cycle += (c,) * max(1, c.weight)
        return cycle

    def nodes(self) -> Tuple[Tuple[str, int, int], ...]:
        cycle = self.class_cycle()
        return tuple((f"node{i+1}", cycle[i % len(cycle)].cpu_m,
                      cycle[i % len(cycle)].mem_mi)
                     for i in range(self.n_nodes))

    def mix_label(self) -> str:
        return "+".join(f"{c.name}x{c.weight}({c.cpu_m}m/{c.mem_mi}Mi)"
                        for c in self.classes)


# preset mixes: averages match the uniform paper node (8000m/15312Mi
# per node when n_nodes divides the cycle length), so hetero tiers
# keep total allocatable comparable to the uniform tiers
NODE_MIXES = {
    "big-small": (
        NodeClass("big", 16000, 30624, weight=1),     # 2x paper node
        NodeClass("small", 4000, 7656, weight=2),     # paper node / 2
    ),
    "cpu-mem-skew": (
        NodeClass("cpu-heavy", 12000, 7656, weight=1),
        NodeClass("mem-heavy", 4000, 22968, weight=1),
    ),
}


def hetero_cluster(n_nodes: int, mix: str = "big-small") -> HeteroCluster:
    """A preset heterogeneous config (see ``NODE_MIXES``)."""
    if mix not in NODE_MIXES:
        raise ValueError(f"unknown node mix {mix!r}; "
                         f"expected one of {sorted(NODE_MIXES)}")
    return HeteroCluster(n_nodes=n_nodes, classes=NODE_MIXES[mix])


def node_class_names(cfg) -> Tuple[str, ...]:
    """Per-node class name for any cluster config, in roster order:
    hetero configs follow the weighted round-robin ``class_cycle``
    (so any prefix slice — the shard partition — keeps consistent
    labels), uniform configs collapse to a single ``"node"`` class.
    The autoscaler's derived node pools group the roster by these."""
    n = cfg.n_nodes
    if hasattr(cfg, "class_cycle"):
        cycle = cfg.class_cycle()
        return tuple(cycle[i % len(cycle)].name for i in range(n))
    return ("node",) * n


# Paper workload: stress -c 1 -m 100 -t 5 -> CPU+mem busy ~10s total,
# requests = limits = 1200m / 1200Mi.
TASK_DURATION_S = 10.0
TASK_CPU_M = 1200
TASK_MEM_MI = 1200

DEFAULT_PARAMS = ClusterParams()
DEFAULT_CLUSTER = PaperCluster()
