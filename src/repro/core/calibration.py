"""Frozen control-plane latency constants (one global set, all figures).

Derivation (see EXPERIMENTS.md §Calibration): the KubeAdaptor column of
the paper fixes the per-pod overhead budget — avg task-pod execution
time ~12.8s with a 10s stress payload leaves ~2.8s of pod lifecycle
overhead, split between container start (image check + create + NFS
mount) and deletion, with the informer contributing its ~50ms cache
latency. Baseline-specific constants come from the tools' documented
behaviour (kubectl round-trips for Batch Job; Argo's controller
reconcile cadence) and were tuned ONCE against the Montage lifecycle
column only — every other number in EXPERIMENTS.md (other 3 workflows,
task-exec times, resource rates, 100-run totals) is emergent.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ClusterParams:
    # apiserver + informer
    api_latency: float = 0.05          # per CRUD round-trip
    watch_latency: float = 0.02        # apiserver -> watch stream
    informer_latency: float = 0.05     # watch -> local cache + handler
    resync_interval: float = 30.0      # informer periodic resync
    # scheduler (the level-2 "K8s" scheduler: disordered by design)
    sched_cycle: float = 0.08
    # pod lifecycle
    pod_start_latency: float = 1.20    # image-present check + container create
    pvc_mount_latency: float = 0.30    # NFS dynamic-volume mount per pod
    pod_delete_latency: float = 1.15   # container teardown
    # namespace / storage
    ns_create_latency: float = 0.40
    ns_delete_latency: float = 0.60
    pvc_create_latency: float = 0.50   # StorageClass dynamic provisioning
    # Batch Job baseline (kubectl-driven, level-synchronized)
    kubectl_latency: float = 1.20      # CLI spawn + apiserver round-trip
    batch_poll_interval: float = 3.0   # kubectl-get status polling
    batch_pod_poll: float = 0.70       # per-pod status fetch within a poll
    # Argo-like baseline (controller reconcile loop)
    argo_reconcile: float = 7.0        # resync/requeue cadence per step
    argo_controller_overhead: float = 1.0   # DAG processing per cycle
    argo_pod_overhead: float = 0.5     # per-pod template instantiation
    argo_workflow_init: float = 2.0    # CRD submission + controller pickup
    # fault tolerance / stragglers
    max_retries: int = 3
    on_retry_exhausted: str = "raise"   # "raise": RuntimeError tears down the
                                        # whole run (historical behaviour);
                                        # "fail-workflow": mark the workflow
                                        # failed, clean up its namespace, let
                                        # every other workflow finish
    create_retry_backoff: float = 0.25  # wait before re-creating after
                                        # AlreadyExists delete+retry (§4.5);
                                        # avoids hot-looping the apiserver
    preempt_cooldown_s: float = 5.0     # min gap between preemption plans
                                        # per starved tenant (bounds
                                        # eviction churn while a plan's
                                        # deletions are still in flight)
    # transient apiserver faults (chaos plane, ISSUE 7): capped
    # exponential backoff with jitter for retryable "Unavailable"
    # errors on pod create/delete — generalizes the AlreadyExists
    # delete+retry above
    api_fault_backoff_s: float = 0.25   # base delay, doubled per attempt
    api_fault_backoff_max_s: float = 8.0
    max_api_fault_retries: int = 8      # then RuntimeError (outage, not blip)
    straggler_factor: float = 1.5      # speculative copy beyond x expected
    straggler_min_wait: float = 5.0
    # metrics
    sample_period: float = 0.5         # resource usage sampling (paper: 0.5s)


@dataclass(frozen=True)
class PaperCluster:
    """§5.1: 1 master + 6 workers, 8-core/16GB each; master unschedulable."""
    n_nodes: int = 6
    node_cpu_m: int = 8000             # 48000m allocatable total (Fig 9)
    node_mem_mi: int = 15312           # 91872Mi allocatable total (Fig 10)

    def nodes(self) -> Tuple[Tuple[str, int, int], ...]:
        return tuple((f"node{i+1}", self.node_cpu_m, self.node_mem_mi)
                     for i in range(self.n_nodes))


# Paper workload: stress -c 1 -m 100 -t 5 -> CPU+mem busy ~10s total,
# requests = limits = 1200m / 1200Mi.
TASK_DURATION_S = 10.0
TASK_CPU_M = 1200
TASK_MEM_MI = 1200

DEFAULT_PARAMS = ClusterParams()
DEFAULT_CLUSTER = PaperCluster()
