"""Periodic descheduler: evict-to-rebalance off overloaded nodes.

The CPU-aware K3s scheduler (SNIPPETS.md) pairs utilization-scored
placement with a 30 s daemon that offloads pods from nodes ≥90% busy
so they reschedule onto cooler ones.  This is that daemon for the
simulated cluster: a sim daemon timer (``Sim.after(daemon=True)``, so
an armed descheduler never keeps an otherwise-drained run alive)
wakes every ``interval_s``, checks each node's live utilization
(``Cluster.node_util``: max of bound cpu/mem fraction — O(nodes) per
tick, pods are only scanned when something is actually hot), and
evicts up to ``max_evict_per_node`` RUNNING pods from every node at
or above ``util_threshold`` via ``Cluster.rebalance_evict``.

Evicted pods surface as FAILED with ``evicted=True`` AND
``rebalanced=True``, so the engine's requeue machinery (the PR-4/PR-7
path preemptions and node losses already ride) re-admits the task
with NO retry-budget charge, and recovery metrics count the offload
separately (``WorkflowRecord.rebalanced``).

Determinism: everything is a pure function of cluster state — nodes
are visited in the canonical ``_node_seq`` order, victims on a hot
node follow the declared ``victim`` policy (``"youngest"``: latest
``started``, least sunk work; ``"largest-request"``: biggest cpu/mem
ask, most relief per eviction; pod name tie-breaks both), and NO
random draw is ever consumed,
so arming a descheduler does not move the scheduler RNG word stream
and a fixed seed replays exactly.  Thrash guard: a pod is only
offloaded when some OTHER ready node below the threshold could fit
it right now — on a uniformly hot cluster the daemon idles instead of
cycling pods between equally-busy nodes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.cluster import RUNNING, Cluster
from repro.core.sim import Sim


VICTIM_POLICIES = ("youngest", "largest-request")


@dataclass(frozen=True)
class DeschedulePolicy:
    """Picklable descheduler knobs (frozen: shareable across shards)."""
    interval_s: float = 30.0           # wake cadence (K3s: 30 s)
    util_threshold: float = 0.90       # node is "hot" at >= this
    max_evict_per_node: int = 1        # offloads per hot node per tick
    start_after_s: float = 0.0         # calm period before the first tick
    victim: str = "youngest"           # eviction order on a hot node:
                                       # "youngest" = least sunk work,
                                       # "largest-request" = biggest
                                       # utilization relief per eviction


class Descheduler:
    """The live daemon: arm once per run, read ``counters()`` after."""

    def __init__(self, sim: Sim, cluster: Cluster, policy: DeschedulePolicy):
        if policy.interval_s <= 0:
            raise ValueError("interval_s must be positive")
        if not (0.0 < policy.util_threshold <= 1.0):
            raise ValueError("util_threshold must be in (0, 1]")
        if policy.victim not in VICTIM_POLICIES:
            raise ValueError(f"unknown victim policy {policy.victim!r}; "
                             f"expected one of {VICTIM_POLICIES}")
        self.sim = sim
        self.cluster = cluster
        self.policy = policy
        self.cycles = 0                # ticks that found >= 1 hot node
        self.ticks = 0                 # all wakeups
        self.evictions = 0             # pods offloaded
        sim.after(policy.start_after_s + policy.interval_s, self._tick,
                  daemon=True, note="descheduler")

    def _tick(self):
        self.ticks += 1
        cluster = self.cluster
        threshold = self.policy.util_threshold
        hot = []
        cool = []                      # ready nodes below the threshold
        for node in cluster._node_seq:
            if not node.ready:
                continue
            if cluster.node_util(node) >= threshold:
                hot.append(node)
            else:
                cool.append(node)
        if hot and cool:
            self.cycles += 1
            for node in hot:
                self._offload(node, cool)
        self.sim.after(self.policy.interval_s, self._tick, daemon=True,
                       note="descheduler")

    def _offload(self, node, cool):
        """Evict up to ``max_evict_per_node`` RUNNING residents of one
        hot node, ordered by the victim policy (youngest = latest
        ``started``, least sunk work; largest-request = biggest
        cpu/mem ask, most relief per eviction; pod name tie-breaks
        both), each gated on a cooler node that fits it (thrash
        guard)."""
        if self.policy.victim == "largest-request":
            key = lambda p: (-p.cpu_m, -p.mem_mi, p.name)
        else:
            key = lambda p: (-p.started, p.name)
        residents = sorted(
            (pod for pod in self.cluster.pods.values()
             if pod.node == node.name and pod.phase == RUNNING),
            key=key)
        evicted = 0
        for pod in residents:
            if evicted >= self.policy.max_evict_per_node:
                break
            if not any(n.fits(pod.cpu_m, pod.mem_mi) for n in cool):
                continue
            if self.cluster.rebalance_evict(pod.namespace, pod.name):
                evicted += 1
        self.evictions += evicted

    def counters(self) -> dict:
        return {"ticks": self.ticks, "active_cycles": self.cycles,
                "evictions": self.evictions,
                "interval_s": self.policy.interval_s,
                "util_threshold": self.policy.util_threshold,
                "victim": self.policy.victim}
