"""Event-trigger mechanism (§4.6): registry + callbacks.

The registry ties KubeAdaptor's modules together: informer handlers
emit events ('pod-succeeded', 'pod-deleted', ...), registered callbacks
respond in the same virtual instant — the quick create/destroy switch
the paper credits for its resource-usage advantage.

Dispatch passes positional args through the sim's event record (no
per-callback lambda allocation on the hot pod-lifecycle path).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, List

from repro.core.sim import Sim


class EventRegistry:
    def __init__(self, sim: Sim):
        self.sim = sim
        self._subs: Dict[str, List[Callable]] = defaultdict(list)
        self.emitted: Dict[str, int] = defaultdict(int)

    def register(self, name: str, cb: Callable):
        self._subs[name].append(cb)

    def emit(self, name: str, *args, **kw):
        self.emitted[name] += 1
        for cb in list(self._subs[name]):
            # event dispatch is in-process: effectively immediate
            if kw:
                self.sim.after(0.0, (lambda c=cb: c(*args, **kw)), note=name)
            else:
                self.sim.after(0.0, cb, note=name, args=args)
