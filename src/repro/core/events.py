"""Event-trigger mechanism (§4.6): registry + callbacks.

The registry ties KubeAdaptor's modules together: informer handlers
emit events ('pod-succeeded', 'pod-deleted', ...), registered callbacks
respond in the same virtual instant — the quick create/destroy switch
the paper credits for its resource-usage advantage.

Dispatch passes positional args through the sim's event record (no
per-callback lambda allocation on the hot pod-lifecycle path).

Scale fast path (ISSUE 3): same-instant dispatches coalesce.  The old
path scheduled one zero-delay sim event per callback per emit — two
per pod (pod-succeeded, pod-removed) on the lifecycle hot path.  Now
the first emit of an instant opens a dispatch buffer and schedules one
flush; subsequent emits at that instant append.  The flush fires the
callbacks in exact emit order at the same virtual instant and with the
same position in the instant's event sequence the first per-callback
event would have had (callbacks scheduled between two emits of one
instant can only target *later* times, so nothing can interleave —
the same argument that makes the cluster's lifecycle batches exact).
Emits issued *during* a flush open a fresh buffer, matching the old
behaviour of a nested emit queuing behind the current event.
``EventRegistry(sim, batched=False)`` restores the per-callback path
(the ControlPlane ties it to its ``lifecycle="chained"`` mode).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.sim import Sim


class EventRegistry:
    def __init__(self, sim: Sim, batched: bool = True):
        self.sim = sim
        self.batched = batched
        self._subs: Dict[str, List[Callable]] = defaultdict(list)
        self.emitted: Dict[str, int] = defaultdict(int)
        # open same-instant dispatch batch: (instant, [(cb, args), ...])
        self._buf: Optional[Tuple[float, List[Tuple[Callable, tuple]]]] = None

    def register(self, name: str, cb: Callable):
        self._subs[name].append(cb)

    def emit(self, name: str, *args, **kw):
        self.emitted[name] += 1
        if kw or not self.batched:
            for cb in list(self._subs[name]):
                # event dispatch is in-process: effectively immediate
                if kw:
                    self.sim.after(0.0, (lambda c=cb: c(*args, **kw)), note=name)
                else:
                    self.sim.after(0.0, cb, note=name, args=args)
            return
        subs = self._subs[name]
        if not subs:
            return
        now = self.sim.t
        buf = self._buf
        if buf is not None and buf[0] == now:
            pending = buf[1]
        else:
            pending = []
            self._buf = (now, pending)
            self.sim.after(0.0, self._flush, note="event-dispatch",
                           args=(now, pending))
        for cb in subs:
            pending.append((cb, args))

    def _flush(self, due: float, pending: List[Tuple[Callable, tuple]]):
        buf = self._buf
        if buf is not None and buf[0] == due and buf[1] is pending:
            self._buf = None        # emits during the flush re-arm
        for cb, args in pending:
            cb(*args)
