"""The level-2 cluster: an apiserver + disordered scheduler analogue.

Faithful to the properties the paper builds on:
  * the scheduler is DISORDERED, SCATTERED and UNPREDICTABLE (§3.1):
    each cycle it visits pending pods in random order and scatters them
    over shuffled nodes first-fit — it knows nothing about task
    dependencies (Fig 1's problem);
  * every API interaction costs ``api_latency`` (the apiserver-pressure
    effect the Informer exists to avoid);
  * watch streams deliver object events with ``watch_latency``;
  * pods hold node resources from bind to completion; Succeeded/Failed
    pods release compute but keep their object until deleted (pressure
    on anyone who forgets GC, like the paper's baselines).

Payloads: virtual (declared seconds) or real callables whose wall time
feeds the virtual clock (see core/sim.py).
"""
from __future__ import annotations

import copy
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import calibration as cal
from repro.core.sim import Sim, measure_wall

PENDING, RUNNING, SUCCEEDED, FAILED = "Pending", "Running", "Succeeded", "Failed"
ADDED, MODIFIED, DELETED = "ADDED", "MODIFIED", "DELETED"


@dataclass
class NodeObj:
    name: str
    cpu_alloc: int
    mem_alloc: int
    cpu_used: int = 0
    mem_used: int = 0
    ready: bool = True
    slow_factor: float = 1.0          # straggler injection for tests

    def fits(self, cpu: int, mem: int) -> bool:
        return (self.ready and self.cpu_used + cpu <= self.cpu_alloc
                and self.mem_used + mem <= self.mem_alloc)


@dataclass
class PodObj:
    name: str
    namespace: str
    task_id: str
    workflow: str
    cpu_m: int
    mem_mi: int
    duration_s: float = 0.0
    payload: Optional[Callable[[], Any]] = None
    volume: Optional[str] = None       # PVC name (mount adds latency)
    labels: Dict[str, str] = field(default_factory=dict)
    phase: str = PENDING
    node: Optional[str] = None
    created: float = 0.0
    scheduled: float = -1.0
    started: float = -1.0
    finished: float = -1.0
    deleted: float = -1.0
    restarts: int = 0
    _holding: bool = False             # currently holds node resources


@dataclass
class NamespaceObj:
    name: str
    created: float = 0.0
    deleted: float = -1.0


@dataclass
class PVCObj:
    name: str
    namespace: str
    bound: bool = False
    created: float = 0.0


@dataclass
class WatchEvent:
    kind: str        # "pod" | "node" | "namespace" | "pvc"
    type: str        # ADDED | MODIFIED | DELETED
    obj: Any


class Cluster:
    def __init__(self, sim: Sim, params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 cluster_cfg: cal.PaperCluster = cal.DEFAULT_CLUSTER,
                 payload_mode: str = "virtual", seed: int = 0):
        self.sim = sim
        self.p = params
        self.payload_mode = payload_mode
        self.rng = random.Random(seed)
        self.nodes: Dict[str, NodeObj] = {
            name: NodeObj(name, cpu, mem) for name, cpu, mem in cluster_cfg.nodes()}
        self.pods: Dict[Tuple[str, str], PodObj] = {}
        self.namespaces: Dict[str, NamespaceObj] = {}
        self.pvcs: Dict[Tuple[str, str], PVCObj] = {}
        self._watchers: Dict[str, List[Callable[[WatchEvent], None]]] = {}
        self._sched_scheduled = False
        self.api_calls = 0                   # apiserver pressure counter
        self.pod_log: List[PodObj] = []      # every pod ever (metrics)

    # ---- watch ---------------------------------------------------------
    def watch(self, kind: str, cb: Callable[[WatchEvent], None]):
        self._watchers.setdefault(kind, []).append(cb)

    def _notify(self, kind: str, type_: str, obj: Any):
        # snapshot the object version at event time (like a real watch
        # stream's resourceVersion) — consumers must not see later state
        snap = copy.copy(obj)
        for cb in self._watchers.get(kind, []):
            self.sim.after(self.p.watch_latency,
                           (lambda c=cb, e=WatchEvent(kind, type_, snap): c(e)))

    # ---- namespaces / PVC ----------------------------------------------
    def create_namespace(self, name: str, cb: Optional[Callable] = None):
        self.api_calls += 1

        def do():
            if name not in self.namespaces:
                ns = NamespaceObj(name, created=self.sim.now())
                self.namespaces[name] = ns
                self._notify("namespace", ADDED, ns)
            if cb:
                cb(self.namespaces[name])

        self.sim.after(self.p.api_latency + self.p.ns_create_latency, do)

    def delete_namespace(self, name: str, cb: Optional[Callable] = None):
        self.api_calls += 1

        def do():
            ns = self.namespaces.pop(name, None)
            if ns is not None:
                ns.deleted = self.sim.now()
                # cascade: pods + pvcs in the namespace
                for key in [k for k in self.pods if k[0] == name]:
                    self._remove_pod(self.pods[key])
                for key in [k for k in self.pvcs if k[0] == name]:
                    del self.pvcs[key]
                self._notify("namespace", DELETED, ns)
            if cb:
                cb(ns)

        self.sim.after(self.p.api_latency + self.p.ns_delete_latency, do)

    def create_pvc(self, namespace: str, name: str, cb: Optional[Callable] = None):
        self.api_calls += 1

        def bound():
            pvc = self.pvcs.get((namespace, name))
            if pvc is not None:
                pvc.bound = True
                self._notify("pvc", MODIFIED, pvc)
                if cb:
                    cb(pvc)

        def do():
            pvc = PVCObj(name, namespace, created=self.sim.now())
            self.pvcs[(namespace, name)] = pvc
            self._notify("pvc", ADDED, pvc)
            # dynamic provisioning (StorageClass + NFS provisioner pod)
            self.sim.after(self.p.pvc_create_latency, bound)

        self.sim.after(self.p.api_latency, do)

    # ---- pods ------------------------------------------------------------
    def create_pod(self, pod: PodObj, cb: Optional[Callable] = None,
                   error_cb: Optional[Callable] = None):
        self.api_calls += 1

        def do():
            key = (pod.namespace, pod.name)
            if key in self.pods:
                if error_cb:
                    error_cb("AlreadyExists", self.pods[key])
                return
            if pod.namespace not in self.namespaces:
                if error_cb:
                    error_cb("NamespaceNotFound", pod)
                return
            pod.created = self.sim.now()
            pod.phase = PENDING
            self.pods[key] = pod
            self.pod_log.append(pod)
            self._notify("pod", ADDED, pod)
            self._kick_scheduler()
            if cb:
                cb(pod)

        self.sim.after(self.p.api_latency, do)

    def delete_pod(self, namespace: str, name: str,
                   cb: Optional[Callable] = None):
        self.api_calls += 1

        def do():
            pod = self.pods.get((namespace, name))
            if pod is None:
                if cb:
                    cb(None)
                return
            self.sim.after(self.p.pod_delete_latency,
                           lambda: (self._remove_pod(pod), cb(pod) if cb else None))

        self.sim.after(self.p.api_latency, do)

    def _remove_pod(self, pod: PodObj):
        key = (pod.namespace, pod.name)
        if self.pods.get(key) is not pod:
            return
        self._release(pod)
        pod.deleted = self.sim.now()
        del self.pods[key]
        self._notify("pod", DELETED, pod)

    def _release(self, pod: PodObj):
        if pod._holding and pod.node in self.nodes:
            n = self.nodes[pod.node]
            n.cpu_used -= pod.cpu_m
            n.mem_used -= pod.mem_mi
            pod._holding = False

    # ---- the disordered scheduler ---------------------------------------
    def _kick_scheduler(self):
        if not self._sched_scheduled:
            self._sched_scheduled = True
            self.sim.after(self.p.sched_cycle, self._schedule_cycle)

    def _schedule_cycle(self):
        self._sched_scheduled = False
        pending = [p for p in self.pods.values()
                   if p.phase == PENDING and p.scheduled < 0]   # unbound only
        if not pending:
            return
        self.rng.shuffle(pending)                   # disorderly
        node_list = list(self.nodes.values())
        for pod in pending:
            self.rng.shuffle(node_list)             # scattered
            for node in node_list:
                if node.fits(pod.cpu_m, pod.mem_mi):
                    self._bind(pod, node)
                    break
        if any(p.phase == PENDING and p.scheduled < 0
               for p in self.pods.values()):
            self._kick_scheduler()

    def _bind(self, pod: PodObj, node: NodeObj):
        pod.node = node.name
        pod.scheduled = self.sim.now()
        node.cpu_used += pod.cpu_m
        node.mem_used += pod.mem_mi
        pod._holding = True
        start_lat = self.p.pod_start_latency
        if pod.volume:
            start_lat += self.p.pvc_mount_latency
        self.sim.after(start_lat, lambda: self._start(pod))

    def _start(self, pod: PodObj):
        if self.pods.get((pod.namespace, pod.name)) is not pod:
            return                                   # deleted while starting
        if not self.nodes[pod.node].ready:
            return                                   # node died mid-start
        pod.phase = RUNNING
        pod.started = self.sim.now()
        self._notify("pod", MODIFIED, pod)
        dur = pod.duration_s
        if pod.payload is not None and self.payload_mode == "real":
            dur = measure_wall(pod.payload)
        elif pod.payload is not None:
            pod.payload()                            # run, but virtual timing
        dur *= self.nodes[pod.node].slow_factor
        self.sim.after(dur, lambda: self._finish(pod, SUCCEEDED))

    def _finish(self, pod: PodObj, phase: str):
        if self.pods.get((pod.namespace, pod.name)) is not pod:
            return
        if pod.phase != RUNNING:
            return
        pod.phase = phase
        pod.finished = self.sim.now()
        self._release(pod)                           # compute freed; object stays
        self._notify("pod", MODIFIED, pod)

    def fail_pod(self, namespace: str, name: str):
        pod = self.pods.get((namespace, name))
        if pod is not None and pod.phase == RUNNING:
            self._finish(pod, FAILED)

    # ---- node failure (fault-tolerance substrate) -------------------------
    def fail_node(self, name: str):
        node = self.nodes[name]
        node.ready = False
        self._notify("node", MODIFIED, node)
        for pod in list(self.pods.values()):
            if pod.node == name and pod.phase in (PENDING, RUNNING):
                self._release(pod)
                pod.phase = FAILED
                pod.finished = self.sim.now()
                self._notify("pod", MODIFIED, pod)

    def restore_node(self, name: str):
        node = self.nodes[name]
        node.ready = True
        node.cpu_used = node.mem_used = 0
        self._notify("node", MODIFIED, node)
        self._kick_scheduler()

    # ---- reads (each list is an apiserver round-trip — the pressure the
    # Informer cache avoids; watch-driven callers never come here) ----------
    def list_pods(self, namespace: Optional[str] = None) -> List[PodObj]:
        self.api_calls += 1
        return [p for (ns, _), p in self.pods.items()
                if namespace is None or ns == namespace]

    def list_nodes(self) -> List[NodeObj]:
        self.api_calls += 1
        return list(self.nodes.values())

    def list_namespaces(self) -> List[NamespaceObj]:
        self.api_calls += 1
        return list(self.namespaces.values())

    def allocatable(self) -> Tuple[int, int]:
        cpu = sum(n.cpu_alloc for n in self.nodes.values() if n.ready)
        mem = sum(n.mem_alloc for n in self.nodes.values() if n.ready)
        return cpu, mem

    def used(self) -> Tuple[int, int]:
        cpu = sum(n.cpu_used for n in self.nodes.values())
        mem = sum(n.mem_used for n in self.nodes.values())
        return cpu, mem
