"""The level-2 cluster: an apiserver + disordered scheduler analogue.

Faithful to the properties the paper builds on:
  * the scheduler is DISORDERED, SCATTERED and UNPREDICTABLE (§3.1):
    each cycle it visits pending pods in random order and scatters them
    over shuffled nodes first-fit — it knows nothing about task
    dependencies (Fig 1's problem);
  * every API interaction costs ``api_latency`` (the apiserver-pressure
    effect the Informer exists to avoid);
  * watch streams deliver object events with ``watch_latency``;
  * pods hold node resources from bind to completion; Succeeded/Failed
    pods release compute but keep their object until deleted (pressure
    on anyone who forgets GC, like the paper's baselines).

Payloads: virtual (declared seconds) or real callables whose wall time
feeds the virtual clock (see core/sim.py).

Scale-out notes (1000 workflows / 100 nodes — see ISSUE 2):
  * a dedicated pending-pod index replaces the per-cycle scan of every
    pod object still alive in the apiserver, and one reusable node
    array (reset to the canonical order each cycle, like the fresh
    ``list(...)`` it replaces) takes the per-pod allocation out of the
    scatter loop;
  * the scatter shuffle burns the exact word stream of the seeded RNG
    via ``ExactShuffler`` — same binding sequence bit-for-bit (pinned
    by tests/test_scale_core.py) — and skips the first-fit scan (never
    the draws) for pods that provably fit no node;
  * watch fan-out batches same-instant events per kind into one sim
    event, with one object snapshot per notification, delivered at the
    same virtual times as the per-event path it replaces.

Pod-lifecycle fast path (10k workflows / 1000 nodes — see ISSUE 3):
the create→bind→running→succeeded→delete chain used to cost one sim
event per pod per hop.  Every hop's *due time* is fixed by a constant
latency, so same-instant hops coalesce into compound batch events that
replay the per-pod callbacks in the exact order the chained events
would have executed:

  * pod creations scheduled at one instant share one apiserver event
    (``_flush_creates``), and deletions share a two-stage batch
    (lookup at +api_latency, removal at +pod_delete_latency);
  * all pods bound in one scheduler cycle start in ONE compound event
    (``_start_batch``) that applies the running transitions, emits the
    watch notifications, and schedules one ``_finish_batch`` per
    distinct completion instant — the timeline of every bound pod is
    determined at bind time (virtual payloads), so the whole
    remaining lifecycle is scheduled in a single pass.

Exactness argument: consecutive hops of one instant draw consecutive
sim sequence numbers (nothing else can schedule between them), so a
batch that replays them back-to-back preserves every same-instant
ordering; hops whose sequence numbers shift (e.g. a finish group
scheduled after its siblings' notifications) only target instants
reachable from distinct constant-latency sums, where no foreign event
can sit between the old and new position.  ``lifecycle="chained"``
(or ``REPRO_LIFECYCLE=chained``) restores the one-event-per-hop path;
tests/test_event_core.py pins both paths to identical binding
sequences and metrics, and tests/test_scale_core.py's pinned hashes
run on the fast path.

Usage accounting: the cluster maintains exact in-use cpu/mem totals
(``cpu_in_use``/``mem_in_use``, updated at bind/release) so ``used()``
is O(1), and fires ``on_usage_change`` after every change — the
event-driven usage accumulator in core/metrics.py hangs off this hook
instead of polling a 0.5 s sampler.  The bind/release path also keeps
per-tenant holding cpu AND mem (quota/DRF accounting, ISSUE 4), and
``evict_pod`` is the admission pipeline's preemption primitive: a
RUNNING pod is killed and released immediately, surfacing as FAILED
with ``evicted=True`` so the engine re-queues the task through
admission without charging the retry budget.

Utilization-scored placement (ISSUE 8): ``placement="scored-spread"``
(least-allocated, the K3s CPU-aware spread) or ``"scored-pack"``
replaces ONLY the first-fit pick inside the scatter cycle — every
shuffle still consumes the identical word stream, so
``placement="first-fit"`` (the default) stays bit-identical to every
pinned binding hash and a scored run is reproducible on both the
native and pure-Python backends.  Node capacities are per node
throughout (heterogeneous ``NodeClass`` mixes flow straight through
the free/ready mirrors, ``kill_node``/``drain_node``/``restore_node``
included); ``node_peak_util``/``hotspot_summary()`` track per-node
bind-time high-water marks, and ``rebalance_evict`` is the periodic
descheduler's offload primitive (``rebalanced=True`` pods requeue
through admission with no retry-budget charge).

Elastic provisioning (ISSUE 9): every node carries a ``provisioned``
bit orthogonal to ``ready``.  The full max roster is materialized at
construction (fixed native-mirror indices), and the autoscaler
(core/autoscaler.py) flips membership with
:meth:`provision_node`/:meth:`deprovision_node` — restore_node-style
ready/free-array writes on the way up, the ``drain_node`` eviction
path on the way down.  A node deprovisioned while chaos holds it down
is NOT resurrected by ``restore_node`` (the autoscaler owns it until
re-provisioned).  The cluster keeps O(1) provisioned-capacity area
integrals (node-, mcore- and MiB-seconds plus in-use areas, windowed
to ``last_event_t`` exactly like the per-node utilization integrals)
so :meth:`cost_summary` reports the cost axis — node-seconds and
time-weighted utilization over *provisioned* time — mergeable across
shards by plain summation.
"""
from __future__ import annotations

import ctypes
import os
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import calibration as cal
from repro.core.shuffle import ExactShuffler
from repro.core.sim import Sim, measure_wall
from repro.core.stats import StreamingStat

PENDING, RUNNING, SUCCEEDED, FAILED = "Pending", "Running", "Succeeded", "Failed"
ADDED, MODIFIED, DELETED = "ADDED", "MODIFIED", "DELETED"

# objects materialized by _FastCopy.snapshot()/clone() since import —
# benchmarks report the delta per run as `informer_copies` (the copy
# traffic the zero-copy views avoid; see ISSUE 5)
SNAPSHOTS_MADE = 0


class _FastCopy:
    """Generation-stamped copy-on-write snapshots (zero-copy informer
    views, ISSUE 5).

    Every mutation of a watched object bumps its revision stamp
    ``_rv``; ``snapshot()`` returns an immutable view of the current
    state, materializing a copy ONLY when a field actually changed
    since the last snapshot — consecutive snapshots of an unchanged
    object are the SAME object, so the informer's resync reconcile,
    its listers and its running aggregates all read shared structures
    instead of per-call clones.  A handed-out snapshot is never
    mutated again (the next mutation bumps ``_rv`` and the next
    snapshot materializes fresh), which preserves the PR-2 guarantee
    that no handler or lister caller can observe future live-object
    state — pinned by tests/test_informer_views.py.

    Code outside cluster.py that mutates a watched field directly must
    bump ``obj._rv`` itself (the cluster's own mutation points all
    do).
    """

    _rv = 0                        # revision stamp (bumped per mutation)
    _snap = None                   # cached snapshot of revision _snap._rv

    def __copy__(self):
        global SNAPSHOTS_MADE
        SNAPSHOTS_MADE += 1
        new = object.__new__(type(self))
        d = new.__dict__
        d.update(self.__dict__)
        d.pop("_snap", None)       # snapshots never chain to older ones
        return new

    clone = __copy__

    def snapshot(self):
        """The current state as an immutable shared view (copy-on-write)."""
        snap = self._snap
        if snap is not None and snap._rv == self._rv:
            return snap
        snap = self.__copy__()
        self._snap = snap
        return snap


@dataclass
class NodeObj(_FastCopy):
    name: str
    cpu_alloc: int
    mem_alloc: int
    cpu_used: int = 0
    mem_used: int = 0
    ready: bool = True
    provisioned: bool = True          # autoscaler pool membership (ISSUE 9)
    slow_factor: float = 1.0          # straggler injection for tests

    def fits(self, cpu: int, mem: int) -> bool:
        return (self.ready and self.cpu_used + cpu <= self.cpu_alloc
                and self.mem_used + mem <= self.mem_alloc)


@dataclass
class PodObj(_FastCopy):
    name: str
    namespace: str
    task_id: str
    workflow: str
    cpu_m: int
    mem_mi: int
    duration_s: float = 0.0
    payload: Optional[Callable[[], Any]] = None
    volume: Optional[str] = None       # PVC name (mount adds latency)
    labels: Dict[str, str] = field(default_factory=dict)
    tenant: str = "default"            # denormalized labels["tenant"] —
    #                                    read on every bind/release/track

    def __post_init__(self):
        if self.tenant == "default" and self.labels:
            self.tenant = self.labels.get("tenant", "default")
    phase: str = PENDING
    node: Optional[str] = None
    created: float = 0.0
    scheduled: float = -1.0
    started: float = -1.0
    finished: float = -1.0
    deleted: float = -1.0
    restarts: int = 0
    evicted: bool = False              # preempted by the admission pipeline
    node_lost: bool = False            # evicted because its node died
    rebalanced: bool = False           # evicted by the descheduler
    _holding: bool = False             # currently holds node resources


@dataclass
class NamespaceObj(_FastCopy):
    name: str
    created: float = 0.0
    deleted: float = -1.0


@dataclass
class PVCObj(_FastCopy):
    name: str
    namespace: str
    bound: bool = False
    created: float = 0.0


class WatchEvent:
    """One watch-stream record (``__slots__``: allocated per event on
    the hot pod-lifecycle path)."""

    __slots__ = ("kind", "type", "obj")

    def __init__(self, kind: str, type: str, obj: Any):
        self.kind = kind     # "pod" | "node" | "namespace" | "pvc"
        self.type = type     # ADDED | MODIFIED | DELETED
        self.obj = obj


class Cluster:
    # placement -> score mode of the fused cycle (0 first-fit scan,
    # 1 spread = maximize post-bind free fraction, 2 pack = minimize)
    PLACEMENTS = {"first-fit": 0, "scored-spread": 1, "scored-pack": 2,
                  "scored": 1}         # "scored" = the spread variant
    SCORE_SCALE = 1 << 20              # integer fixed-point (C mirror)

    def __init__(self, sim: Sim, params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 cluster_cfg: cal.PaperCluster = cal.DEFAULT_CLUSTER,
                 payload_mode: str = "virtual", seed: int = 0,
                 retain_pod_log: bool = True,
                 lifecycle: Optional[str] = None,
                 placement: str = "first-fit"):
        self.sim = sim
        self.p = params
        if lifecycle is None:
            lifecycle = os.environ.get("REPRO_LIFECYCLE", "fast")
        if lifecycle not in ("fast", "chained"):
            raise ValueError(f"unknown lifecycle {lifecycle!r}; "
                             f"expected 'fast' or 'chained'")
        self.lifecycle = lifecycle
        if placement not in self.PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r}; "
                             f"expected one of {sorted(self.PLACEMENTS)}")
        self.placement = "scored-spread" if placement == "scored" \
            else placement
        self._score_mode = self.PLACEMENTS[placement]
        self._fast = lifecycle == "fast"
        self._watch_lat = params.watch_latency   # hoisted: read per notify
        self.payload_mode = payload_mode
        self.rng = random.Random(seed)
        # sole consumer of self.rng (see shuffle.py buffering contract)
        self._shuffler = ExactShuffler(self.rng)
        self.nodes: Dict[str, NodeObj] = {
            name: NodeObj(name, cpu, mem) for name, cpu, mem in cluster_cfg.nodes()}
        self.pods: Dict[Tuple[str, str], PodObj] = {}
        self.namespaces: Dict[str, NamespaceObj] = {}
        self.pvcs: Dict[Tuple[str, str], PVCObj] = {}
        # per-namespace pvc keys: the teardown cascade and namespaced
        # lists must not scan every live workflow's volume
        self._pvcs_by_ns: Dict[str, List[Tuple[str, str]]] = {}
        self._watchers: Dict[str, List[Callable[[WatchEvent], None]]] = {}
        self._batch_watchers: Dict[str, List[Callable]] = {}
        self._watched: Dict[str, bool] = {}   # any watcher of this kind?
        # kind -> (delivery time, events) for the open same-instant batch
        self._watch_buf: Dict[str, Tuple[float, List[WatchEvent]]] = {}
        self._sched_scheduled = False
        # fast-lifecycle coalescing buffers: (due instant, open batch)
        self._create_buf: Optional[Tuple[float, List]] = None
        self._del_buf: Optional[Tuple[float, List]] = None
        self._start_buf: Optional[Tuple[float, List[PodObj]]] = None
        self.api_calls = 0                   # apiserver pressure counter
        self.pods_created = 0                # pods accepted by the apiserver
        self.retain_pod_log = retain_pod_log
        self.pod_log: List[PodObj] = []      # every pod ever (metrics)
        self.exec_stat = StreamingStat()     # pod create->delete (Succeeded)
        # exact in-use totals (mirror of the node scan) + change hook
        self.cpu_in_use = 0
        self.mem_in_use = 0
        self.on_usage_change: Optional[Callable[[Optional[str]], None]] = None
        # scheduler indexes: unbound Pending pods in creation order (the
        # same visit order as the old full-pod scan), reusable node array
        self._pending_pods: Dict[Tuple[str, str], PodObj] = {}
        self._pods_by_ns: Dict[str, Dict[Tuple[str, str], PodObj]] = {}
        self._node_seq: List[NodeObj] = list(self.nodes.values())
        self._node_perm = self._shuffler.make_perm(len(self._node_seq))
        if self._shuffler.has_native_cycle:
            n = len(self._node_seq)
            # free-capacity mirrors of the node objects, maintained
            # incrementally at bind/release/fail/restore (absolute
            # writes, so the in-place charging the native cycle already
            # did is simply re-asserted) — the per-cycle O(nodes)
            # refill dominated the 1000-node scheduler profile.
            # Every mirror is PER NODE (heterogeneous capacities flow
            # straight through); the alloc arrays are static denominators
            # for the scored placement modes
            self._c_free_cpu = (ctypes.c_int32 * n)()
            self._c_free_mem = (ctypes.c_int32 * n)()
            self._c_ready = (ctypes.c_uint8 * n)()
            self._c_alloc_cpu = (ctypes.c_int32 * n)()
            self._c_alloc_mem = (ctypes.c_int32 * n)()
            self._node_idx: Dict[str, int] = {}
            for i, node in enumerate(self._node_seq):
                self._c_free_cpu[i] = node.cpu_alloc - node.cpu_used
                self._c_free_mem[i] = node.mem_alloc - node.mem_used
                self._c_ready[i] = node.ready
                self._c_alloc_cpu[i] = node.cpu_alloc
                self._c_alloc_mem[i] = node.mem_alloc
                self._node_idx[node.name] = i
            self._c_pod_cap = 0
            self._c_pod_cpu = self._c_pod_mem = self._c_bind = None
            self._c_pod_perm = None
        else:
            self._c_free_cpu = None
        self.max_pending_pods = 0            # peak unbound-pod queue depth
        self.sched_cycles = 0
        self.evictions = 0                   # pods preempted via evict_pod
        self.pods_lost = 0                   # pods failed by node kill/drain
        self.rebalances = 0                  # pods evicted by the descheduler
        # per-node peak utilization high-water marks (max of cpu/mem
        # bound fraction, updated O(1) at bind) — the hotspot-variance
        # bench axis; a node never bound keeps 0.0, which is the skew
        self.node_peak_util: Dict[str, float] = {
            name: 0.0 for name in self.nodes}
        # time-weighted per-node utilization (Σ util·dt, O(1) per bind/
        # release): under a deep backlog every node eventually hits its
        # max packing, so all-time peaks quantize to capacity and stop
        # discriminating placement quality — the time average does not
        self._util_area: Dict[str, float] = {name: 0.0 for name in self.nodes}
        self._util_cur: Dict[str, float] = {name: 0.0 for name in self.nodes}
        self._util_mark: Dict[str, float] = {name: 0.0 for name in self.nodes}
        # provisioned-capacity cost accounting (ISSUE 9): O(1) area
        # integrals over the provisioned roster (node/cpu/mem seconds)
        # and the in-use totals, windowed to last_event_t by
        # cost_summary() exactly like the per-node utilization areas.
        # The full roster starts provisioned; the autoscaler shrinks it
        self._prov_nodes = len(self._node_seq)
        self._prov_cpu = sum(n.cpu_alloc for n in self._node_seq)
        self._prov_mem = sum(n.mem_alloc for n in self._node_seq)
        self._prov_mark = 0.0
        self._prov_node_area = 0.0
        self._prov_cpu_area = 0.0
        self._prov_mem_area = 0.0
        self._prov_peak = self._prov_low = self._prov_nodes
        self._use_mark = 0.0
        self._use_cpu_area = 0.0
        self._use_mem_area = 0.0
        self.provision_flips = 0             # provision+deprovision events
        # fault injection (chaos plane, ISSUE 7): ChaosInjector attaches
        # itself here; None = zero draws, bit-identical behavior
        self.chaos = None
        # bound (resource-holding) cpu/mem per tenant label, kept current
        # at bind/release so samplers never scan the pod table
        self.tenant_holding_cpu: Dict[str, int] = {}
        self.tenant_holding_mem: Dict[str, int] = {}

    # ---- watch ---------------------------------------------------------
    def watch(self, kind: str, cb: Callable[[WatchEvent], None]):
        self._watchers.setdefault(kind, []).append(cb)
        self._watched[kind] = True

    def watch_batch(self, kind: str, cb: Callable[[List[WatchEvent]], None]):
        """Batched stream: one callback per delivery instant with every
        event of ``kind`` that became due at that instant (informers use
        this; per-event ``watch`` remains for simple consumers)."""
        self._batch_watchers.setdefault(kind, []).append(cb)
        self._watched[kind] = True

    def _notify(self, kind: str, type_: str, obj: Any):
        if kind not in self._watched:
            return
        # snapshot the object version at event time (like a real watch
        # stream's resourceVersion) — consumers must not see later state;
        # copy-on-write: consecutive notifications of an unchanged object
        # (and resync list reads) share one materialized view
        ev = WatchEvent(kind, type_, obj.snapshot())
        due = self.sim.t + self._watch_lat
        buf = self._watch_buf.get(kind)
        if buf is not None and buf[0] == due:
            buf[1].append(ev)
            return
        batch = [ev]
        self._watch_buf[kind] = (due, batch)
        self.sim.at(due, self._flush_watch, note=f"watch:{kind}",
                    args=(kind, due, batch))

    def _flush_watch(self, kind: str, due: float, batch: List[WatchEvent]):
        buf = self._watch_buf.get(kind)
        if buf is not None and buf[0] == due:
            del self._watch_buf[kind]
        for cb in self._batch_watchers.get(kind, ()):
            cb(batch)
        for cb in self._watchers.get(kind, ()):
            for ev in batch:
                cb(ev)

    # ---- namespaces / PVC ----------------------------------------------
    def create_namespace(self, name: str, cb: Optional[Callable] = None):
        self.api_calls += 1

        def do():
            if name not in self.namespaces:
                ns = NamespaceObj(name, created=self.sim.now())
                self.namespaces[name] = ns
                self._notify("namespace", ADDED, ns)
            if cb:
                cb(self.namespaces[name])

        self.sim.after(self.p.api_latency + self.p.ns_create_latency, do)

    def delete_namespace(self, name: str, cb: Optional[Callable] = None):
        self.api_calls += 1

        def do():
            ns = self.namespaces.pop(name, None)
            if ns is not None:
                ns.deleted = self.sim.now()
                ns._rv += 1
                # cascade: pods + pvcs in the namespace
                for pod in list(self._pods_by_ns.get(name, {}).values()):
                    self._remove_pod(pod)
                for key in self._pvcs_by_ns.pop(name, ()):
                    self.pvcs.pop(key, None)
                self._notify("namespace", DELETED, ns)
            if cb:
                cb(ns)

        self.sim.after(self.p.api_latency + self.p.ns_delete_latency, do)

    def create_pvc(self, namespace: str, name: str, cb: Optional[Callable] = None):
        self.api_calls += 1

        def bound():
            pvc = self.pvcs.get((namespace, name))
            if pvc is not None:
                pvc.bound = True
                pvc._rv += 1
                self._notify("pvc", MODIFIED, pvc)
                if cb:
                    cb(pvc)

        def do():
            pvc = PVCObj(name, namespace, created=self.sim.now())
            key = (namespace, name)
            if key not in self.pvcs:     # re-create: index entry exists
                self._pvcs_by_ns.setdefault(namespace, []).append(key)
            self.pvcs[key] = pvc
            self._notify("pvc", ADDED, pvc)
            # dynamic provisioning (StorageClass + NFS provisioner pod)
            self.sim.after(self.p.pvc_create_latency, bound)

        self.sim.after(self.p.api_latency, do)

    # ---- pods ------------------------------------------------------------
    def create_pod(self, pod: PodObj, cb: Optional[Callable] = None,
                   error_cb: Optional[Callable] = None):
        self.api_calls += 1
        # transient apiserver fault (chaos plane): the call is charged
        # but fails after the round-trip with a retryable error; only
        # callers that can absorb it (error_cb) are ever faulted
        if (self.chaos is not None and error_cb is not None
                and self.chaos.api_fault_draw()):
            self.sim.after(self.p.api_latency, error_cb,
                           note="api-fault", args=("Unavailable", pod))
            return
        if not self._fast:
            self.sim.after(self.p.api_latency, self._create_now,
                           args=(pod, cb, error_cb))
            return
        # same-instant creations share one apiserver round-trip event
        due = self.sim.t + self.p.api_latency
        buf = self._create_buf
        if buf is not None and buf[0] == due:
            buf[1].append((pod, cb, error_cb))
            return
        batch = [(pod, cb, error_cb)]
        self._create_buf = (due, batch)
        self.sim.at(due, self._flush_creates, note="pod-create",
                    args=(due, batch))

    def _flush_creates(self, due: float, batch: List):
        buf = self._create_buf
        if buf is not None and buf[0] == due:
            self._create_buf = None
        for pod, cb, error_cb in batch:
            self._create_now(pod, cb, error_cb)

    def _create_now(self, pod: PodObj, cb: Optional[Callable],
                    error_cb: Optional[Callable]):
        key = (pod.namespace, pod.name)
        if key in self.pods:
            if error_cb:
                error_cb("AlreadyExists", self.pods[key])
            return
        if pod.namespace not in self.namespaces:
            if error_cb:
                error_cb("NamespaceNotFound", pod)
            return
        pod.created = self.sim.now()
        pod.phase = PENDING
        self.pods[key] = pod
        self.pods_created += 1
        self._pods_by_ns.setdefault(pod.namespace, {})[key] = pod
        self._pending_pods[key] = pod
        if len(self._pending_pods) > self.max_pending_pods:
            self.max_pending_pods = len(self._pending_pods)
        if self.retain_pod_log:
            self.pod_log.append(pod)
        self._notify("pod", ADDED, pod)
        self._kick_scheduler()
        if cb:
            cb(pod)

    def delete_pod(self, namespace: str, name: str,
                   cb: Optional[Callable] = None,
                   error_cb: Optional[Callable] = None):
        self.api_calls += 1
        if (self.chaos is not None and error_cb is not None
                and self.chaos.api_fault_draw()):
            self.sim.after(self.p.api_latency, error_cb,
                           note="api-fault",
                           args=("Unavailable", (namespace, name)))
            return
        if not self._fast:
            self.sim.after(self.p.api_latency, self._delete_lookup,
                           args=(namespace, name, cb))
            return
        # same-instant deletions share the apiserver lookup event and
        # one removal event pod_delete_latency later
        due = self.sim.t + self.p.api_latency
        buf = self._del_buf
        if buf is not None and buf[0] == due:
            buf[1].append((namespace, name, cb))
            return
        batch = [(namespace, name, cb)]
        self._del_buf = (due, batch)
        self.sim.at(due, self._flush_delete_lookups, note="pod-delete",
                    args=(due, batch))

    def _delete_lookup(self, namespace: str, name: str,
                       cb: Optional[Callable]):
        pod = self.pods.get((namespace, name))
        if pod is None:
            if cb:
                cb(None)
            return
        self.sim.after(self.p.pod_delete_latency, self._remove_batch,
                       args=([(pod, cb)],))

    def _flush_delete_lookups(self, due: float, batch: List):
        buf = self._del_buf
        if buf is not None and buf[0] == due:
            self._del_buf = None
        removals = []
        for namespace, name, cb in batch:
            pod = self.pods.get((namespace, name))
            if pod is None:
                if cb:
                    cb(None)
            else:
                removals.append((pod, cb))
        if removals:
            self.sim.after(self.p.pod_delete_latency, self._remove_batch,
                           note="pod-remove", args=(removals,))

    def _remove_batch(self, removals: List):
        for pod, cb in removals:
            self._remove_pod(pod)
            if cb:
                cb(pod)

    def _remove_pod(self, pod: PodObj):
        key = (pod.namespace, pod.name)
        if self.pods.get(key) is not pod:
            return
        self._release(pod)
        pod.deleted = self.sim.now()
        pod._rv += 1
        del self.pods[key]
        self._pending_pods.pop(key, None)
        ns_map = self._pods_by_ns.get(pod.namespace)
        if ns_map is not None:
            ns_map.pop(key, None)
            if not ns_map:
                del self._pods_by_ns[pod.namespace]
        if pod.phase == SUCCEEDED and pod.labels.get("virtual") != "1":
            # paper metric: task-pod execution time, virtual entry/exit
            # pods excluded (matches MetricsCollector.pod_exec_times)
            self.exec_stat.add(pod.deleted - pod.created)
        self._notify("pod", DELETED, pod)

    def _release(self, pod: PodObj):
        if pod._holding and pod.node in self.nodes:
            n = self.nodes[pod.node]
            n.cpu_used -= pod.cpu_m
            n.mem_used -= pod.mem_mi
            n._rv += 1
            pod._holding = False
            pod._rv += 1
            if self._c_free_cpu is not None:
                i = self._node_idx[n.name]
                self._c_free_cpu[i] = n.cpu_alloc - n.cpu_used
                self._c_free_mem[i] = n.mem_alloc - n.mem_used
            now = self.sim.now()
            name = n.name
            fc = n.cpu_used / n.cpu_alloc
            fm = n.mem_used / n.mem_alloc
            self._util_area[name] += \
                self._util_cur[name] * (now - self._util_mark[name])
            self._util_mark[name] = now
            self._util_cur[name] = fc if fc >= fm else fm
            dt = now - self._use_mark
            if dt > 0.0:
                self._use_cpu_area += self.cpu_in_use * dt
                self._use_mem_area += self.mem_in_use * dt
                self._use_mark = now
            self.cpu_in_use -= pod.cpu_m
            self.mem_in_use -= pod.mem_mi
            tenant = pod.tenant
            self.tenant_holding_cpu[tenant] -= pod.cpu_m
            self.tenant_holding_mem[tenant] -= pod.mem_mi
            if self.on_usage_change is not None:
                self.on_usage_change(tenant)

    # ---- the disordered scheduler ---------------------------------------
    def _kick_scheduler(self):
        if not self._sched_scheduled:
            self._sched_scheduled = True
            self.sim.after(self.p.sched_cycle, self._schedule_cycle,
                           note="sched-cycle")

    def _schedule_cycle(self):
        self._sched_scheduled = False
        if not self._pending_pods:
            return
        self.sched_cycles += 1
        pending = list(self._pending_pods.values())
        shuffler = self._shuffler
        node_seq = self._node_seq
        n_nodes = len(node_seq)
        perm = self._node_perm
        shuffler.reset_perm(perm, n_nodes)          # canonical order each cycle
        if shuffler.has_native_cycle:
            self._native_cycle(pending, perm, node_seq, n_nodes)
        else:
            shuffler.shuffle(pending)               # disorderly
            self._python_cycle(pending, perm, node_seq, n_nodes)
        if self._pending_pods:
            self._kick_scheduler()

    def _native_cycle(self, pending, perm, node_seq, n_nodes):
        """Fused scatter cycle in the native helper: one call shuffles
        the pending order, draws, scans and picks nodes for every
        pending pod (identical draw stream and algorithm to
        ``shuffle(pending)`` + ``_python_cycle``); only the binds come
        back to Python, applied in the shuffled pod order."""
        n_pods = len(pending)
        if n_pods > self._c_pod_cap:
            cap = max(64, 2 * n_pods)
            self._c_pod_cpu = (ctypes.c_int32 * cap)()
            self._c_pod_mem = (ctypes.c_int32 * cap)()
            self._c_bind = (ctypes.c_int32 * cap)()
            self._c_pod_perm = (ctypes.c_int32 * cap)()
            self._c_pod_cap = cap
        pod_cpu, pod_mem = self._c_pod_cpu, self._c_pod_mem
        for j, pod in enumerate(pending):
            pod_cpu[j] = pod.cpu_m
            pod_mem[j] = pod.mem_mi
        # free/ready mirrors are already current (see __init__)
        pod_perm = self._c_pod_perm
        self._shuffler.schedule_cycle(perm, n_nodes, self._c_free_cpu,
                                      self._c_free_mem, self._c_ready,
                                      self._c_alloc_cpu, self._c_alloc_mem,
                                      self._score_mode,
                                      n_pods, pod_perm, pod_cpu, pod_mem,
                                      self._c_bind)
        bind = self._c_bind
        for j in range(n_pods):
            idx = bind[j]
            if idx >= 0:
                self._bind(pending[pod_perm[j]], node_seq[idx])

    def _python_cycle(self, pending, perm, node_seq, n_nodes):
        shuffler = self._shuffler
        # upper bounds on any single node's free capacity this cycle:
        # binds only shrink node headroom, so the cycle-start maxima stay
        # valid upper bounds — a pod requesting more than either can fit
        # no node, and its first-fit scan (never its draws) is skipped
        free_cpu_max = free_mem_max = 0
        for node in node_seq:
            if node.ready:
                fc = node.cpu_alloc - node.cpu_used
                fm = node.mem_alloc - node.mem_used
                if fc > free_cpu_max:
                    free_cpu_max = fc
                if fm > free_mem_max:
                    free_mem_max = fm
        score_mode = self._score_mode
        scale = self.SCORE_SCALE
        for pod in pending:
            shuffler.draw_apply(perm, n_nodes)      # scattered
            cpu, mem = pod.cpu_m, pod.mem_mi
            if cpu > free_cpu_max or mem > free_mem_max:
                continue                            # fits no node: skip scan
            if score_mode == 0:
                for idx in perm:
                    node = node_seq[idx]
                    if (node.ready and node.cpu_used + cpu <= node.cpu_alloc
                            and node.mem_used + mem <= node.mem_alloc):
                        self._bind(pod, node)
                        break
                continue
            # scored placement (semantic reference for the fused C
            # scan): integer least-allocated score of the POST-BIND
            # free fractions; spread maximizes, pack minimizes; strict
            # comparison means ties go to the earliest perm position.
            # Same draws, same skip rule — only the pick differs.
            best = None
            best_score = 0
            for idx in perm:
                node = node_seq[idx]
                if not (node.ready and node.cpu_used + cpu <= node.cpu_alloc
                        and node.mem_used + mem <= node.mem_alloc):
                    continue
                fc = node.cpu_alloc - node.cpu_used - cpu
                fm = node.mem_alloc - node.mem_used - mem
                score = (fc * scale) // node.cpu_alloc \
                    + (fm * scale) // node.mem_alloc
                if best is None or (score > best_score if score_mode == 1
                                    else score < best_score):
                    best = node
                    best_score = score
            if best is not None:
                self._bind(pod, best)

    def _bind(self, pod: PodObj, node: NodeObj):
        pod.node = node.name
        pod.scheduled = self.sim.now()
        pod._rv += 1
        node.cpu_used += pod.cpu_m
        node.mem_used += pod.mem_mi
        node._rv += 1
        pod._holding = True
        if self._c_free_cpu is not None:
            i = self._node_idx[node.name]
            self._c_free_cpu[i] = node.cpu_alloc - node.cpu_used
            self._c_free_mem[i] = node.mem_alloc - node.mem_used
        # O(1) hotspot high-water mark + time-weighted load integral
        # (the bench's spread axes)
        frac = node.cpu_used / node.cpu_alloc
        frac_m = node.mem_used / node.mem_alloc
        if frac_m > frac:
            frac = frac_m
        name = node.name
        if frac > self.node_peak_util[name]:
            self.node_peak_util[name] = frac
        self._util_area[name] += \
            self._util_cur[name] * (pod.scheduled - self._util_mark[name])
        self._util_mark[name] = pod.scheduled
        self._util_cur[name] = frac
        dt = pod.scheduled - self._use_mark
        if dt > 0.0:
            self._use_cpu_area += self.cpu_in_use * dt
            self._use_mem_area += self.mem_in_use * dt
            self._use_mark = pod.scheduled
        self.cpu_in_use += pod.cpu_m
        self.mem_in_use += pod.mem_mi
        tenant = pod.tenant
        self.tenant_holding_cpu[tenant] = \
            self.tenant_holding_cpu.get(tenant, 0) + pod.cpu_m
        self.tenant_holding_mem[tenant] = \
            self.tenant_holding_mem.get(tenant, 0) + pod.mem_mi
        if self.on_usage_change is not None:
            self.on_usage_change(tenant)
        self._pending_pods.pop((pod.namespace, pod.name), None)
        start_lat = self.p.pod_start_latency
        if pod.volume:
            start_lat += self.p.pvc_mount_latency
        if not self._fast:
            self.sim.after(start_lat, self._start, args=(pod,))
            return
        # compound timeline: every pod bound in this scheduler cycle
        # shares one start event; the rest of its lifecycle (finish
        # instants, watch notifications) is laid out when it fires
        due = self.sim.t + start_lat
        buf = self._start_buf
        if buf is not None and buf[0] == due:
            buf[1].append(pod)
            return
        batch = [pod]
        self._start_buf = (due, batch)
        self.sim.at(due, self._start_batch, note="pod-start",
                    args=(due, batch))

    def _start_one(self, pod: PodObj) -> float:
        """Apply the Pending→Running transition; returns the completion
        due time, or -1.0 when the pod can no longer start."""
        if self.pods.get((pod.namespace, pod.name)) is not pod:
            return -1.0                              # deleted while starting
        if pod.phase != PENDING:
            return -1.0                              # failed before start
            #                                          (node kill/drain while
            #                                           the start was in flight)
        if not self.nodes[pod.node].ready:
            return -1.0                              # node died mid-start
        pod.phase = RUNNING
        pod.started = self.sim.now()
        pod._rv += 1
        self._notify("pod", MODIFIED, pod)
        dur = pod.duration_s
        if pod.payload is not None and self.payload_mode == "real":
            dur = measure_wall(pod.payload)
        elif pod.payload is not None:
            pod.payload()                            # run, but virtual timing
        dur *= self.nodes[pod.node].slow_factor
        if self.chaos is not None and dur > 0.0:
            # seeded mid-run crash (chaos plane): fires strictly before
            # the success finish, which then no-ops on phase != RUNNING;
            # unlike node loss this charges the §4.5 retry budget
            crash_after = self.chaos.task_crash_draw(dur)
            if crash_after is not None:
                self.sim.at(self.sim.t + crash_after, self._finish,
                            note="chaos-crash", args=(pod, FAILED))
        return self.sim.t + (dur if dur > 0.0 else 0.0)

    def _start(self, pod: PodObj):
        fdue = self._start_one(pod)
        if fdue >= 0.0:
            self.sim.at(fdue, self._finish, args=(pod, SUCCEEDED))

    def _start_batch(self, due: float, pods: List[PodObj]):
        buf = self._start_buf
        if buf is not None and buf[0] == due:
            self._start_buf = None
        # transition every pod first (their RUNNING notifications share
        # one watch batch, in bind order — exactly the chained order),
        # then schedule one finish event per distinct completion instant
        groups: Dict[float, List[PodObj]] = {}
        for pod in pods:
            fdue = self._start_one(pod)
            if fdue < 0.0:
                continue
            g = groups.get(fdue)
            if g is None:
                groups[fdue] = [pod]
            else:
                g.append(pod)
        for fdue, group in groups.items():
            self.sim.at(fdue, self._finish_batch, note="pod-finish",
                        args=(group,))

    def _finish_batch(self, group: List[PodObj]):
        for pod in group:
            self._finish(pod, SUCCEEDED)

    def _finish(self, pod: PodObj, phase: str):
        if self.pods.get((pod.namespace, pod.name)) is not pod:
            return
        if pod.phase != RUNNING:
            return
        pod.phase = phase
        pod.finished = self.sim.now()
        pod._rv += 1
        self._release(pod)                           # compute freed; object stays
        self._notify("pod", MODIFIED, pod)

    def fail_pod(self, namespace: str, name: str):
        pod = self.pods.get((namespace, name))
        if pod is not None and pod.phase == RUNNING:
            self._finish(pod, FAILED)

    def evict_pod(self, namespace: str, name: str) -> bool:
        """Preemption path of the admission pipeline: kill a RUNNING
        pod now, releasing its node resources.  The pod surfaces as
        FAILED with ``evicted=True`` so the engine re-queues its task
        through admission instead of charging the retry budget.
        Returns False when the pod is gone or not RUNNING (the
        arbiter's informer view may lag the apiserver)."""
        self.api_calls += 1
        pod = self.pods.get((namespace, name))
        if pod is None or pod.phase != RUNNING:
            return False
        pod.evicted = True
        pod._rv += 1
        self.evictions += 1
        self._finish(pod, FAILED)
        return True

    def rebalance_evict(self, namespace: str, name: str) -> bool:
        """Descheduler eviction: like :meth:`evict_pod` but flagged
        ``rebalanced`` so recovery metrics split offloads from
        admission preemptions.  The engine requeues the task through
        admission with no retry-budget charge; it lands on a cooler
        node (or pends) via the ordinary scatter cycle."""
        self.api_calls += 1
        pod = self.pods.get((namespace, name))
        if pod is None or pod.phase != RUNNING:
            return False
        pod.evicted = True
        pod.rebalanced = True
        pod._rv += 1
        self.rebalances += 1
        self._finish(pod, FAILED)
        return True

    def node_util(self, node: NodeObj) -> float:
        """Live utilization of one node: max of its bound cpu and mem
        fractions (the descheduler's overload signal)."""
        fc = node.cpu_used / node.cpu_alloc
        fm = node.mem_used / node.mem_alloc
        return fc if fc >= fm else fm

    def hotspot_summary(self) -> Dict[str, float]:
        """Per-node utilization spread — the load-imbalance axes the
        scored placement modes attack.  Two profiles over the node
        population: the bind-time high-water marks (``*_peak_util``;
        note a deep enough backlog saturates every node's peak at its
        max packing) and the time-weighted per-node mean utilizations
        (``*_mean_util`` / ``util_variance`` — the saturation-proof
        hotspot-variance axis benchmarks and CI compare)."""
        n = len(self.node_peak_util)
        if not n:
            return {}
        peaks = list(self.node_peak_util.values())
        # drained sims park t at the horizon; the workload's real time
        # span ends at the last event — use it as the averaging window
        now = min(self.sim.now(),
                  getattr(self.sim, "last_event_t", self.sim.now()))
        means = [(self._util_area[name]
                  + self._util_cur[name]
                  * max(0.0, now - self._util_mark[name]))
                 / now if now > 0 else 0.0
                 for name in self.node_peak_util]
        peak_mean = sum(peaks) / n
        util_mean = sum(means) / n
        return {
            "nodes": float(n),
            "mean_peak_util": peak_mean,
            "max_peak_util": max(peaks),
            "min_peak_util": min(peaks),
            "peak_util_variance": sum(
                (p - peak_mean) ** 2 for p in peaks) / n,
            "mean_util": util_mean,
            "max_mean_util": max(means),
            "min_mean_util": min(means),
            "util_variance": sum(
                (u - util_mean) ** 2 for u in means) / n,
        }

    # ---- node failure (fault-tolerance substrate) -------------------------
    def _fail_resident(self, pod: PodObj):
        """Fail one pod resident on a dying node.  Surfaces like a
        preemption (``evicted=True`` -> engine requeues through
        admission, no retry-budget charge) but flagged ``node_lost``
        so recovery metrics split the two causes."""
        pod.evicted = True
        pod.node_lost = True
        pod._rv += 1
        self.pods_lost += 1
        if pod.phase == PENDING:
            # bound but not yet started: the pending _start event will
            # no-op on the phase guard; release and fail directly (the
            # _finish path only handles RUNNING pods)
            self._pending_pods.pop((pod.namespace, pod.name), None)
            self._release(pod)
            pod.phase = FAILED
            pod.finished = self.sim.now()
            pod._rv += 1
            self._notify("pod", MODIFIED, pod)
        else:
            self._finish(pod, FAILED)

    def kill_node(self, name: str, drain: bool = False) -> int:
        """Chaos primitive: node crash (or graceful spot reclaim when
        ``drain=True``).  Cordons the node out of the scheduler (node
        arrays + informer aggregates track the MODIFIED event) and
        fails every resident pod via :meth:`_fail_resident`; the
        engine's requeue machinery re-admits the tasks with no retry
        charge.  A drain evicts each pod through the apiserver
        (charged to ``api_calls``); a crash charges nothing.  Returns
        the number of pods disrupted.  ``restore_node`` undoes the
        cordon."""
        node = self.nodes[name]
        if not node.ready:
            return 0
        node.ready = False
        node._rv += 1
        if self._c_free_cpu is not None:
            self._c_ready[self._node_idx[name]] = 0
        self._notify("node", MODIFIED, node)
        lost = 0
        for pod in list(self.pods.values()):
            if pod.node == name and pod.phase in (PENDING, RUNNING):
                if drain:
                    self.api_calls += 1      # per-pod eviction round-trip
                self._fail_resident(pod)
                lost += 1
        return lost

    def drain_node(self, name: str) -> int:
        """Spot/preemptible reclaim: like :meth:`kill_node` but each
        resident pod is evicted through the apiserver (api pressure),
        modeling the reclaim grace-period drain."""
        return self.kill_node(name, drain=True)

    def fail_node(self, name: str):
        node = self.nodes[name]
        node.ready = False
        node._rv += 1
        if self._c_free_cpu is not None:
            self._c_ready[self._node_idx[name]] = 0
        self._notify("node", MODIFIED, node)
        for pod in list(self.pods.values()):
            if pod.node == name and pod.phase in (PENDING, RUNNING):
                self._release(pod)
                pod.phase = FAILED
                pod.finished = self.sim.now()
                pod._rv += 1
                self._notify("pod", MODIFIED, pod)

    def restore_node(self, name: str):
        node = self.nodes[name]
        if not node.provisioned:
            # the autoscaler deprovisioned this node while it was down:
            # a late chaos rejoin must not resurrect it — only
            # provision_node (which re-enters here) brings it back
            return
        node.ready = True
        node._rv += 1
        if node.cpu_used or node.mem_used:   # normally zero: failure released
            now = self.sim.now()
            dt = now - self._use_mark
            if dt > 0.0:
                self._use_cpu_area += self.cpu_in_use * dt
                self._use_mem_area += self.mem_in_use * dt
                self._use_mark = now
            self.cpu_in_use -= node.cpu_used
            self.mem_in_use -= node.mem_used
            if self.on_usage_change is not None:
                self.on_usage_change(None)
        node.cpu_used = node.mem_used = 0
        if self._c_free_cpu is not None:
            i = self._node_idx[name]
            self._c_free_cpu[i] = node.cpu_alloc
            self._c_free_mem[i] = node.mem_alloc
            self._c_ready[i] = 1
        self._notify("node", MODIFIED, node)
        self._kick_scheduler()

    # ---- elastic provisioning (autoscaler substrate) ----------------------
    def _accrue_provisioned(self):
        """Advance the provisioned-capacity area integrals to now.
        O(1): the roster totals are maintained incrementally by the
        provision/deprovision flips, so the integral only needs the
        elapsed span times the current totals."""
        now = self.sim.now()
        dt = now - self._prov_mark
        if dt > 0.0:
            self._prov_node_area += self._prov_nodes * dt
            self._prov_cpu_area += self._prov_cpu * dt
            self._prov_mem_area += self._prov_mem * dt
            self._prov_mark = now

    def provision_node(self, name: str):
        """Autoscaler scale-up: bring a deprovisioned node back into
        the roster.  Accrues the cost integrals at the old capacity,
        flips the provisioned bit, then rejoins the scheduler through
        the ordinary :meth:`restore_node` path (ready-array writes,
        node MODIFIED fan-out, scheduler kick) — the native mirrors
        keep their fixed indices because the node object never left
        ``_node_seq``."""
        node = self.nodes[name]
        if node.provisioned:
            return
        self._accrue_provisioned()
        node.provisioned = True
        node._rv += 1
        self._prov_nodes += 1
        self._prov_cpu += node.cpu_alloc
        self._prov_mem += node.mem_alloc
        if self._prov_nodes > self._prov_peak:
            self._prov_peak = self._prov_nodes
        self.provision_flips += 1
        self.restore_node(name)

    def deprovision_node(self, name: str) -> int:
        """Autoscaler scale-down: cordon + drain the node through the
        PR-7 reclaim path (residents requeue with no retry-budget
        charge), then remove its capacity from the provisioned
        roster.  While deprovisioned the node is invisible to chaos
        victim picks and immune to late ``restore_node`` rejoins.
        Returns the number of pods disrupted (zero when the caller
        only drains idle nodes)."""
        node = self.nodes[name]
        if not node.provisioned:
            return 0
        lost = self.drain_node(name) if node.ready else 0
        self._accrue_provisioned()
        node.provisioned = False
        node._rv += 1
        self._prov_nodes -= 1
        self._prov_cpu -= node.cpu_alloc
        self._prov_mem -= node.mem_alloc
        if self._prov_nodes < self._prov_low:
            self._prov_low = self._prov_nodes
        self.provision_flips += 1
        return lost

    def cost_summary(self) -> Dict[str, float]:
        """Provisioned-capacity cost axes: node/cpu/mem-seconds paid
        and the time-weighted utilization of that paid capacity.
        Windowed to ``last_event_t`` like :meth:`hotspot_summary`
        (drained sims park the clock at the horizon).  Every field is
        a plain sum/extremum over the run, so sharded planes merge it
        exactly: areas and flips add, peaks/lows take max/min, and
        the ratios are recomputed from the pooled areas."""
        now = min(self.sim.now(),
                  getattr(self.sim, "last_event_t", self.sim.now()))
        span = max(0.0, now - self._prov_mark)
        node_s = self._prov_node_area + self._prov_nodes * span
        cpu_s = self._prov_cpu_area + self._prov_cpu * span
        mem_s = self._prov_mem_area + self._prov_mem * span
        use_span = max(0.0, now - self._use_mark)
        used_cpu_s = self._use_cpu_area + self.cpu_in_use * use_span
        used_mem_s = self._use_mem_area + self.mem_in_use * use_span
        return {
            "node_seconds": node_s,
            "cpu_mcore_seconds": cpu_s,
            "mem_mib_seconds": mem_s,
            "used_cpu_mcore_seconds": used_cpu_s,
            "used_mem_mib_seconds": used_mem_s,
            "cpu_util_over_provisioned": (
                used_cpu_s / cpu_s if cpu_s > 0 else 0.0),
            "mem_util_over_provisioned": (
                used_mem_s / mem_s if mem_s > 0 else 0.0),
            "provisioned_peak_nodes": float(self._prov_peak),
            "provisioned_low_nodes": float(self._prov_low),
            "provision_flips": float(self.provision_flips),
        }

    # ---- reads (each list is an apiserver round-trip — the pressure the
    # Informer cache avoids; watch-driven callers never come here) ----------
    def list_pods(self, namespace: Optional[str] = None) -> List[PodObj]:
        self.api_calls += 1
        if namespace is None:
            return list(self.pods.values())
        return list(self._pods_by_ns.get(namespace, {}).values())

    def list_nodes(self) -> List[NodeObj]:
        self.api_calls += 1
        return list(self.nodes.values())

    def list_namespaces(self) -> List[NamespaceObj]:
        self.api_calls += 1
        return list(self.namespaces.values())

    def list_pvcs(self, namespace: Optional[str] = None) -> List[PVCObj]:
        self.api_calls += 1
        if namespace is None:
            return list(self.pvcs.values())
        pvcs = self.pvcs
        return [pvcs[k] for k in self._pvcs_by_ns.get(namespace, ())
                if k in pvcs]

    def allocatable(self) -> Tuple[int, int]:
        cpu = sum(n.cpu_alloc for n in self.nodes.values() if n.ready)
        mem = sum(n.mem_alloc for n in self.nodes.values() if n.ready)
        return cpu, mem

    def used(self) -> Tuple[int, int]:
        # exact running totals, O(1); equals the node scan at all times
        # (pinned by tests/test_event_core.py)
        return self.cpu_in_use, self.mem_in_use

    def used_scan(self) -> Tuple[int, int]:
        """Reference node scan; equals ``used()`` at every instant."""
        cpu = sum(n.cpu_used for n in self.nodes.values())
        mem = sum(n.mem_used for n in self.nodes.values())
        return cpu, mem
