"""Streaming accumulators: flat-memory statistics for the stress tier.

At 1000 workflows a run produces tens of thousands of pod records and
resource samples; appending every observation to a Python list makes
metrics memory grow with run length. ``StreamingStat`` keeps O(1)
state — count / mean / min / max via Welford-style online updates —
plus a fixed-size uniform reservoir so percentiles stay answerable
without retaining the stream.

The reservoir RNG is self-seeded and private: it never touches the
cluster's scheduling RNG, so enabling streaming metrics cannot perturb
the seeded disordered-scheduler sequence.
"""
from __future__ import annotations

import random
from typing import List


class StreamingStat:
    """Online count/mean/min/max + reservoir-sampled percentiles."""

    __slots__ = ("count", "mean", "max", "min", "_m2",
                 "_reservoir", "_capacity", "_rng")

    def __init__(self, reservoir: int = 512, seed: int = 0xC0FFEE):
        self.count = 0
        self.mean = 0.0
        self.max = float("-inf")
        self.min = float("inf")
        self._m2 = 0.0
        self._reservoir: List[float] = []
        self._capacity = reservoir
        self._rng = random.Random(seed)

    def add(self, x: float):
        self.count += 1
        delta = x - self.mean
        self.mean += delta / self.count
        self._m2 += delta * (x - self.mean)
        if x > self.max:
            self.max = x
        if x < self.min:
            self.min = x
        if len(self._reservoir) < self._capacity:
            self._reservoir.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self._capacity:
                self._reservoir[j] = x

    @property
    def variance(self) -> float:
        return self._m2 / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (0-100) from the reservoir."""
        if not self._reservoir:
            return float("nan")
        xs = sorted(self._reservoir)
        idx = min(len(xs) - 1, max(0, round(q / 100.0 * (len(xs) - 1))))
        return xs[idx]

    def __repr__(self):
        return (f"StreamingStat(count={self.count}, mean={self.mean:.4g}, "
                f"min={self.min:.4g}, max={self.max:.4g})")
