"""Streaming accumulators: flat-memory statistics for the stress tier.

At 1000 workflows a run produces tens of thousands of pod records and
resource samples; appending every observation to a Python list makes
metrics memory grow with run length. ``StreamingStat`` keeps O(1)
state — count / mean / min / max via Welford-style online updates —
plus a fixed-size uniform reservoir so percentiles stay answerable
without retaining the stream.

The reservoir RNG is self-seeded and private: it never touches the
cluster's scheduling RNG, so enabling streaming metrics cannot perturb
the seeded disordered-scheduler sequence.

``StepAccumulator`` (ISSUE 3) is the event-driven replacement for the
0.5 s resource-usage sampler: cluster usage is a piecewise-constant
step function that only changes at pod bind/release, so instead of
polling it on a daemon (whose event count scales with *sim time*), the
accumulator is fed each change and keeps the exact per-level residence
times.  Mean, peak, and time-weighted percentiles then come out in
closed form — exact where the sampler was approximate, and at zero
sim-event cost.  Distinct levels are bounded by the workload's request
quantisation (a few hundred values), so memory stays flat.
"""
from __future__ import annotations

import random
from typing import Dict, List


class StreamingStat:
    """Online count/mean/min/max + reservoir-sampled percentiles."""

    __slots__ = ("count", "mean", "max", "min", "_m2",
                 "_reservoir", "_capacity", "_rng")

    def __init__(self, reservoir: int = 512, seed: int = 0xC0FFEE):
        self.count = 0
        self.mean = 0.0
        self.max = float("-inf")
        self.min = float("inf")
        self._m2 = 0.0
        self._reservoir: List[float] = []
        self._capacity = reservoir
        self._rng = random.Random(seed)

    def add(self, x: float):
        self.count += 1
        delta = x - self.mean
        self.mean += delta / self.count
        self._m2 += delta * (x - self.mean)
        if x > self.max:
            self.max = x
        if x < self.min:
            self.min = x
        if len(self._reservoir) < self._capacity:
            self._reservoir.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self._capacity:
                self._reservoir[j] = x

    @property
    def variance(self) -> float:
        return self._m2 / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (0-100) from the reservoir."""
        if not self._reservoir:
            return float("nan")
        xs = sorted(self._reservoir)
        idx = min(len(xs) - 1, max(0, round(q / 100.0 * (len(xs) - 1))))
        return xs[idx]

    def __repr__(self):
        return (f"StreamingStat(count={self.count}, mean={self.mean:.4g}, "
                f"min={self.min:.4g}, max={self.max:.4g})")


class StepAccumulator:
    """Exact time-weighted statistics of a step function.

    Feed every level change via ``set(t, level)`` (or close the
    current interval with ``close(t)``); the accumulator integrates
    residence time per level.  All reads are closed-form over the
    recorded intervals ``[start_t, last_t]``.
    """

    __slots__ = ("level", "peak", "start_t", "last_t", "level_dur", "changes")

    def __init__(self, t0: float = 0.0, level: float = 0):
        self.level = level
        self.peak = level
        self.start_t = t0
        self.last_t = t0
        self.level_dur: Dict[float, float] = {}
        self.changes = 0

    def set(self, t: float, level: float):
        dt = t - self.last_t
        if dt > 0.0:
            ld = self.level_dur
            cur = self.level
            ld[cur] = ld.get(cur, 0.0) + dt
            self.last_t = t
        if level != self.level:
            self.changes += 1
            self.level = level
            if level > self.peak:
                self.peak = level

    def add(self, t: float, delta: float):
        self.set(t, self.level + delta)

    def close(self, t: float):
        """Integrate the open interval up to ``t`` (idempotent)."""
        self.set(t, self.level)

    @property
    def total_time(self) -> float:
        return self.last_t - self.start_t

    def mean(self) -> float:
        tot = self.total_time
        if tot <= 0.0:
            return 0.0
        return sum(lv * d for lv, d in self.level_dur.items()) / tot

    def percentile(self, q: float) -> float:
        """Smallest level the function sits at or below for ``q`` % of
        the recorded time (exact, time-weighted)."""
        if not self.level_dur:
            return float(self.level)
        tot = self.total_time
        target = q / 100.0 * tot
        cum = 0.0
        levels = sorted(self.level_dur)
        for lv in levels:
            cum += self.level_dur[lv]
            if cum >= target - 1e-12 * tot:
                return lv
        return levels[-1]

    def __repr__(self):
        return (f"StepAccumulator(level={self.level}, peak={self.peak}, "
                f"changes={self.changes}, total_time={self.total_time:.4g})")
