"""Streaming accumulators: flat-memory statistics for the stress tier.

At 1000 workflows a run produces tens of thousands of pod records and
resource samples; appending every observation to a Python list makes
metrics memory grow with run length. ``StreamingStat`` keeps O(1)
state — count / mean / min / max via Welford-style online updates —
plus a fixed-size uniform reservoir so percentiles stay answerable
without retaining the stream.

The reservoir RNG is self-seeded and private: it never touches the
cluster's scheduling RNG, so enabling streaming metrics cannot perturb
the seeded disordered-scheduler sequence.

``StepAccumulator`` (ISSUE 3) is the event-driven replacement for the
0.5 s resource-usage sampler: cluster usage is a piecewise-constant
step function that only changes at pod bind/release, so instead of
polling it on a daemon (whose event count scales with *sim time*), the
accumulator is fed each change and keeps the exact per-level residence
times.  Mean, peak, and time-weighted percentiles then come out in
closed form — exact where the sampler was approximate, and at zero
sim-event cost.  Distinct levels are bounded by the workload's request
quantisation (a few hundred values), so memory stays flat.

Sharded control plane (ISSUE 6): both accumulators are *mergeable*.
``StreamingStat.merge`` composes count/mean/variance exactly (Chan's
parallel update), min/max exactly, and unions the percentile
reservoirs (weighted subsample when the union overflows the
capacity — deterministic, driven by the stat's own private RNG).
``StepAccumulator.merge`` composes two recorded windows as if the
second followed the first: per-level residence times add, the peak is
the max of peaks, so a step stream split at any boundary and merged
equals the unsplit accumulation exactly.  Both types pickle cleanly,
so per-shard partials travel over the result pipe and the parent
reconstructs global summaries (see core/shard.py).
"""
from __future__ import annotations

import random
from typing import Dict, List


class StreamingStat:
    """Online count/mean/min/max + reservoir-sampled percentiles."""

    __slots__ = ("count", "mean", "max", "min", "_m2",
                 "_reservoir", "_capacity", "_rng")

    def __init__(self, reservoir: int = 512, seed: int = 0xC0FFEE):
        self.count = 0
        self.mean = 0.0
        self.max = float("-inf")
        self.min = float("inf")
        self._m2 = 0.0
        self._reservoir: List[float] = []
        self._capacity = reservoir
        self._rng = random.Random(seed)

    def add(self, x: float):
        self.count += 1
        delta = x - self.mean
        self.mean += delta / self.count
        self._m2 += delta * (x - self.mean)
        if x > self.max:
            self.max = x
        if x < self.min:
            self.min = x
        if len(self._reservoir) < self._capacity:
            self._reservoir.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self._capacity:
                self._reservoir[j] = x

    def merge(self, other: "StreamingStat") -> "StreamingStat":
        """Fold ``other`` into self (Chan's parallel variance update).

        count / min / max compose exactly; mean and variance compose
        exactly up to float associativity.  Reservoirs are unioned;
        when the union exceeds capacity a weighted subsample is drawn
        with self's private RNG (each parent's entries are kept with
        probability proportional to the stream size they represent),
        so percentile quality is preserved and the result is
        deterministic for a deterministic merge order.
        """
        if other.count == 0:
            return self
        if self.count == 0:
            self.count = other.count
            self.mean = other.mean
            self._m2 = other._m2
            self.max = other.max
            self.min = other.min
            self._reservoir = list(other._reservoir)
            return self
        n_a, n_b = self.count, other.count
        n = n_a + n_b
        delta = other.mean - self.mean
        self.mean += delta * (n_b / n)
        self._m2 += other._m2 + delta * delta * (n_a * n_b / n)
        self.count = n
        if other.max > self.max:
            self.max = other.max
        if other.min < self.min:
            self.min = other.min
        union = self._reservoir + list(other._reservoir)
        if len(union) > self._capacity:
            # Weighted subsample: items from the larger stream should
            # survive proportionally more often.  Each reservoir item
            # stands for count/len(reservoir) observations.
            w_a = n_a / max(1, len(self._reservoir))
            w_b = n_b / max(1, len(other._reservoir))
            weights = ([w_a] * len(self._reservoir)
                       + [w_b] * len(other._reservoir))
            picked = []
            total_w = sum(weights)
            rng = self._rng
            for _ in range(self._capacity):
                r = rng.random() * total_w
                acc = 0.0
                for i, w in enumerate(weights):
                    acc += w
                    if r <= acc:
                        picked.append(union[i])
                        total_w -= w
                        del union[i], weights[i]
                        break
                else:  # float slack: take the last remaining item
                    picked.append(union.pop())
                    total_w -= weights.pop()
            self._reservoir = picked
        else:
            self._reservoir = union
        return self

    @property
    def variance(self) -> float:
        return self._m2 / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (0-100) from the reservoir."""
        if not self._reservoir:
            return float("nan")
        xs = sorted(self._reservoir)
        idx = min(len(xs) - 1, max(0, round(q / 100.0 * (len(xs) - 1))))
        return xs[idx]

    def __repr__(self):
        return (f"StreamingStat(count={self.count}, mean={self.mean:.4g}, "
                f"min={self.min:.4g}, max={self.max:.4g})")


class StepAccumulator:
    """Exact time-weighted statistics of a step function.

    Feed every level change via ``set(t, level)`` (or close the
    current interval with ``close(t)``); the accumulator integrates
    residence time per level.  All reads are closed-form over the
    recorded intervals ``[start_t, last_t]``.
    """

    __slots__ = ("level", "peak", "start_t", "last_t", "level_dur", "changes")

    def __init__(self, t0: float = 0.0, level: float = 0):
        self.level = level
        self.peak = level
        self.start_t = t0
        self.last_t = t0
        self.level_dur: Dict[float, float] = {}
        self.changes = 0

    def set(self, t: float, level: float):
        dt = t - self.last_t
        if dt > 0.0:
            ld = self.level_dur
            cur = self.level
            ld[cur] = ld.get(cur, 0.0) + dt
            self.last_t = t
        if level != self.level:
            self.changes += 1
            self.level = level
            if level > self.peak:
                self.peak = level

    def add(self, t: float, delta: float):
        self.set(t, self.level + delta)

    def close(self, t: float):
        """Integrate the open interval up to ``t`` (idempotent)."""
        self.set(t, self.level)

    def merge(self, other: "StepAccumulator") -> "StepAccumulator":
        """Compose two recorded windows (self, then other).

        Per-level residence times add, ``peak`` is the max of peaks,
        ``changes`` add, and the recorded span extends by the other's
        span — so an accumulation split at any closed boundary and
        merged equals the unsplit accumulation exactly.  Both sides
        should be ``close``d first; the merged ``level`` is the
        other's final level (the later window).
        """
        ld = self.level_dur
        for lv, d in other.level_dur.items():
            ld[lv] = ld.get(lv, 0.0) + d
        if other.peak > self.peak:
            self.peak = other.peak
        self.changes += other.changes
        self.last_t += other.total_time
        self.level = other.level
        return self

    @property
    def total_time(self) -> float:
        return self.last_t - self.start_t

    def mean(self) -> float:
        tot = self.total_time
        if tot <= 0.0:
            return 0.0
        return sum(lv * d for lv, d in self.level_dur.items()) / tot

    def percentile(self, q: float) -> float:
        """Smallest level the function sits at or below for ``q`` % of
        the recorded time (exact, time-weighted)."""
        if not self.level_dur:
            return float(self.level)
        tot = self.total_time
        target = q / 100.0 * tot
        cum = 0.0
        levels = sorted(self.level_dur)
        for lv in levels:
            cum += self.level_dur[lv]
            if cum >= target - 1e-12 * tot:
                return lv
        return levels[-1]

    def __repr__(self):
        return (f"StepAccumulator(level={self.level}, peak={self.peak}, "
                f"changes={self.changes}, total_time={self.total_time:.4g})")
