"""Resource gathering & allocation (§4.3) + multi-tenant admission.

``ResourceGatherer`` is the paper's module: it reads NodeLister/
PodLister from the informer cache (never the apiserver), computes
cluster headroom as

    available = sum(Allocatable of ready nodes)        (master excluded —
              - sum(Requests of non-terminal pods)      it isn't in the
                                                        node list at all)

and gates task-pod creation on fit, so KubeAdaptor admits exactly as
many concurrent task pods as the cluster can hold instead of flooding
the scheduler queue.

``AdmissionArbiter`` promotes that stateless gate into the control
plane's shared admission point. Concurrent workflows from many tenants
contend for the same headroom, so the arbiter adds:

* a pending queue of not-yet-admitted (workflow, task) requests,
  re-evaluated whenever a pod frees resources — a starved workflow is
  woken by *any* tenant's completions, not only its own;
* a reservation ledger for pods granted but not yet visible in the
  informer cache (the watch+informer latency window), preventing two
  workflows from double-spending the same headroom;
* pluggable admission policies (``ADMISSION_POLICIES``):

    fifo        arrival order (paper-equivalent for one stream)
    priority    higher tenant priority first, FIFO within a class
    fair-share  weighted max-min: grant to the tenant with the lowest
                in-use-cpu / weight ratio first

Tenants are registered with ``set_tenant(name, priority=, weight=)``;
unregistered tenants get priority 0 / weight 1.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import FAILED, PENDING, RUNNING, SUCCEEDED
from repro.core.dag import Task
from repro.core.informer import InformerSet


class ResourceGatherer:
    def __init__(self, informers: InformerSet):
        self.inf = informers

    def allocatable(self) -> Tuple[int, int]:
        cpu = mem = 0
        for node in self.inf.nodes.lister():
            if node.ready:
                cpu += node.cpu_alloc
                mem += node.mem_alloc
        return cpu, mem

    def requested(self) -> Tuple[int, int]:
        cpu = mem = 0
        for pod in self.inf.pods.lister():
            if pod.phase in (PENDING, RUNNING):
                cpu += pod.cpu_m
                mem += pod.mem_mi
        return cpu, mem

    def available(self) -> Tuple[int, int]:
        (ca, ma), (cr, mr) = self.allocatable(), self.requested()
        return ca - cr, ma - mr

    def fits(self, task: Task) -> bool:
        cpu, mem = task.resource_request()
        ac, am = self.available()
        return cpu <= ac and mem <= am

    def admit(self, tasks: List[Task]) -> List[Task]:
        """Greedy admission of a ready set within current headroom."""
        ac, am = self.available()
        out = []
        for t in tasks:
            cpu, mem = t.resource_request()
            if cpu <= ac and mem <= am:
                out.append(t)
                ac -= cpu
                am -= mem
        return out


# ---------------------------------------------------------------------------
# admission requests + tenant accounting
# ---------------------------------------------------------------------------
@dataclass
class AdmissionRequest:
    namespace: str
    tenant: str
    task: Task
    create: Callable[[Task], None]
    seq: int
    deferred: bool = False

    def key(self) -> Tuple[str, str]:
        return (self.namespace, self.task.id)


@dataclass
class TenantShare:
    priority: int = 0
    weight: float = 1.0
    granted: int = 0               # pods admitted over the run
    deferred: int = 0              # requests that had to wait at least once


# ---------------------------------------------------------------------------
# policies: given the pending set, pick the next request to consider
# ---------------------------------------------------------------------------
class FifoPolicy:
    name = "fifo"

    def order(self, pending: List[AdmissionRequest],
              arbiter: "AdmissionArbiter") -> List[AdmissionRequest]:
        return sorted(pending, key=lambda r: r.seq)

    def may_backfill(self, blocked: AdmissionRequest,
                     candidate: AdmissionRequest,
                     arbiter: "AdmissionArbiter") -> bool:
        # FIFO is work-conserving: smaller later tasks may slip past a
        # blocked one (the paper gatherer's greedy behaviour)
        return True


class PriorityPolicy:
    name = "priority"

    def order(self, pending: List[AdmissionRequest],
              arbiter: "AdmissionArbiter") -> List[AdmissionRequest]:
        def rank(r: AdmissionRequest):
            return (-arbiter.tenant(r.tenant).priority, r.seq)
        return sorted(pending, key=rank)

    def may_backfill(self, blocked: AdmissionRequest,
                     candidate: AdmissionRequest,
                     arbiter: "AdmissionArbiter") -> bool:
        # never jump a *higher*-priority blocked request — a stream of
        # small low-priority tasks must not starve a big high-priority
        # one; backfill within the same class is fine (FIFO there)
        return (arbiter.tenant(candidate.tenant).priority
                >= arbiter.tenant(blocked.tenant).priority)


class FairSharePolicy:
    """Weighted max-min: most-underserved tenant (in-use cpu / weight)
    goes first; FIFO inside a tenant."""

    name = "fair-share"

    def order(self, pending: List[AdmissionRequest],
              arbiter: "AdmissionArbiter") -> List[AdmissionRequest]:
        usage = arbiter.tenant_usage_cpu()

        def rank(r: AdmissionRequest):
            share = arbiter.tenant(r.tenant)
            return (usage.get(r.tenant, 0) / max(share.weight, 1e-9), r.seq)
        return sorted(pending, key=rank)

    def may_backfill(self, blocked: AdmissionRequest,
                     candidate: AdmissionRequest,
                     arbiter: "AdmissionArbiter") -> bool:
        return True

    # ranking depends on per-tenant usage, which every grant changes —
    # the arbiter must re-order after each grant (fifo/priority don't)
    dynamic_order = True


ADMISSION_POLICIES = {
    "fifo": FifoPolicy,
    "priority": PriorityPolicy,
    "fair-share": FairSharePolicy,
}


class AdmissionArbiter(ResourceGatherer):
    """Stateful, policy-driven admission shared by all live workflows."""

    def __init__(self, informers: InformerSet, policy: str = "fifo",
                 on_defer: Optional[Callable[[str], None]] = None):
        super().__init__(informers)
        if isinstance(policy, str):
            policy = ADMISSION_POLICIES[policy]()
        self.policy = policy
        self.on_defer = on_defer
        self.pending: Dict[Tuple[str, str], AdmissionRequest] = {}
        # (ns, pod name) -> (tenant, cpu, mem, reserved_at)
        self.reserved: Dict[Tuple[str, str], Tuple[str, int, int, float]] = {}
        self.tenants: Dict[str, TenantShare] = {}
        self.admitted = 0
        self.deferrals = 0
        self._seq = 0

    # -- tenant registry ----------------------------------------------------
    def set_tenant(self, name: str, priority: int = 0, weight: float = 1.0):
        self.tenants[name] = TenantShare(priority=priority, weight=weight)

    def tenant(self, name: str) -> TenantShare:
        if name not in self.tenants:
            self.tenants[name] = TenantShare()
        return self.tenants[name]

    # -- accounting ---------------------------------------------------------
    def _sync_reservations(self):
        """Drop reservations for pods the informer now sees as
        non-terminal — from that point ``requested()`` accounts for
        them. (A FAILED/SUCCEEDED cache entry can be a *previous*
        incarnation of a retried pod name, so it doesn't count.)"""
        cache = self.inf.pods.cache
        for key in [k for k in self.reserved
                    if k in cache and cache[k].phase in (PENDING, RUNNING)]:
            del self.reserved[key]

    def reserve(self, namespace: str, name: str, tenant: str,
                cpu: int, mem: int):
        """Charge headroom for a pod whose creation is in flight but not
        yet visible in the informer cache. Engines call this for EVERY
        pod they create (granted, retried, or speculative twin), closing
        the watch+informer latency double-spend window. The timestamp
        lets ``pod_removed`` tell which incarnation of a reused pod name
        a reservation belongs to."""
        now = self.inf.pods.sim.now()
        self.reserved.setdefault((namespace, name), (tenant, cpu, mem, now))

    def available(self) -> Tuple[int, int]:
        self._sync_reservations()
        ac, am = super().available()
        for _, cpu, mem, _t in self.reserved.values():
            ac -= cpu
            am -= mem
        return ac, am

    def tenant_usage_cpu(self) -> Dict[str, int]:
        """CPU currently held per tenant: informer-visible non-terminal
        pods plus not-yet-visible reservations."""
        self._sync_reservations()
        usage: Dict[str, int] = {}
        for pod in self.inf.pods.lister():
            if pod.phase in (PENDING, RUNNING):
                t = pod.labels.get("tenant", "default")
                usage[t] = usage.get(t, 0) + pod.cpu_m
        for tenant, cpu, _mem, _t in self.reserved.values():
            usage[tenant] = usage.get(tenant, 0) + cpu
        return usage

    # -- request lifecycle ----------------------------------------------------
    def submit(self, namespace: str, tenant: str, tasks: List[Task],
               create: Callable[[Task], None]):
        """Queue admission requests (idempotent per (namespace, task))
        and immediately evaluate the pending set."""
        for task in tasks:
            req = AdmissionRequest(namespace, tenant, task, create,
                                   seq=self._seq)
            self._seq += 1
            self.pending.setdefault(req.key(), req)
        self.evaluate()

    def evaluate(self):
        """Grant as many pending requests as headroom (and the policy's
        backfill rule) allows. Headroom is decremented locally per grant
        (one cluster scan per evaluate, not per grant); fifo/priority
        orderings are grant-invariant so they grant in a single sorted
        pass, while fair-share re-ranks after every grant because its
        usage/weight key shifts as grants accrue. The grant callback
        performs the actual pod creation and charges the reservation
        (via ``reserve`` inside the engine's create path); it returns
        False for a stale grant the engine declined, which then counts
        toward nothing."""
        ac, am = self.available()
        dynamic = getattr(self.policy, "dynamic_order", False)
        progress = True
        while progress and self.pending:
            progress = False
            blocked: List[AdmissionRequest] = []
            for req in self.policy.order(list(self.pending.values()), self):
                cpu, mem = req.task.resource_request()
                if (cpu <= ac and mem <= am
                        and all(self.policy.may_backfill(b, req, self)
                                for b in blocked)):
                    del self.pending[req.key()]
                    if req.create(req.task) is not False:
                        self.admitted += 1
                        self.tenant(req.tenant).granted += 1
                        ac -= cpu
                        am -= mem
                    progress = True
                    if dynamic:
                        break          # re-rank with the new usage
                else:
                    blocked.append(req)
            if not dynamic:
                break                  # one sorted pass granted all that fit
        # whatever is still pending had to wait at least once
        for req in self.pending.values():
            if not req.deferred:
                req.deferred = True
                self.deferrals += 1
                self.tenant(req.tenant).deferred += 1
                if self.on_defer:
                    self.on_defer(req.tenant)

    def pod_removed(self, pod):
        """A pod freed resources: drop its reservation (if still held)
        and wake pending requests of every tenant.

        A retried pod can be re-created under the same name *before*
        the old incarnation's DELETED event reaches the informer; the
        reservation timestamp tells the incarnations apart — a
        reservation made after the removed pod was created belongs to
        the replacement and must survive."""
        key = (pod.namespace, pod.name)
        held = self.reserved.get(key)
        if held is not None and held[3] <= pod.created:
            del self.reserved[key]
        if self.pending:
            self.evaluate()

    def forget_namespace(self, namespace: str):
        for key in [k for k in self.pending if k[0] == namespace]:
            del self.pending[key]
        for key in [k for k in self.reserved if k[0] == namespace]:
            del self.reserved[key]
