"""Resource gathering & allocation (§4.3) + the admission pipeline driver.

``ResourceGatherer`` is the paper's module: it reads NodeLister/
PodLister from the informer cache (never the apiserver), computes
cluster headroom as

    available = sum(Allocatable of ready nodes)        (master excluded —
              - sum(Requests of non-terminal pods)      it isn't in the
                                                        node list at all)

and gates task-pod creation on fit, so KubeAdaptor admits exactly as
many concurrent task pods as the cluster can hold instead of flooding
the scheduler queue.

``AdmissionArbiter`` promotes that stateless gate into the control
plane's shared admission point, now a thin driver over the staged
pipeline in ``repro.core.policy`` (ISSUE 4):

    QueueOrder   fifo / priority / fair-share / drf plugins own their
                 specialized O(1)-ish walk structures (policy/ordering)
    Filter       hard per-tenant quota caps (policy/filters), consulted
                 inside the walks, inert until a cap is registered
    Reserve      the reservation ledger closing the informer-latency
                 double-spend window (policy/reservations)
    Permit       grant bookkeeping — ``_create_bookkeep`` fires the
                 engine callback and updates tenant counters
    Preempt      starvation-triggered eviction of lower-priority
                 RUNNING pods (policy/preemption), armed by the
                 ``preempt`` preset

The arbiter keeps the cross-stage state the walks share: the pending
queue (re-evaluated whenever any tenant's pod frees resources), the
value-count multisets behind the ``_no_fit_possible`` early exit, the
deferral ledger, and the tenant registry (``set_tenant`` now carries
quota caps next to priority/weight).  Every scheduling decision of the
pre-pipeline monolith is preserved bit-for-bit: the legacy policies'
binding-sequence hashes are pinned by tests/test_scale_core.py and
tests/test_policy_pipeline.py, and the specialized walks still match
the generic re-sort loop (``_evaluate_generic``, kept as the reference
and the path for custom/legacy policy objects).
"""
from __future__ import annotations

from collections import Counter
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import PENDING, RUNNING
from repro.core.dag import Task
from repro.core.informer import InformerSet
from repro.core.policy import (POLICY_PRESETS, AdmissionRequest,
                               DominantShareOrder, FairShareOrder, FifoOrder,
                               PipelineSpec, Preemptor, PriorityOrder,
                               QueueOrder, ReservationLedger, TenantQuotaFilter,
                               TenantShare, make_order, resolve_policy)

# legacy aliases: the monolith's policy classes live on as the ordering
# plugins (same names importable from here, same three-entry registry —
# new names live in repro.core.policy.QUEUE_ORDERS / POLICY_PRESETS)
FifoPolicy = FifoOrder
PriorityPolicy = PriorityOrder
FairSharePolicy = FairShareOrder

ADMISSION_POLICIES = {
    "fifo": FifoOrder,
    "priority": PriorityOrder,
    "fair-share": FairShareOrder,
}


class ResourceGatherer:
    def __init__(self, informers: InformerSet):
        self.inf = informers
        self._alloc_cache: Tuple[int, int] = (0, 0)
        self._alloc_gen = -1

    def allocatable(self) -> Tuple[int, int]:
        nodes = self.inf.nodes
        if nodes.generation != self._alloc_gen:
            cpu = mem = 0
            for node in nodes.lister():
                if node.ready:
                    cpu += node.cpu_alloc
                    mem += node.mem_alloc
            self._alloc_cache = (cpu, mem)
            self._alloc_gen = nodes.generation
        return self._alloc_cache

    def requested(self) -> Tuple[int, int]:
        pods = self.inf.pods
        return pods.nonterminal_cpu, pods.nonterminal_mem

    def _requested_scan(self) -> Tuple[int, int]:
        """Reference cache scan; equals ``requested()`` at all times
        (the informer aggregates are exact — see test_scale_core)."""
        cpu = mem = 0
        for pod in self.inf.pods.lister():
            if pod.phase in (PENDING, RUNNING):
                cpu += pod.cpu_m
                mem += pod.mem_mi
        return cpu, mem

    def available(self) -> Tuple[int, int]:
        (ca, ma), (cr, mr) = self.allocatable(), self.requested()
        return ca - cr, ma - mr

    def fits(self, task: Task) -> bool:
        cpu, mem = task.resource_request()
        ac, am = self.available()
        return cpu <= ac and mem <= am

    def admit(self, tasks: List[Task]) -> List[Task]:
        """Greedy admission of a ready set within current headroom."""
        ac, am = self.available()
        out = []
        for t in tasks:
            cpu, mem = t.resource_request()
            if cpu <= ac and mem <= am:
                out.append(t)
                ac -= cpu
                am -= mem
        return out


class AdmissionArbiter(ResourceGatherer):
    """Stateful, policy-driven admission shared by all live workflows —
    the pipeline driver (stages in repro.core.policy)."""

    def __init__(self, informers: InformerSet, policy: str = "fifo",
                 on_defer: Optional[Callable[[str], None]] = None,
                 on_quota_reject: Optional[Callable[[str], None]] = None,
                 evict: Optional[Callable[[str, str], bool]] = None,
                 preempt: Optional[bool] = None,
                 preempt_cooldown_s: float = 5.0):
        super().__init__(informers)
        spec = resolve_policy(policy)
        self.order_plugin = make_order(spec).bind(self)
        # back-compat alias: the order plugin carries the old policy
        # object's order()/may_backfill() surface
        self.policy = self.order_plugin
        self.filters = [TenantQuotaFilter().bind(self)]
        self.ledger = ReservationLedger()
        if preempt is None:
            preempt = isinstance(spec, PipelineSpec) and spec.preempt
        self.preemptor = (Preemptor(cooldown_s=preempt_cooldown_s).bind(self)
                          if preempt else None)
        self.evict = evict
        self.on_defer = on_defer
        self.on_quota_reject = on_quota_reject
        self.pending: Dict[Tuple[str, str], AdmissionRequest] = {}
        self.tenants: Dict[str, TenantShare] = {}
        self.admitted = 0
        self.grant_batches = 0             # evaluates granting >= 1 request
        self.deferrals = 0
        self.quota_rejects = 0
        self.preemptions = 0               # RUNNING pods evicted
        self.preemption_log: List[dict] = []
        self.max_pending = 0               # peak admission-queue depth
        # submission-edge outcomes, fed by the DurableGateway so bench
        # rows and tenant_summary read them here instead of reaching
        # into gateway internals (ISSUE 10); all zero when no gate
        self.gateway_rejects = 0
        self.gateway_retries = 0
        self.gateway_shed = 0
        self._seq = 0
        self._quota_active = False         # any tenant with a cap?
        self._fresh: List[AdmissionRequest] = []   # not yet deferral-checked
        self._min_cpu = Counter()      # value -> count over pending requests
        self._min_mem = Counter()
        # only plugins with a specialized walk take the fast path;
        # legacy order/may_backfill objects run the generic loop
        self._fast = callable(getattr(self.order_plugin, "walk", None))

    def counters(self) -> Dict[str, int]:
        """Compact counter export (shard result records): everything
        the benchmarks read off the arbiter, no object graph."""
        return {"admitted": self.admitted,
                "grant_batches": self.grant_batches,
                "deferrals": self.deferrals,
                "quota_rejects": self.quota_rejects,
                "preemptions": self.preemptions,
                "max_pending": self.max_pending,
                "gateway_rejects": self.gateway_rejects,
                "gateway_retries": self.gateway_retries,
                "gateway_shed": self.gateway_shed}

    def note_gateway(self, kind: str):
        """Submission-edge event from the DurableGateway."""
        if kind == "reject":
            self.gateway_rejects += 1
        elif kind == "retry":
            self.gateway_retries += 1
        elif kind == "shed":
            self.gateway_shed += 1
        else:
            raise ValueError(f"unknown gateway event {kind!r}")

    # -- tenant registry ----------------------------------------------------
    def set_tenant(self, name: str, priority: int = 0, weight: float = 1.0,
                   quota_cpu_m: int = 0, quota_mem_mi: int = 0):
        self.tenants[name] = TenantShare(priority=priority, weight=weight,
                                         quota_cpu_m=quota_cpu_m,
                                         quota_mem_mi=quota_mem_mi)
        if quota_cpu_m or quota_mem_mi:
            self._quota_active = True

    def tenant(self, name: str) -> TenantShare:
        if name not in self.tenants:
            self.tenants[name] = TenantShare()
        return self.tenants[name]

    # -- Reserve stage ------------------------------------------------------
    @property
    def reserved(self):
        return self.ledger.reserved

    def reserve(self, namespace: str, name: str, tenant: str,
                cpu: int, mem: int):
        """Charge headroom for a pod whose creation is in flight but not
        yet visible in the informer cache. Engines call this for EVERY
        pod they create (granted, retried, or speculative twin), closing
        the watch+informer latency double-spend window."""
        self.ledger.reserve(namespace, name, tenant, cpu, mem,
                            self.inf.pods.sim.now())

    def available(self) -> Tuple[int, int]:
        self.ledger.sync(self.inf.pods)
        ac, am = super().available()
        return ac - self.ledger.cpu, am - self.ledger.mem

    def tenant_usage_cpu(self) -> Dict[str, int]:
        """CPU currently held per tenant: informer-visible non-terminal
        pods plus not-yet-visible reservations (O(tenants) — the
        fair-share walk reads this once per grant round)."""
        self.ledger.sync(self.inf.pods)
        usage = dict(self.inf.pods.nonterminal_cpu_by_tenant)
        for tenant, cpu in self.ledger.cpu_by_tenant.items():
            usage[tenant] = usage.get(tenant, 0) + cpu
        return usage

    def tenant_usage(self) -> Tuple[Dict[str, int], Dict[str, int]]:
        """(cpu, mem) held per tenant — one reservation sync, both
        axes; the drf walk reads this once per grant round."""
        self.ledger.sync(self.inf.pods)
        pods = self.inf.pods
        cpu = dict(pods.nonterminal_cpu_by_tenant)
        for tenant, c in self.ledger.cpu_by_tenant.items():
            cpu[tenant] = cpu.get(tenant, 0) + c
        mem = dict(pods.nonterminal_mem_by_tenant)
        for tenant, m in self.ledger.mem_by_tenant.items():
            mem[tenant] = mem.get(tenant, 0) + m
        return cpu, mem

    # -- Filter stage -------------------------------------------------------
    def _filters_allow(self, req: AdmissionRequest) -> bool:
        """Side-effect-free filter probe (no reject accounting)."""
        for f in self.filters:
            if not f.permits(req):
                return False
        return True

    def _permits(self, req: AdmissionRequest) -> bool:
        """Consulted by the walks at the exact point the headroom
        fit-check passes; inert until a tenant registers a cap."""
        if not self._quota_active:
            return True
        if self._filters_allow(req):
            return True
        if not req.quota_rejected:
            req.quota_rejected = True
            self.quota_rejects += 1
            self.tenant(req.tenant).quota_rejects += 1
            if self.on_quota_reject:
                self.on_quota_reject(req.tenant)
        return False

    # -- request lifecycle --------------------------------------------------
    def submit(self, namespace: str, tenant: str, tasks: List[Task],
               create: Callable[[Task], None]):
        """Queue admission requests (idempotent per (namespace, task))
        and immediately evaluate the pending set."""
        if not tasks:
            # nothing new to queue: every submit with no ready tasks
            # rides the pod-removal chain, whose informer delete
            # callback already evaluated at this instant with this
            # exact state — a re-evaluate is a provable no-op
            return
        for task in tasks:
            cpu, mem = task.resource_request()
            req = AdmissionRequest(namespace, tenant, task, create,
                                   seq=self._seq, cpu=cpu, mem=mem)
            self._seq += 1
            key = req.key()
            if key not in self.pending:
                self.pending[key] = req
                self._index_add(req)
        if len(self.pending) > self.max_pending:
            self.max_pending = len(self.pending)
        self.evaluate()

    def _index_add(self, req: AdmissionRequest):
        self._fresh.append(req)
        self._min_cpu[req.cpu] += 1
        self._min_mem[req.mem] += 1
        self.order_plugin.on_add(req)

    def _counters_remove(self, req: AdmissionRequest):
        self._min_cpu[req.cpu] -= 1
        if not self._min_cpu[req.cpu]:
            del self._min_cpu[req.cpu]
        self._min_mem[req.mem] -= 1
        if not self._min_mem[req.mem]:
            del self._min_mem[req.mem]

    def _index_remove(self, req: AdmissionRequest):
        self._counters_remove(req)
        self.order_plugin.on_remove(req)

    # -- Permit stage -------------------------------------------------------
    def _create_bookkeep(self, req: AdmissionRequest) -> bool:
        """Fire the grant callback; True when it consumed headroom (a
        stale grant the engine declined consumes none)."""
        if req.create(req.task) is not False:
            self.admitted += 1
            self.tenant(req.tenant).granted += 1
            return True
        return False

    def _grant(self, req: AdmissionRequest) -> bool:
        del self.pending[req.key()]
        self._index_remove(req)
        return self._create_bookkeep(req)

    def _mark_deferred(self):
        """Every request still pending after an evaluate has waited at
        least once. Only requests submitted since the last evaluate can
        be newly deferred, so the check is O(new), not O(pending)."""
        if self._fresh:
            pending = self.pending
            for req in self._fresh:
                if not req.deferred and pending.get(req.key()) is req:
                    req.deferred = True
                    self.deferrals += 1
                    self.tenant(req.tenant).deferred += 1
                    if self.on_defer:
                        self.on_defer(req.tenant)
            self._fresh.clear()

    def _no_fit_possible(self, ac: int, am: int) -> bool:
        """True when headroom is below every pending request on at
        least one axis — no walk can grant anything."""
        return (ac < min(self._min_cpu) if self._min_cpu else False) or \
               (am < min(self._min_mem) if self._min_mem else False)

    def evaluate(self):
        """Drive the pipeline once: grant as many pending requests as
        headroom, the ordering plugin's walk, and the filters allow,
        then mark deferrals and give the Preempt stage its shot."""
        before = self.admitted
        if not self._fast:
            self._evaluate_generic()
        else:
            # available() is called unconditionally, exactly like the
            # generic loop: its reservation-sync side effect must run
            # at the same instants or reservations outlive their
            # informer visibility window and headroom diverges
            ac, am = self.available()
            if self.pending:
                self.order_plugin.walk(ac, am)
        if self.admitted != before:
            self.grant_batches += 1        # one multi-grant admission round
        self._mark_deferred()
        if self.preemptor is not None:
            self.preemptor.maybe_preempt()

    # -- generic loop (reference + custom-policy path) -----------------------
    def _evaluate_generic(self):
        ac, am = self.available()
        policy = self.order_plugin
        dynamic = getattr(policy, "dynamic_order", False)
        progress = True
        while progress and self.pending:
            progress = False
            blocked: List[AdmissionRequest] = []
            for req in policy.order(list(self.pending.values()), self):
                cpu, mem = req.task.resource_request()
                if cpu <= ac and mem <= am:
                    if not self._permits(req):
                        continue   # capped: skips, never bars others
                    if all(policy.may_backfill(b, req, self)
                           for b in blocked):
                        if self._grant(req):
                            ac -= cpu
                            am -= mem
                        progress = True
                        if dynamic:
                            break  # re-rank with the new usage
                        continue
                blocked.append(req)
            if not dynamic:
                break              # one sorted pass granted all that fit

    def pod_removed(self, pod):
        """A pod freed resources: drop its reservation (if still held —
        unless it belongs to a newer incarnation of a reused name) and
        wake pending requests of every tenant."""
        self.ledger.release_if_current((pod.namespace, pod.name), pod.created)
        if self.pending:
            self.evaluate()

    def forget_namespace(self, namespace: str):
        for key in [k for k in self.pending if k[0] == namespace]:
            req = self.pending.pop(key)
            self._index_remove(req)
        self.ledger.drop_namespace(namespace)
