"""Resource gathering & allocation (§4.3) + multi-tenant admission.

``ResourceGatherer`` is the paper's module: it reads NodeLister/
PodLister from the informer cache (never the apiserver), computes
cluster headroom as

    available = sum(Allocatable of ready nodes)        (master excluded —
              - sum(Requests of non-terminal pods)      it isn't in the
                                                        node list at all)

and gates task-pod creation on fit, so KubeAdaptor admits exactly as
many concurrent task pods as the cluster can hold instead of flooding
the scheduler queue.

``AdmissionArbiter`` promotes that stateless gate into the control
plane's shared admission point. Concurrent workflows from many tenants
contend for the same headroom, so the arbiter adds:

* a pending queue of not-yet-admitted (workflow, task) requests,
  re-evaluated whenever a pod frees resources — a starved workflow is
  woken by *any* tenant's completions, not only its own;
* a reservation ledger for pods granted but not yet visible in the
  informer cache (the watch+informer latency window), preventing two
  workflows from double-spending the same headroom;
* pluggable admission policies (``ADMISSION_POLICIES``):

    fifo        arrival order (paper-equivalent for one stream)
    priority    higher tenant priority first, FIFO within a class
    fair-share  weighted max-min: grant to the tenant with the lowest
                in-use-cpu / weight ratio first

Tenants are registered with ``set_tenant(name, priority=, weight=)``;
unregistered tenants get priority 0 / weight 1.

Scale-out evaluation (ISSUE 2): the generic re-sort-everything loop
(`_evaluate_generic`, kept as reference and as the path for custom
policies) is O(P log P) per wake-up — ruinous at a 1000-workflow
backlog where every pod completion re-evaluates thousands of pending
requests. The built-in policies run specialized walks that reproduce
the generic loop's grant sequence EXACTLY (same order, same deferral
counts — pinned by tests/test_scale_core.py):

* fifo        walks the seq-ordered pending dict directly (no copy);
* priority    walks a bisect-maintained (-priority, seq) list and stops
              once a blocked higher class makes further grants illegal;
* fair-share  lazily merges per-tenant FIFO queues through a heap keyed
              (usage/weight, seq), identical to sorting every request;

all three stop early when remaining headroom is below the smallest
pending request (tracked by value-count multisets), so a saturated
evaluate is O(1) instead of O(P). ``requested()`` reads the pod
informer's running aggregates instead of scanning its cache, and
``allocatable()`` is cached on the node informer's generation.

10k-workflow tier (ISSUE 3): reservation reconciliation no longer
scans the whole ledger per evaluate — only keys the informer cache
wrote since the last sync plus reservations added since then can have
become droppable (see ``_sync_reservations`` for the exactness
argument), and per-tenant reserved-cpu totals make
``tenant_usage_cpu`` O(tenants) instead of O(ledger) per fair-share
grant round.  The arbiter is the single consumer of the pod
informer's ``touched`` list: exactly one arbiter per InformerSet.
"""
from __future__ import annotations

import heapq
from bisect import bisect_left, insort
from collections import Counter, deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core.cluster import FAILED, PENDING, RUNNING, SUCCEEDED
from repro.core.dag import Task
from repro.core.informer import InformerSet


class ResourceGatherer:
    def __init__(self, informers: InformerSet):
        self.inf = informers
        self._alloc_cache: Tuple[int, int] = (0, 0)
        self._alloc_gen = -1

    def allocatable(self) -> Tuple[int, int]:
        nodes = self.inf.nodes
        if nodes.generation != self._alloc_gen:
            cpu = mem = 0
            for node in nodes.lister():
                if node.ready:
                    cpu += node.cpu_alloc
                    mem += node.mem_alloc
            self._alloc_cache = (cpu, mem)
            self._alloc_gen = nodes.generation
        return self._alloc_cache

    def requested(self) -> Tuple[int, int]:
        pods = self.inf.pods
        return pods.nonterminal_cpu, pods.nonterminal_mem

    def _requested_scan(self) -> Tuple[int, int]:
        """Reference cache scan; equals ``requested()`` at all times
        (the informer aggregates are exact — see test_scale_core)."""
        cpu = mem = 0
        for pod in self.inf.pods.lister():
            if pod.phase in (PENDING, RUNNING):
                cpu += pod.cpu_m
                mem += pod.mem_mi
        return cpu, mem

    def available(self) -> Tuple[int, int]:
        (ca, ma), (cr, mr) = self.allocatable(), self.requested()
        return ca - cr, ma - mr

    def fits(self, task: Task) -> bool:
        cpu, mem = task.resource_request()
        ac, am = self.available()
        return cpu <= ac and mem <= am

    def admit(self, tasks: List[Task]) -> List[Task]:
        """Greedy admission of a ready set within current headroom."""
        ac, am = self.available()
        out = []
        for t in tasks:
            cpu, mem = t.resource_request()
            if cpu <= ac and mem <= am:
                out.append(t)
                ac -= cpu
                am -= mem
        return out


# ---------------------------------------------------------------------------
# admission requests + tenant accounting
# ---------------------------------------------------------------------------
@dataclass
class AdmissionRequest:
    namespace: str
    tenant: str
    task: Task
    create: Callable[[Task], None]
    seq: int
    cpu: int = 0                   # cached task.resource_request()
    mem: int = 0
    deferred: bool = False

    def key(self) -> Tuple[str, str]:
        return (self.namespace, self.task.id)


@dataclass
class TenantShare:
    priority: int = 0
    weight: float = 1.0
    granted: int = 0               # pods admitted over the run
    deferred: int = 0              # requests that had to wait at least once


# ---------------------------------------------------------------------------
# policies: given the pending set, pick the next request to consider
# ---------------------------------------------------------------------------
class FifoPolicy:
    name = "fifo"

    def order(self, pending: List[AdmissionRequest],
              arbiter: "AdmissionArbiter") -> List[AdmissionRequest]:
        return sorted(pending, key=lambda r: r.seq)

    def may_backfill(self, blocked: AdmissionRequest,
                     candidate: AdmissionRequest,
                     arbiter: "AdmissionArbiter") -> bool:
        # FIFO is work-conserving: smaller later tasks may slip past a
        # blocked one (the paper gatherer's greedy behaviour)
        return True


class PriorityPolicy:
    name = "priority"

    def order(self, pending: List[AdmissionRequest],
              arbiter: "AdmissionArbiter") -> List[AdmissionRequest]:
        def rank(r: AdmissionRequest):
            return (-arbiter.tenant(r.tenant).priority, r.seq)
        return sorted(pending, key=rank)

    def may_backfill(self, blocked: AdmissionRequest,
                     candidate: AdmissionRequest,
                     arbiter: "AdmissionArbiter") -> bool:
        # never jump a *higher*-priority blocked request — a stream of
        # small low-priority tasks must not starve a big high-priority
        # one; backfill within the same class is fine (FIFO there)
        return (arbiter.tenant(candidate.tenant).priority
                >= arbiter.tenant(blocked.tenant).priority)


class FairSharePolicy:
    """Weighted max-min: most-underserved tenant (in-use cpu / weight)
    goes first; FIFO inside a tenant."""

    name = "fair-share"

    def order(self, pending: List[AdmissionRequest],
              arbiter: "AdmissionArbiter") -> List[AdmissionRequest]:
        usage = arbiter.tenant_usage_cpu()

        def rank(r: AdmissionRequest):
            share = arbiter.tenant(r.tenant)
            return (usage.get(r.tenant, 0) / max(share.weight, 1e-9), r.seq)
        return sorted(pending, key=rank)

    def may_backfill(self, blocked: AdmissionRequest,
                     candidate: AdmissionRequest,
                     arbiter: "AdmissionArbiter") -> bool:
        return True

    # ranking depends on per-tenant usage, which every grant changes —
    # the arbiter must re-order after each grant (fifo/priority don't)
    dynamic_order = True


ADMISSION_POLICIES = {
    "fifo": FifoPolicy,
    "priority": PriorityPolicy,
    "fair-share": FairSharePolicy,
}


class AdmissionArbiter(ResourceGatherer):
    """Stateful, policy-driven admission shared by all live workflows."""

    def __init__(self, informers: InformerSet, policy: str = "fifo",
                 on_defer: Optional[Callable[[str], None]] = None):
        super().__init__(informers)
        if isinstance(policy, str):
            policy = ADMISSION_POLICIES[policy]()
        self.policy = policy
        self.on_defer = on_defer
        self.pending: Dict[Tuple[str, str], AdmissionRequest] = {}
        # (ns, pod name) -> (tenant, cpu, mem, reserved_at)
        self.reserved: Dict[Tuple[str, str], Tuple[str, int, int, float]] = {}
        self.tenants: Dict[str, TenantShare] = {}
        self.admitted = 0
        self.deferrals = 0
        self.max_pending = 0           # peak admission-queue depth
        self._seq = 0
        self._reserved_cpu = 0
        self._reserved_mem = 0
        self._reserved_cpu_by_tenant: Dict[str, int] = {}
        self._fresh_reserved: List[Tuple[str, str]] = []   # since last sync
        self._fresh: List[AdmissionRequest] = []   # not yet deferral-checked
        self._min_cpu = Counter()      # value -> count over pending requests
        self._min_mem = Counter()
        # priority: (-tenant priority, seq, request), bisect-sorted
        self._prio_order: List[Tuple[int, int, AdmissionRequest]] = []
        # fair-share: per-tenant FIFO of requests (lazy-deleted)
        self._by_tenant: Dict[str, Deque[AdmissionRequest]] = {}
        # subclasses may override order()/may_backfill(): only the exact
        # built-in types take the specialized walks
        self._fast = type(self.policy) in (FifoPolicy, PriorityPolicy,
                                           FairSharePolicy)

    # -- tenant registry ----------------------------------------------------
    def set_tenant(self, name: str, priority: int = 0, weight: float = 1.0):
        self.tenants[name] = TenantShare(priority=priority, weight=weight)

    def tenant(self, name: str) -> TenantShare:
        if name not in self.tenants:
            self.tenants[name] = TenantShare()
        return self.tenants[name]

    # -- accounting ---------------------------------------------------------
    def _sync_reservations(self):
        """Drop reservations for pods the informer now sees as
        non-terminal — from that point ``requested()`` accounts for
        them. (A FAILED/SUCCEEDED cache entry can be a *previous*
        incarnation of a retried pod name, so it doesn't count.)

        Only candidate keys are checked instead of the whole ledger:
        a reservation can become droppable only if its cache entry was
        written since the last sync (``informer.touched``) or it was
        added since then (``_fresh_reserved``) — any key already
        checked and kept, with an untouched cache entry, would be kept
        again. Exactly the full scan's drop set, at O(changes) cost
        (the full ledger scan per evaluate dominated the 10k-workflow
        admission profile)."""
        pods = self.inf.pods
        touched = pods.touched
        fresh = self._fresh_reserved
        reserved = self.reserved
        if not reserved:
            if touched:
                touched.clear()
            if fresh:
                fresh.clear()
            return
        cache = pods.cache
        for candidates in (touched, fresh):
            for key in candidates:
                held = reserved.get(key)
                if held is None:
                    continue
                pod = cache.get(key)
                if pod is not None and pod.phase in (PENDING, RUNNING):
                    del reserved[key]
                    self._reserved_cpu -= held[1]
                    self._reserved_mem -= held[2]
                    self._tenant_unreserve(held[0], held[1])
        if touched:
            touched.clear()
        if fresh:
            fresh.clear()

    def _tenant_unreserve(self, tenant: str, cpu: int):
        by = self._reserved_cpu_by_tenant
        left = by[tenant] - cpu
        if left:
            by[tenant] = left
        else:
            del by[tenant]

    def reserve(self, namespace: str, name: str, tenant: str,
                cpu: int, mem: int):
        """Charge headroom for a pod whose creation is in flight but not
        yet visible in the informer cache. Engines call this for EVERY
        pod they create (granted, retried, or speculative twin), closing
        the watch+informer latency double-spend window. The timestamp
        lets ``pod_removed`` tell which incarnation of a reused pod name
        a reservation belongs to."""
        key = (namespace, name)
        if key not in self.reserved:
            self.reserved[key] = (tenant, cpu, mem, self.inf.pods.sim.now())
            self._reserved_cpu += cpu
            self._reserved_mem += mem
            by = self._reserved_cpu_by_tenant
            by[tenant] = by.get(tenant, 0) + cpu
            self._fresh_reserved.append(key)

    def _drop_reservation(self, key: Tuple[str, str]):
        held = self.reserved.pop(key, None)
        if held is not None:
            self._reserved_cpu -= held[1]
            self._reserved_mem -= held[2]
            self._tenant_unreserve(held[0], held[1])

    def available(self) -> Tuple[int, int]:
        self._sync_reservations()
        ac, am = super().available()
        return ac - self._reserved_cpu, am - self._reserved_mem

    def tenant_usage_cpu(self) -> Dict[str, int]:
        """CPU currently held per tenant: informer-visible non-terminal
        pods plus not-yet-visible reservations (O(tenants) — the
        fair-share walk reads this once per grant round)."""
        self._sync_reservations()
        usage = dict(self.inf.pods.nonterminal_cpu_by_tenant)
        for tenant, cpu in self._reserved_cpu_by_tenant.items():
            usage[tenant] = usage.get(tenant, 0) + cpu
        return usage

    # -- request lifecycle ----------------------------------------------------
    def submit(self, namespace: str, tenant: str, tasks: List[Task],
               create: Callable[[Task], None]):
        """Queue admission requests (idempotent per (namespace, task))
        and immediately evaluate the pending set."""
        for task in tasks:
            cpu, mem = task.resource_request()
            req = AdmissionRequest(namespace, tenant, task, create,
                                   seq=self._seq, cpu=cpu, mem=mem)
            self._seq += 1
            key = req.key()
            if key not in self.pending:
                self.pending[key] = req
                self._index_add(req)
        if len(self.pending) > self.max_pending:
            self.max_pending = len(self.pending)
        self.evaluate()

    def _index_add(self, req: AdmissionRequest):
        self._fresh.append(req)
        self._min_cpu[req.cpu] += 1
        self._min_mem[req.mem] += 1
        if isinstance(self.policy, PriorityPolicy):
            insort(self._prio_order,
                   (-self.tenant(req.tenant).priority, req.seq, req))
        elif isinstance(self.policy, FairSharePolicy):
            self._by_tenant.setdefault(req.tenant, deque()).append(req)

    def _counters_remove(self, req: AdmissionRequest):
        self._min_cpu[req.cpu] -= 1
        if not self._min_cpu[req.cpu]:
            del self._min_cpu[req.cpu]
        self._min_mem[req.mem] -= 1
        if not self._min_mem[req.mem]:
            del self._min_mem[req.mem]

    def _index_remove(self, req: AdmissionRequest):
        self._counters_remove(req)
        if isinstance(self.policy, PriorityPolicy):
            order = self._prio_order
            # seq is unique, so tuple comparison never reaches the
            # request; a 2-tuple probe sorts just before its entry
            i = bisect_left(order, (-self.tenant(req.tenant).priority,
                                    req.seq))
            if i < len(order) and order[i][2] is req:
                del order[i]
            else:   # priority changed since insert: find by identity
                for j, entry in enumerate(order):
                    if entry[2] is req:
                        del order[j]
                        break
        # fair-share per-tenant deques are lazy-deleted during the walk

    def _create_bookkeep(self, req: AdmissionRequest) -> bool:
        """Fire the grant callback; True when it consumed headroom (a
        stale grant the engine declined consumes none) — identical
        bookkeeping to the generic loop."""
        if req.create(req.task) is not False:
            self.admitted += 1
            self.tenant(req.tenant).granted += 1
            return True
        return False

    def _grant(self, req: AdmissionRequest) -> bool:
        del self.pending[req.key()]
        self._index_remove(req)
        return self._create_bookkeep(req)

    def _mark_deferred(self):
        """Every request still pending after an evaluate has waited at
        least once. Only requests submitted since the last evaluate can
        be newly deferred, so the check is O(new), not O(pending)."""
        if self._fresh:
            pending = self.pending
            for req in self._fresh:
                if not req.deferred and pending.get(req.key()) is req:
                    req.deferred = True
                    self.deferrals += 1
                    self.tenant(req.tenant).deferred += 1
                    if self.on_defer:
                        self.on_defer(req.tenant)
            self._fresh.clear()

    def _no_fit_possible(self, ac: int, am: int) -> bool:
        """True when headroom is below every pending request on at
        least one axis — no walk can grant anything."""
        return (ac < min(self._min_cpu) if self._min_cpu else False) or \
               (am < min(self._min_mem) if self._min_mem else False)

    def evaluate(self):
        """Grant as many pending requests as headroom (and the policy's
        backfill rule) allows; see the module docstring for the
        specialized walks and their equivalence to the generic loop."""
        if not self._fast:
            self._evaluate_generic()
            self._mark_deferred()
            return
        # available() is called unconditionally, exactly like the
        # generic loop: its _sync_reservations side effect must run at
        # the same instants or reservations outlive their informer
        # visibility window and headroom diverges
        ac, am = self.available()
        if self.pending:
            if isinstance(self.policy, FairSharePolicy):
                self._walk_fair_share(ac, am)
            elif not self._no_fit_possible(ac, am):
                if isinstance(self.policy, FifoPolicy):
                    self._walk_fifo(ac, am)
                else:
                    self._walk_priority(ac, am)
        self._mark_deferred()

    # -- specialized walks (exact replicas of _evaluate_generic) ------------
    def _walk_fifo(self, ac: int, am: int):
        # generic fifo: one pass in seq order, always-backfill — i.e.
        # first-fit down the queue. The pending dict IS seq-ordered, so
        # walk it directly; pending deletion is deferred past the loop
        # (grants never mutate the dict — verified: the engine's create
        # path only schedules sim events and charges reservations).
        grants: List[AdmissionRequest] = []
        for req in self.pending.values():
            if req.cpu <= ac and req.mem <= am:
                grants.append(req)
                self._counters_remove(req)
                if self._create_bookkeep(req):
                    ac -= req.cpu
                    am -= req.mem
                    if self._no_fit_possible(ac, am):
                        break      # nothing further can fit
        for req in grants:
            del self.pending[req.key()]

    def _walk_priority(self, ac: int, am: int):
        # generic priority: one pass in (-priority, seq) order; a
        # blocked request bars every strictly-lower class behind it, so
        # the walk may stop at the first lower class after a block.
        order = self._prio_order
        grants: List[AdmissionRequest] = []
        max_blocked_prio: Optional[int] = None
        i = 0
        while i < len(order):
            req = order[i][2]
            if self.pending.get(req.key()) is not req:
                del order[i]       # ghost entry from a priority change
                continue
            prio = self.tenant(req.tenant).priority
            if max_blocked_prio is not None and prio < max_blocked_prio:
                break              # all remaining are lower still
            if req.cpu <= ac and req.mem <= am:
                del order[i]
                grants.append(req)
                self._counters_remove(req)
                if self._create_bookkeep(req):
                    ac -= req.cpu
                    am -= req.mem
                    if self._no_fit_possible(ac, am):
                        break
                continue           # entries shifted left: same index
            if max_blocked_prio is None or prio > max_blocked_prio:
                max_blocked_prio = prio
            i += 1
        for req in grants:
            del self.pending[req.key()]

    def _walk_fair_share(self, ac: int, am: int):
        # generic fair-share re-sorts all requests by (usage/weight,
        # seq) and grants the first fit, once per grant. The lazy merge
        # over per-tenant FIFO queues pops requests in exactly that
        # order (seq ties across equal-ratio tenants included) without
        # materializing it.
        pending = self.pending
        while True:
            if not pending:
                return
            # one sync per round, mirroring the generic loop's order()
            # call at the top of every pass (final no-grant pass too)
            usage = self.tenant_usage_cpu()
            if self._no_fit_possible(ac, am):
                return
            heap = []
            for tenant, q in self._by_tenant.items():
                while q and pending.get(q[0].key()) is not q[0]:
                    q.popleft()    # granted/forgotten leftovers
                if q:
                    share = self.tenant(tenant)
                    ratio = usage.get(tenant, 0) / max(share.weight, 1e-9)
                    heap.append((ratio, q[0].seq, tenant, 0))
            if not heap:
                return
            heapq.heapify(heap)
            granted = False
            while heap:
                ratio, _seq, tenant, idx = heapq.heappop(heap)
                q = self._by_tenant[tenant]
                req = q[idx]       # push-time staleness check keeps
                if req.cpu <= ac and req.mem <= am:   # entries live
                    if self._grant(req):
                        ac -= req.cpu
                        am -= req.mem
                    granted = True
                    break          # re-rank with the new usage
                nxt = idx + 1
                while nxt < len(q) and pending.get(q[nxt].key()) is not q[nxt]:
                    nxt += 1
                if nxt < len(q):
                    heapq.heappush(heap, (ratio, q[nxt].seq, tenant, nxt))
            if not granted:
                return

    # -- generic loop (reference + custom-policy path) -----------------------
    def _evaluate_generic(self):
        ac, am = self.available()
        dynamic = getattr(self.policy, "dynamic_order", False)
        progress = True
        while progress and self.pending:
            progress = False
            blocked: List[AdmissionRequest] = []
            for req in self.policy.order(list(self.pending.values()), self):
                cpu, mem = req.task.resource_request()
                if (cpu <= ac and mem <= am
                        and all(self.policy.may_backfill(b, req, self)
                                for b in blocked)):
                    if self._grant(req):
                        ac -= cpu
                        am -= mem
                    progress = True
                    if dynamic:
                        break          # re-rank with the new usage
                else:
                    blocked.append(req)
            if not dynamic:
                break                  # one sorted pass granted all that fit

    def pod_removed(self, pod):
        """A pod freed resources: drop its reservation (if still held)
        and wake pending requests of every tenant.

        A retried pod can be re-created under the same name *before*
        the old incarnation's DELETED event reaches the informer; the
        reservation timestamp tells the incarnations apart — a
        reservation made after the removed pod was created belongs to
        the replacement and must survive."""
        key = (pod.namespace, pod.name)
        held = self.reserved.get(key)
        if held is not None and held[3] <= pod.created:
            self._drop_reservation(key)
        if self.pending:
            self.evaluate()

    def forget_namespace(self, namespace: str):
        for key in [k for k in self.pending if k[0] == namespace]:
            req = self.pending.pop(key)
            self._index_remove(req)
        for key in [k for k in self.reserved if k[0] == namespace]:
            self._drop_reservation(key)
