"""Resource gathering & allocation module (§4.3).

Reads NodeLister/PodLister from the informer cache (never the
apiserver), computes cluster headroom as

    available = sum(Allocatable of ready nodes)        (master excluded —
              - sum(Requests of non-terminal pods)      it isn't in the
                                                        node list at all)

and gates task-pod creation on fit. This is what lets KubeAdaptor admit
exactly as many concurrent task pods as the cluster can hold instead of
flooding the scheduler queue.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.cluster import FAILED, PENDING, RUNNING, SUCCEEDED
from repro.core.dag import Task
from repro.core.informer import InformerSet


class ResourceGatherer:
    def __init__(self, informers: InformerSet):
        self.inf = informers

    def allocatable(self) -> Tuple[int, int]:
        cpu = mem = 0
        for node in self.inf.nodes.lister():
            if node.ready:
                cpu += node.cpu_alloc
                mem += node.mem_alloc
        return cpu, mem

    def requested(self) -> Tuple[int, int]:
        cpu = mem = 0
        for pod in self.inf.pods.lister():
            if pod.phase in (PENDING, RUNNING):
                cpu += pod.cpu_m
                mem += pod.mem_mi
        return cpu, mem

    def available(self) -> Tuple[int, int]:
        (ca, ma), (cr, mr) = self.allocatable(), self.requested()
        return ca - cr, ma - mr

    def fits(self, task: Task) -> bool:
        cpu, mem = task.resource_request()
        ac, am = self.available()
        return cpu <= ac and mem <= am

    def admit(self, tasks: List[Task]) -> List[Task]:
        """Greedy admission of a ready set within current headroom."""
        ac, am = self.available()
        out = []
        for t in tasks:
            cpu, mem = t.resource_request()
            if cpu <= ac and mem <= am:
                out.append(t)
                ac -= cpu
                am -= mem
        return out
