"""Durable submission front door (ISSUE 10): WAL + admission backpressure.

The ROADMAP's millions-of-users item asks for a pkbs-style submission
service: a durable queue in front of the arbiter shards, admission
backpressure instead of unbounded pending, and ``qstat``-style
introspection.  This module supplies all three as a wrapper around the
multi-stream ``WorkflowGateway`` (core/injector.py):

* ``SubmissionWAL`` — a per-shard append-only submission log.  Every
  record is deterministic (monotonic submission id, tenant, arrival
  ``t``, workflow spec digest) and sha256-chained: ``chain_n =
  sha256(chain_{n-1} + line_n)``, so any mutation, drop, or reorder of
  the log is detectable from the head hash alone.  Records live in
  bounded in-memory segments; an optional file sink (one JSON line per
  record, flushed per append) survives a worker crash.  On restart the
  WAL loads the file, truncates a torn tail line (a crash mid-write),
  and *replays*: each regenerated submission is verified field-for-field
  against the logged record at its id — the log is the authority for
  what the outside world already submitted, so a diverging replay
  raises ``WalReplayError`` instead of silently double-running — and
  exactly-once dedup guarantees each submission id reaches the engine
  at most once even when the chaos plane drops or duplicates the
  transport hop.

* ``BackpressurePolicy`` — frozen, picklable (crosses the fork inside
  ``ShardSpec``).  ``max_pending`` bounds the submissions admitted past
  the gate and not yet finished; a breach rejects the submission with a
  deterministic retry-after timer.  The retry jitter draws from a
  dedicated sha256-spawned stream (``repro-gate/{seed}/{shard}``), so
  scheduler / chaos / shuffle word streams are untouched and every
  pinned binding hash holds; an unsaturated gateway performs zero
  draws and adds zero sim events — bit-identical to no gateway at all.
  ``shed`` picks the victim when pressure persists: ``reject-newest``
  sheds the arriving submission once its client retries are exhausted,
  ``shed-oldest`` bounds the retry room by evicting its oldest entry,
  ``fair-shed`` evicts from the tenant hogging the retry room.

* ``GatewayStats`` — the qstat surface: per-tenant
  queued/admitted/running/done/rejected/retried/shed counters plus the
  current retry-after horizon, snapshotted as plain dicts that merge
  across shards exactly like the PR-6 metrics partials
  (``merge_gateway_snapshots``: counters sum, peaks max).

Determinism argument: every WAL append, admission check, and retry
draw happens inside the single-threaded sim loop in event order.  Two
runs with the same workload, seed, and policy consume the identical
gate-stream draw sequence; a mid-run shard kill replays the WAL prefix
under verification and regenerates the suffix, so the merged metrics
are bit-identical to a never-crashed run (pinned by
tests/test_gateway.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

__all__ = ["BackpressurePolicy", "DurableGateway", "GatewayStats",
           "SubmissionWAL", "WalReplayError", "gate_stream_seed",
           "workflow_digest", "merge_gateway_snapshots"]

SHED_MODES = ("reject-newest", "shed-oldest", "fair-shed")
WAL_GENESIS = hashlib.sha256(b"repro-wal/genesis").hexdigest()
WAL_SEGMENT = 4096


def gate_stream_seed(seed: int, shard: int) -> int:
    """Decorrelate the gateway's retry-jitter stream from every other
    consumer of the run seed (scheduler RNG, chaos streams, shard
    seeds) — same sha256-spawn scheme under its own tag."""
    digest = hashlib.sha256(
        f"repro-gate/{seed}/{shard}".encode("ascii")).digest()
    return int.from_bytes(digest[:8], "big")


def workflow_digest(tenant: str, name: str, instance: int) -> str:
    """Deterministic spec digest for one submission (the WAL's replay
    verification key: same tenant/topology/instance => same digest)."""
    return hashlib.sha256(
        f"{tenant}/{name}#{instance}".encode("utf-8")).hexdigest()[:16]


def _wal_line(rec: dict) -> str:
    return json.dumps(rec, sort_keys=True, separators=(",", ":"))


class WalReplayError(RuntimeError):
    """The WAL is corrupt, or a restarted shard's regenerated arrivals
    diverged from the logged submissions — never silently continue."""


class SubmissionWAL:
    """Append-only, sha256-chained submission log for one shard.

    In-memory segments always; ``path`` adds the crash-durable file
    sink.  When the file already holds records (a prior incarnation
    died mid-run), appends replay against that prefix: each record is
    verified field-for-field and NOT rewritten; appends beyond the
    prefix extend the file.  ``replayed`` counts verified prefix
    records — the observable proof a restart recovered from the log.
    """

    def __init__(self, path: Optional[str] = None,
                 segment_size: int = WAL_SEGMENT):
        if segment_size < 1:
            raise ValueError("segment_size must be >= 1")
        self.path = path
        self.segment_size = segment_size
        self.segments: List[List[dict]] = []
        self.count = 0
        self.chain = WAL_GENESIS
        self.replayed = 0
        self._expected: List[dict] = []
        self._sink = None
        if path:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self._expected = self._load_and_trim(path)
            self._sink = open(path, "a")

    @staticmethod
    def _load_and_trim(path: str) -> List[dict]:
        """Load the durable prefix; verify the chain line by line; drop
        (and truncate away) a torn tail line from a crash mid-write."""
        try:
            with open(path, "rb") as f:
                raw = f.read()
        except FileNotFoundError:
            return []
        records: List[dict] = []
        chain = WAL_GENESIS
        valid_len = 0
        offset = 0
        for chunk in raw.split(b"\n"):
            if not chunk:
                offset += 1
                continue
            line = chunk.decode("utf-8", errors="replace")
            complete = raw[offset + len(chunk):offset + len(chunk) + 1] \
                == b"\n"
            try:
                rec = json.loads(line)
                ok = (isinstance(rec, dict)
                      and rec.get("id") == len(records)
                      and _wal_line(rec) == line)
            except ValueError:
                ok = False
            if not ok or not complete:
                if complete:
                    raise WalReplayError(
                        f"corrupt WAL record at id {len(records)} in "
                        f"{path}")
                break               # torn tail: the crash interrupted a write
            chain = hashlib.sha256((chain + line).encode()).hexdigest()
            records.append(rec)
            offset += len(chunk) + 1
            valid_len = offset
        if valid_len < len(raw):
            os.truncate(path, valid_len)
        return records

    def append(self, tenant: str, t: float, digest: str) -> dict:
        rec = {"id": self.count, "tenant": tenant, "t": t, "digest": digest}
        line = _wal_line(rec)
        if self.count < len(self._expected):
            exp = self._expected[self.count]
            if exp != rec:
                raise WalReplayError(
                    f"WAL replay diverged at submission {self.count}: "
                    f"logged {exp}, regenerated {rec}")
            self.replayed += 1
        elif self._sink is not None:
            self._sink.write(line + "\n")
            self._sink.flush()
        if not self.segments or len(self.segments[-1]) >= self.segment_size:
            self.segments.append([])
        self.segments[-1].append(rec)
        self.chain = hashlib.sha256((self.chain + line).encode()).hexdigest()
        self.count += 1
        return rec

    def records(self) -> List[dict]:
        return [rec for seg in self.segments for rec in seg]

    def verify(self) -> bool:
        """Recompute the chain over the in-memory segments and compare
        with the running head — the integrity check."""
        chain = WAL_GENESIS
        for seg in self.segments:
            for rec in seg:
                chain = hashlib.sha256(
                    (chain + _wal_line(rec)).encode()).hexdigest()
        return chain == self.chain

    def close(self):
        if self._sink is not None:
            self._sink.close()
            self._sink = None


@dataclass(frozen=True)
class BackpressurePolicy:
    """Admission backpressure at the submission edge (frozen: crosses
    the fork boundary inside ``ShardSpec`` unchanged — per-shard
    decorrelation comes from the gate stream seed, not the policy)."""

    max_pending: int = 64          # admitted-but-unfinished cap per shard
    per_tenant_cap: int = 0        # per-tenant in-flight cap (0 = uncapped)
    shed: str = "reject-newest"
    retry_after_s: float = 5.0     # client retry-after base (jittered)
    max_client_retries: int = 8    # rejects before a submission sheds

    def __post_init__(self):
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        if self.per_tenant_cap < 0 or self.max_client_retries < 0:
            raise ValueError("per_tenant_cap / max_client_retries "
                             "must be >= 0")
        if self.retry_after_s <= 0:
            raise ValueError("retry_after_s must be > 0")
        if self.shed not in SHED_MODES:
            raise ValueError(f"unknown shed mode {self.shed!r}; "
                             f"expected one of {SHED_MODES}")


_COUNTERS = ("submissions", "admitted", "rejected", "retried", "shed",
             "done")
_GAUGES = ("queued", "running")
_FAULTS = ("dropped", "duplicated", "deduped", "redelivered")


class GatewayStats:
    """qstat-style introspection: per-tenant counters and gauges, plus
    gateway-level peaks and the retry-after horizon.  ``snapshot()``
    emits plain dicts; ``merge_gateway_snapshots`` unions shards."""

    def __init__(self, policy: BackpressurePolicy):
        self.policy = policy
        self.tenants: Dict[str, Dict[str, int]] = {}
        self.peak_pending = 0       # max admitted-but-unfinished depth
        self.peak_waiting = 0       # max retry-room depth
        self.retry_horizon_t = 0.0  # latest scheduled retry instant
        self.dropped = 0            # chaos transport drops (recovered)
        self.duplicated = 0         # chaos transport duplicates
        self.deduped = 0            # deliveries suppressed by the id set
        self.redelivered = 0        # WAL-recovery delivery attempts

    def row(self, tenant: str) -> Dict[str, int]:
        r = self.tenants.get(tenant)
        if r is None:
            r = self.tenants[tenant] = {k: 0 for k in _COUNTERS + _GAUGES}
        return r

    def bump(self, tenant: str, key: str, n: int = 1):
        self.row(tenant)[key] += n

    def snapshot(self, wal: Optional[SubmissionWAL] = None) -> dict:
        p = self.policy
        totals = {k: 0 for k in _COUNTERS + _GAUGES}
        tenants = {}
        for tenant in sorted(self.tenants):
            r = dict(self.tenants[tenant])
            tenants[tenant] = r
            for k in totals:
                totals[k] += r[k]
        snap = {
            "policy": {"max_pending": p.max_pending,
                       "per_tenant_cap": p.per_tenant_cap,
                       "shed": p.shed,
                       "retry_after_s": p.retry_after_s,
                       "max_client_retries": p.max_client_retries},
            "tenants": tenants,
            "totals": totals,
            "peak_pending": self.peak_pending,
            "peak_waiting": self.peak_waiting,
            "retry_horizon_t": round(self.retry_horizon_t, 9),
            "faults": {"dropped": self.dropped,
                       "duplicated": self.duplicated,
                       "deduped": self.deduped,
                       "redelivered": self.redelivered},
        }
        if wal is not None:
            snap["wal"] = {"records": wal.count, "replayed": wal.replayed,
                           "chain": wal.chain}
        return snap


def merge_gateway_snapshots(snaps) -> dict:
    """Exact cross-shard merge (the PR-6 partial discipline): counters
    and gauges sum (tenants are shard-disjoint, so key-union), per-shard
    peaks and the retry horizon take the max, WAL record counts sum
    (the per-shard chain heads are per-log and are not merged)."""
    snaps = [s for s in snaps if s]
    if not snaps:
        return {}
    out = {"policy": dict(snaps[0]["policy"]), "tenants": {},
           "totals": {k: 0 for k in _COUNTERS + _GAUGES},
           "peak_pending": 0, "peak_waiting": 0, "retry_horizon_t": 0.0,
           "faults": {k: 0 for k in _FAULTS}}
    any_wal = any("wal" in s for s in snaps)
    if any_wal:
        out["wal"] = {"records": 0, "replayed": 0}
    for s in snaps:
        for tenant, r in s["tenants"].items():
            mine = out["tenants"].setdefault(
                tenant, {k: 0 for k in _COUNTERS + _GAUGES})
            for k, v in r.items():
                mine[k] = mine.get(k, 0) + v
        for k, v in s["totals"].items():
            out["totals"][k] = out["totals"].get(k, 0) + v
        out["peak_pending"] = max(out["peak_pending"], s["peak_pending"])
        out["peak_waiting"] = max(out["peak_waiting"], s["peak_waiting"])
        out["retry_horizon_t"] = max(out["retry_horizon_t"],
                                     s["retry_horizon_t"])
        for k, v in s["faults"].items():
            out["faults"][k] = out["faults"].get(k, 0) + v
        if "wal" in s:
            out["wal"]["records"] += s["wal"]["records"]
            out["wal"]["replayed"] += s["wal"]["replayed"]
    out["tenants"] = {t: out["tenants"][t] for t in sorted(out["tenants"])}
    return out


class _Sub:
    """One logged submission riding through the gate."""

    __slots__ = ("id", "wf", "tenant", "attempts", "delivered", "shed")

    def __init__(self, sub_id: int, wf, tenant: str):
        self.id = sub_id
        self.wf = wf
        self.tenant = tenant
        self.attempts = 0
        self.delivered = False
        self.shed = False


class DurableGateway:
    """The durable front door: sits between ``WorkflowGateway`` (its
    ``send_to``) and ``engine.submit``, logging every submission to the
    WAL and enforcing the backpressure policy at submit time.

    Wiring (see ``ControlPlane``): ``WorkflowGateway(send_to=gate.offer)``
    and ``engine.on_workflow_done = gate.workflow_done``; ``gate.inner``
    points back at the stream gateway so completions and sheds keep the
    closed-loop streams flowing and the drain accounting exact.

    When no submission is ever rejected the gate adds zero sim events
    and performs zero RNG draws — bit-identical to running without it.
    """

    def __init__(self, sim, deliver: Callable, policy: BackpressurePolicy,
                 seed: int = 0, shard: int = 0,
                 wal_path: Optional[str] = None,
                 chaos=None, arbiter=None, metrics=None):
        self.sim = sim
        self.deliver_to = deliver
        self.policy = policy
        self.shard = shard
        self.rng = random.Random(gate_stream_seed(seed, shard))
        self.wal = SubmissionWAL(path=wal_path)
        self.chaos = chaos
        self.arbiter = arbiter
        self.metrics = metrics
        self.inner = None                       # owning WorkflowGateway
        self.stats = GatewayStats(policy)
        self.events: List[tuple] = []           # (t, id, tenant, kind)
        self._by_ns: Dict[str, _Sub] = {}
        self._waiting: Dict[int, _Sub] = {}     # insertion order = age
        self._delivered_ids = set()
        self._in_flight = 0
        self._tenant_running: Dict[str, int] = {}

    # -- introspection ----------------------------------------------------
    def pending(self) -> int:
        """Admitted-but-unfinished depth (the enforced bound)."""
        return self._in_flight

    def waiting(self) -> int:
        """Submissions parked in the retry room."""
        return len(self._waiting)

    def snapshot(self) -> dict:
        return self.stats.snapshot(wal=self.wal)

    def trace_events(self) -> List[dict]:
        """Gateway decisions for ``arrival_trace/v2`` capture."""
        return [{"t": t, "id": sub_id, "tenant": tenant, "event": kind}
                for t, sub_id, tenant, kind in self.events]

    # -- submission path ---------------------------------------------------
    def offer(self, wf):
        """One submission arriving at the gate (the stream gateway's
        ``send_to``): log it, then admit / reject under the policy."""
        tenant = wf.tenant
        rec = self.wal.append(
            tenant, self.sim.now(),
            workflow_digest(tenant, wf.name, wf.instance))
        sub = _Sub(rec["id"], wf, tenant)
        self._by_ns[wf.namespace()] = sub
        self.stats.bump(tenant, "submissions")
        self._try_admit(sub)

    def _has_room(self, tenant: str) -> bool:
        p = self.policy
        if self._in_flight >= p.max_pending:
            return False
        if p.per_tenant_cap and \
                self._tenant_running.get(tenant, 0) >= p.per_tenant_cap:
            return False
        return True

    def _try_admit(self, sub: _Sub):
        if self._has_room(sub.tenant):
            self._admit(sub)
        else:
            self._reject(sub)

    def _admit(self, sub: _Sub):
        self._in_flight += 1
        self._tenant_running[sub.tenant] = \
            self._tenant_running.get(sub.tenant, 0) + 1
        self.stats.bump(sub.tenant, "admitted")
        self.stats.bump(sub.tenant, "running")
        if self._in_flight > self.stats.peak_pending:
            self.stats.peak_pending = self._in_flight
        self._transport(sub)

    def _transport(self, sub: _Sub):
        """The gate -> engine hop, where the chaos plane may drop or
        duplicate the submission; the WAL makes both harmless."""
        fault = (self.chaos.gateway_fault_draw()
                 if self.chaos is not None else None)
        if fault == "drop":
            # the record is already durable: recover by redelivery
            self.stats.dropped += 1
            self.sim.after(self.policy.retry_after_s, self._redeliver,
                           args=(sub,), note="gate:redeliver")
            return
        self._deliver(sub)
        if fault == "dup":
            self.stats.duplicated += 1
            self._deliver(sub)      # second transport copy: deduped below

    def _deliver(self, sub: _Sub):
        if sub.id in self._delivered_ids:
            self.stats.deduped += 1     # exactly-once: id already landed
            return
        self._delivered_ids.add(sub.id)
        sub.delivered = True
        self.deliver_to(sub.wf)

    def _redeliver(self, sub: _Sub):
        self.stats.redelivered += 1
        self._transport(sub)

    def _reject(self, sub: _Sub):
        t = self.sim.now()
        self.stats.bump(sub.tenant, "rejected")
        self._note("reject", sub.tenant)
        self.events.append((t, sub.id, sub.tenant, "reject"))
        if sub.attempts >= self.policy.max_client_retries:
            self._shed(sub)
            return
        sub.attempts += 1
        # deterministic retry-after: base * [0.5, 1.5) jitter from the
        # dedicated gate stream (the only draws this module makes)
        delay = self.policy.retry_after_s * (0.5 + self.rng.random())
        due = t + delay
        if due > self.stats.retry_horizon_t:
            self.stats.retry_horizon_t = due
        self._waiting[sub.id] = sub
        self.stats.bump(sub.tenant, "queued")
        self.sim.after(delay, self._retry, args=(sub,), note="gate:retry")
        self._enforce_waiting_cap()
        # measure AFTER eviction: the gauge reports the enforced bound,
        # not the one-element transient while the victim is picked
        if len(self._waiting) > self.stats.peak_waiting:
            self.stats.peak_waiting = len(self._waiting)

    def _enforce_waiting_cap(self):
        if self.policy.shed == "reject-newest":
            return                  # client-side retries: no server room
        while len(self._waiting) > self.policy.max_pending:
            self._shed(self._pick_victim())

    def _pick_victim(self) -> _Sub:
        if self.policy.shed == "fair-shed":
            by_tenant: Dict[str, int] = {}
            for sub in self._waiting.values():
                by_tenant[sub.tenant] = by_tenant.get(sub.tenant, 0) + 1
            hog = min(by_tenant, key=lambda t: (-by_tenant[t], t))
            for sub in self._waiting.values():     # oldest of the hog
                if sub.tenant == hog:
                    return sub
        return next(iter(self._waiting.values()))  # shed-oldest: global

    def _retry(self, sub: _Sub):
        if sub.shed or sub.id not in self._waiting:
            return                  # shed while parked: timer is a no-op
        del self._waiting[sub.id]
        self.stats.bump(sub.tenant, "queued", -1)
        self.stats.bump(sub.tenant, "retried")
        self._note("retry", sub.tenant)
        self.events.append((self.sim.now(), sub.id, sub.tenant, "retry"))
        self._try_admit(sub)

    def _shed(self, sub: _Sub):
        sub.shed = True
        if self._waiting.pop(sub.id, None) is not None:
            self.stats.bump(sub.tenant, "queued", -1)
        self.stats.bump(sub.tenant, "shed")
        self._note("shed", sub.tenant)
        self.events.append((self.sim.now(), sub.id, sub.tenant, "shed"))
        self._by_ns.pop(sub.wf.namespace(), None)
        if self.inner is not None:
            # release the owning stream (closed-loop flow + drain
            # accounting) one event later: eviction chains on deep
            # closed-loop queues must not recurse through the gate
            self.sim.after(0.0, self.inner.workflow_done, args=(sub.wf,),
                           note="gate:shed-release")

    def _note(self, kind: str, tenant: str):
        if self.arbiter is not None:
            self.arbiter.note_gateway(kind)
        if self.metrics is not None:
            self.metrics.note_gateway(kind, tenant)

    # -- completion routing -----------------------------------------------
    def workflow_done(self, wf):
        sub = self._by_ns.pop(wf.namespace(), None)
        if sub is not None:
            self._in_flight -= 1
            self._tenant_running[sub.tenant] -= 1
            self.stats.bump(sub.tenant, "running", -1)
            self.stats.bump(sub.tenant, "done")
        if self.inner is not None:
            self.inner.workflow_done(wf)

    def close(self):
        self.wal.close()
