"""Discrete-event simulation core.

One single-threaded event queue drives the whole control plane
(cluster, informers, engines, pollers). Payloads can be:

  * virtual  — a declared duration advances the clock (paper-scale
               numbers reproduce instantly; used by benchmarks),
  * real     — the callable executes NOW (e.g. a jitted JAX step) and
               its measured wall-time becomes the virtual duration
               (used by the ML workflow examples and tests).

This "virtual time, real work" design is what lets a 1-core container
model a 6-node cluster faithfully: concurrency exists in virtual time,
while real payloads still run and produce real arrays.

Scale notes: each scheduled event is a ``__slots__`` record, not a
closure-capturing tuple; hot callers pass ``args=`` instead of
allocating a lambda per event. ``events_processed`` counts executed
events so benchmarks can report events/sec, and the ``note`` string is
kept on the record — a ``max_events`` overflow names the next pending
notes so runaway polling loops identify their culprit.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Optional, Tuple


class Event:
    """One scheduled callback: ``fn(*args)`` at a point in virtual time."""

    __slots__ = ("fn", "args", "note", "daemon")

    def __init__(self, fn: Callable, args: Tuple, note: str, daemon: bool):
        self.fn = fn
        self.args = args
        self.note = note
        self.daemon = daemon


class Sim:
    def __init__(self):
        self.t = 0.0
        self._q = []
        self._seq = itertools.count()
        self._live = 0      # non-daemon events outstanding
        self.events_processed = 0

    def at(self, t: float, fn: Callable, note: str = "",
           daemon: bool = False, args: Tuple = ()):
        if not daemon:
            self._live += 1
        # heap tuple layout unchanged: (time, tie-break seq, record)
        heapq.heappush(self._q, (t if t > self.t else self.t,
                                 next(self._seq),
                                 Event(fn, args, note, daemon)))

    def after(self, dt: float, fn: Callable, note: str = "",
              daemon: bool = False, args: Tuple = ()):
        self.at(self.t + (dt if dt > 0.0 else 0.0), fn, note,
                daemon=daemon, args=args)

    def now(self) -> float:
        return self.t

    def run(self, until: Optional[float] = None, max_events: int = 10_000_000):
        """Process events until only daemon events remain (informer
        resyncs, metric samplers) or the horizon is reached."""
        n = 0
        q = self._q
        while q and self._live > 0:
            t, _, ev = q[0]
            if until is not None and t > until:
                self.t = until
                self.events_processed += n
                return
            heapq.heappop(q)
            self.t = t
            if not ev.daemon:
                self._live -= 1
            ev.fn(*ev.args)
            n += 1
            if n >= max_events:
                self.events_processed += n
                notes = [e.note for _, _, e in heapq.nsmallest(8, q) if e.note]
                raise RuntimeError(
                    f"sim exceeded {max_events} events — likely a polling "
                    f"loop never terminated; next pending notes: "
                    f"{notes if notes else '(unnamed events)'}")
        self.events_processed += n

    def idle(self) -> bool:
        return self._live == 0


def measure_wall(fn: Callable[[], None]) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
