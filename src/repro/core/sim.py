"""Discrete-event simulation core.

One single-threaded event queue drives the whole control plane
(cluster, informers, engines, pollers). Payloads can be:

  * virtual  — a declared duration advances the clock (paper-scale
               numbers reproduce instantly; used by benchmarks),
  * real     — the callable executes NOW (e.g. a jitted JAX step) and
               its measured wall-time becomes the virtual duration
               (used by the ML workflow examples and tests).

This "virtual time, real work" design is what lets a 1-core container
model a 6-node cluster faithfully: concurrency exists in virtual time,
while real payloads still run and produce real arrays.

Scale notes: each scheduled event is a ``__slots__`` record, not a
closure-capturing tuple; hot callers pass ``args=`` instead of
allocating a lambda per event. ``events_processed`` counts executed
events so benchmarks can report events/sec, and the ``note`` string is
kept on the record — a ``max_events`` overflow names the next pending
notes so runaway polling loops identify their culprit.

Queue backends (ISSUE 3): the sim's event-time distribution is bimodal
— large same-instant batches stitched together by small constant
control-plane latencies (0.02–1.2 s), plus long pod durations (10 s+)
and far-future daemons.  A binary heap pays O(log n) tuple comparisons
per push/pop against the WHOLE outstanding set (tens of thousands of
pending finish events at the 10k-workflow tier).  The default backend
is therefore a two-level *calendar queue*: a ring of fixed-width
near-future buckets (each a tiny heap) plus one far-future overflow
heap that migrates into the ring as the window advances.  Pop order is
exactly ``(t, seq)`` — identical to the heap backend, FIFO tie-break
included — which ``tests/test_event_core.py`` pins with a property
test.  ``REPRO_SIM_QUEUE=heap`` (or ``Sim(queue="heap")``) restores
the single-heap backend for reproduction runs.

``run(until=...)`` leaves the clock at ``until`` even when the queue
drains early, so a horizon is a horizon regardless of load; the time
of the last *processed* event stays available as ``last_event_t``
(benchmarks report it as the makespan).
"""
from __future__ import annotations

import heapq
import itertools
import os
import time
from typing import Callable, List, Optional, Tuple


class Event:
    """One scheduled callback: ``fn(*args)`` at a point in virtual time."""

    __slots__ = ("fn", "args", "note", "daemon")

    def __init__(self, fn: Callable, args: Tuple, note: str, daemon: bool):
        self.fn = fn
        self.args = args
        self.note = note
        self.daemon = daemon


class HeapQueue:
    """The classic backend: one binary heap of ``(t, seq, Event)``."""

    name = "heap"
    __slots__ = ("_q",)

    def __init__(self):
        self._q: List[Tuple[float, int, Event]] = []

    def __len__(self) -> int:
        return len(self._q)

    def push(self, t: float, seq: int, ev: Event):
        heapq.heappush(self._q, (t, seq, ev))

    def pop_due(self, until: Optional[float]):
        """Remove and return the earliest ``(t, seq, Event)``, or None
        when the queue is empty or the head lies beyond ``until`` (the
        head is left in place so a later ``run`` can resume)."""
        q = self._q
        if not q:
            return None
        if until is not None and q[0][0] > until:
            return None
        return heapq.heappop(q)

    def head_notes(self, n: int) -> List[str]:
        return [e.note for _, _, e in heapq.nsmallest(n, self._q) if e.note]


class CalendarQueue:
    """Two-level calendar queue with exact ``(t, seq)`` pop order.

    Near future: a power-of-two ring of fixed-width buckets, each a
    small heap — pushes into the dense "now + control-plane latency"
    region cost O(log bucket) against a handful of events instead of
    O(log n) against the whole queue.  Far future (``t`` beyond the
    ring window): one overflow heap, migrated bucket-ward as the
    current-bucket cursor advances, so every event is re-heaped at
    most once.  Because buckets partition time and migration always
    runs before the cursor can pass an overflow event's bucket, the
    head of the cursor bucket is the global ``(t, seq)`` minimum.
    """

    name = "calendar"
    __slots__ = ("_width", "_inv", "_nb", "_mask", "_buckets", "_cur",
                 "_far", "_near_len")

    def __init__(self, width: float = 0.25, n_buckets: int = 256):
        assert n_buckets & (n_buckets - 1) == 0, "n_buckets must be 2**k"
        self._width = width
        self._inv = 1.0 / width
        self._nb = n_buckets
        self._mask = n_buckets - 1
        self._buckets: List[List[Tuple[float, int, Event]]] = \
            [[] for _ in range(n_buckets)]
        self._cur = 0                    # absolute index of cursor bucket
        self._far: List[Tuple[float, int, Event]] = []
        self._near_len = 0

    def __len__(self) -> int:
        return self._near_len + len(self._far)

    def push(self, t: float, seq: int, ev: Event):
        # int(t * inv) is monotone in t, so an event never lands in a
        # bucket the cursor has already passed (callers push t >= now,
        # and the cursor only advances through empty buckets)
        abi = int(t * self._inv)
        if abi >= self._cur + self._nb:
            heapq.heappush(self._far, (t, seq, ev))
        else:
            heapq.heappush(self._buckets[abi & self._mask], (t, seq, ev))
            self._near_len += 1

    def _advance(self):
        """Move the cursor to the bucket holding the global minimum and
        return that bucket (None when the queue is empty).  Overflow
        events whose bucket enters the window are migrated before the
        cursor can step past them."""
        far = self._far
        if not self._near_len:
            if not far:
                return None
            self._cur = int(far[0][0] * self._inv)   # rebase onto far-min
        buckets, mask, nb, width = self._buckets, self._mask, self._nb, self._width
        while True:
            if far:
                end_t = (self._cur + nb) * width
                if far[0][0] < end_t:
                    inv = self._inv
                    near_gain = 0
                    while far and far[0][0] < end_t:
                        item = heapq.heappop(far)
                        heapq.heappush(buckets[int(item[0] * inv) & mask], item)
                        near_gain += 1
                    self._near_len += near_gain
            b = buckets[self._cur & mask]
            if b:
                return b
            self._cur += 1

    def pop_due(self, until: Optional[float]):
        # locate the global minimum READ-ONLY first: cursor movement and
        # far->near migration are committed only when an event actually
        # pops.  A declined pop (horizon) must leave the queue untouched,
        # otherwise a later push below the peeked time would land behind
        # the cursor and come out late (and out of order).
        far = self._far
        if self._near_len:
            buckets, mask = self._buckets, self._mask
            cur = self._cur
            while True:
                b = buckets[cur & mask]
                if b:
                    break
                cur += 1
            item = b[0]
            if far and far[0] < item:
                item, b = far[0], None     # true min still in the far heap
        elif far:
            item, b = far[0], None
        else:
            return None
        if until is not None and item[0] > until:
            return None
        if b is None:
            # rebase/migrate; _advance lands on the far item's bucket
            # (every bucket before it is empty by construction)
            b = self._advance()
        else:
            # committing is safe deferred-migration-wise: every far event
            # has t >= the popped min, hence bucket index >= cur
            self._cur = cur
        self._near_len -= 1
        return heapq.heappop(b)

    def head_notes(self, n: int) -> List[str]:
        items = [it for b in self._buckets for it in b]
        items.extend(self._far)
        return [e.note for _, _, e in heapq.nsmallest(n, items) if e.note]


QUEUE_BACKENDS = {"heap": HeapQueue, "calendar": CalendarQueue}


class Sim:
    def __init__(self, queue: Optional[str] = None):
        self.t = 0.0
        self.last_event_t = 0.0          # time of last processed event
        self.run_wall_s = 0.0            # real seconds inside run() loops
        self.run_cpu_s = 0.0             # process CPU seconds inside run()
        if queue is None:
            queue = os.environ.get("REPRO_SIM_QUEUE", "calendar")
        if queue not in QUEUE_BACKENDS:
            raise ValueError(f"unknown sim queue {queue!r}; "
                             f"expected one of {sorted(QUEUE_BACKENDS)}")
        self.queue_name = queue
        self._q = QUEUE_BACKENDS[queue]()
        self._seq = itertools.count()
        self._live = 0      # non-daemon events outstanding
        self.events_processed = 0

    def at(self, t: float, fn: Callable, note: str = "",
           daemon: bool = False, args: Tuple = ()):
        if not daemon:
            self._live += 1
        # record layout unchanged: (time, tie-break seq, record)
        self._q.push(t if t > self.t else self.t,
                     next(self._seq),
                     Event(fn, args, note, daemon))

    def after(self, dt: float, fn: Callable, note: str = "",
              daemon: bool = False, args: Tuple = ()):
        self.at(self.t + (dt if dt > 0.0 else 0.0), fn, note,
                daemon=daemon, args=args)

    def now(self) -> float:
        return self.t

    def run(self, until: Optional[float] = None, max_events: int = 10_000_000):
        """Process events until only daemon events remain (informer
        resyncs, metric samplers) or the horizon is reached.  On exit
        the clock stands at ``until`` (when given) even if the queue
        drained first; ``last_event_t`` keeps the drain time."""
        n = 0
        wall0 = time.perf_counter()
        cpu0 = time.process_time()
        pop = self._q.pop_due
        while self._live > 0:
            item = pop(until)
            if item is None:
                break
            t, _, ev = item
            self.t = self.last_event_t = t
            if not ev.daemon:
                self._live -= 1
            ev.fn(*ev.args)
            n += 1
            if n >= max_events:
                self.events_processed += n
                notes = self._q.head_notes(8)
                raise RuntimeError(
                    f"sim exceeded {max_events} events — likely a polling "
                    f"loop never terminated; next pending notes: "
                    f"{notes if notes else '(unnamed events)'}")
        self.events_processed += n
        # wall time of event processing only — ends with the last
        # processed event (the clock's last_event_t), so throughput
        # figures exclude setup before the loop and any epilogue after
        # it (benchmarks divide events by this, see bench_scale)
        self.run_wall_s += time.perf_counter() - wall0
        self.run_cpu_s += time.process_time() - cpu0
        if until is not None and until > self.t:
            self.t = until

    def idle(self) -> bool:
        return self._live == 0


def measure_wall(fn: Callable[[], None]) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
