"""Discrete-event simulation core.

One single-threaded event queue drives the whole control plane
(cluster, informers, engines, pollers). Payloads can be:

  * virtual  — a declared duration advances the clock (paper-scale
               numbers reproduce instantly; used by benchmarks),
  * real     — the callable executes NOW (e.g. a jitted JAX step) and
               its measured wall-time becomes the virtual duration
               (used by the ML workflow examples and tests).

This "virtual time, real work" design is what lets a 1-core container
model a 6-node cluster faithfully: concurrency exists in virtual time,
while real payloads still run and produce real arrays.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Optional


class Sim:
    def __init__(self):
        self.t = 0.0
        self._q = []
        self._seq = itertools.count()
        self._live = 0      # non-daemon events outstanding

    def at(self, t: float, fn: Callable[[], None], note: str = "",
           daemon: bool = False):
        if not daemon:
            self._live += 1
        heapq.heappush(self._q, (max(t, self.t), next(self._seq), fn, daemon))

    def after(self, dt: float, fn: Callable[[], None], note: str = "",
              daemon: bool = False):
        self.at(self.t + max(dt, 0.0), fn, note, daemon=daemon)

    def now(self) -> float:
        return self.t

    def run(self, until: Optional[float] = None, max_events: int = 10_000_000):
        """Process events until only daemon events remain (informer
        resyncs, metric samplers) or the horizon is reached."""
        n = 0
        while self._q and self._live > 0:
            t, _, fn, daemon = self._q[0]
            if until is not None and t > until:
                self.t = until
                return
            heapq.heappop(self._q)
            self.t = t
            if not daemon:
                self._live -= 1
            fn()
            n += 1
            if n >= max_events:
                raise RuntimeError(f"sim exceeded {max_events} events — "
                                   "likely a polling loop never terminated")

    def idle(self) -> bool:
        return self._live == 0


def measure_wall(fn: Callable[[], None]) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
