"""Exact bulk replica of ``random.Random.shuffle`` for hot loops.

The disordered level-2 scheduler (cluster.py) must keep the paper's
"disorderly, scattered" semantics bit-for-bit: the same seeded RNG and
the same draw sequence, so a fixed seed reproduces the same pod->node
binding sequence before and after any optimization. That rules out
fewer draws — but not cheaper ones.

CPython's ``shuffle`` burns one Python-level ``_randbelow`` call per
element: ``k = n.bit_length(); r = getrandbits(k); while r >= n:
r = getrandbits(k)``, and each ``getrandbits(k<=32)`` consumes exactly
one Mersenne-Twister word (``genrand_uint32() >> (32 - k)``).
``ExactShuffler`` consumes the identical word stream, but fetches it in
bulk: one ``getrandbits(32 * N)`` C call yields N words in genrand
order (the bignum's little-end word is the first draw), so the
Fisher-Yates rejection sampling can be replayed against a flat buffer.

Two backends replay the stream:

* native — a ~30-line C helper (compiled once with the system cc into
  ``_native/``, loaded via ctypes) drains draws and applies the swaps
  to an int32 permutation array in one call;
* python — a tight loop over the unpacked words (used when no compiler
  is available, or under ``REPRO_SHUFFLE_NO_NATIVE=1``).

Both produce identical permutations and identical word consumption —
pinned against ``random.shuffle`` by tests/test_scale_core.py.

The wrapped ``random.Random`` must have no other consumers while a
shuffler is attached (words are buffered ahead); the cluster's
scheduling RNG satisfies this — it is consumed exclusively by the
scheduler's shuffles.
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import random
import struct
import subprocess
import tempfile
from pathlib import Path
from typing import List, Optional, Sequence

_WORDS_PER_FETCH = 4096
_UNPACK = struct.Struct(f"<{_WORDS_PER_FETCH}I").unpack

# _SHIFT[n] = 32 - n.bit_length(): getrandbits(k) == word >> _SHIFT[n]
_SHIFT: List[int] = [32, 31]


def _ensure_shift(n: int) -> None:
    while len(_SHIFT) <= n:
        _SHIFT.append(32 - len(_SHIFT).bit_length())


# ---------------------------------------------------------------------------
# native backend: Fisher-Yates draw+apply over the word buffer
# ---------------------------------------------------------------------------
_C_SRC = r"""
#include <stdint.h>

/* Replay random.shuffle's draw stream for a list of `length`, applying
 * the swaps to `perm`. Resumes at element `start` (0-based, element j
 * swaps index length-1-j); returns the next unfinished element (==
 * length-1 when done) and writes the word cursor back to *pos_out.
 * Stops early when the word buffer runs dry so the caller can refill. */
long ka_draw_apply(const uint32_t *words, long n_words, long pos,
                   long length, long start, int32_t *perm, long *pos_out)
{
    long top = length - 1;
    long j = start;
    for (; j < top; j++) {
        uint32_t n = (uint32_t)(length - j);
        int shift = __builtin_clz(n);           /* 32 - bit_length(n) */
        uint32_t r;
        for (;;) {
            if (pos >= n_words) { *pos_out = pos; return j; }
            r = words[pos++] >> shift;
            if (r < n) break;
        }
        int32_t i = (int32_t)(length - 1 - j);
        int32_t tmp = perm[i];
        perm[i] = perm[r];
        perm[r] = tmp;
    }
    *pos_out = pos;
    return j;
}

/* One disordered-scheduler cycle body: for each pending pod, reshuffle
 * the node permutation (identical draw stream to random.shuffle) and
 * first-fit scan it against the free-capacity arrays, recording the
 * chosen node index (or -1) in bind_out and charging the copy of the
 * free arrays so later pods in the cycle see earlier binds.
 * state[0] = next pod, state[1] = next shuffle element of that pod
 * (resume point when the word buffer runs dry). Returns 1 when the
 * cycle completed, 0 when the caller must refill and call again. */
long ka_schedule_cycle(const uint32_t *words, long n_words, long pos,
                       long n_nodes, int32_t *perm,
                       int32_t *free_cpu, int32_t *free_mem,
                       const uint8_t *ready,
                       long n_pods, const int32_t *pod_cpu,
                       const int32_t *pod_mem,
                       int32_t *bind_out, long *state, long *pos_out)
{
    long j = state[0];
    long elem = state[1];
    long top = n_nodes - 1;
    for (; j < n_pods; j++, elem = 0) {
        for (; elem < top; elem++) {
            uint32_t n = (uint32_t)(n_nodes - elem);
            int shift = __builtin_clz(n);
            uint32_t r;
            for (;;) {
                if (pos >= n_words) {
                    state[0] = j; state[1] = elem; *pos_out = pos;
                    return 0;
                }
                r = words[pos++] >> shift;
                if (r < n) break;
            }
            int32_t i = (int32_t)(n_nodes - 1 - elem);
            int32_t tmp = perm[i];
            perm[i] = perm[r];
            perm[r] = tmp;
        }
        int32_t cpu = pod_cpu[j], mem = pod_mem[j];
        int32_t chosen = -1;
        for (long s = 0; s < n_nodes; s++) {
            int32_t idx = perm[s];
            if (ready[idx] && free_cpu[idx] >= cpu && free_mem[idx] >= mem) {
                free_cpu[idx] -= cpu;
                free_mem[idx] -= mem;
                chosen = idx;
                break;
            }
        }
        bind_out[j] = chosen;
    }
    state[0] = j; state[1] = 0; *pos_out = pos;
    return 1;
}
"""

_NATIVE_DIR = Path(__file__).resolve().parent / "_native"
_native_lib = None
_native_tried = False


def _load_native():
    global _native_lib, _native_tried
    if _native_tried:
        return _native_lib
    _native_tried = True
    if os.environ.get("REPRO_SHUFFLE_NO_NATIVE"):
        return None
    try:
        tag = hashlib.sha256(_C_SRC.encode()).hexdigest()[:16]
        so_path = _NATIVE_DIR / f"ka_shuffle_{tag}.so"
        if not so_path.exists():
            _NATIVE_DIR.mkdir(parents=True, exist_ok=True)
            with tempfile.NamedTemporaryFile(
                    "w", suffix=".c", dir=str(_NATIVE_DIR),
                    delete=False) as f:
                f.write(_C_SRC)
                c_path = f.name
            try:
                subprocess.run(
                    ["cc", "-O2", "-shared", "-fPIC", "-o",
                     str(so_path) + ".tmp", c_path],
                    check=True, capture_output=True, timeout=60)
                os.replace(str(so_path) + ".tmp", so_path)
            finally:
                os.unlink(c_path)
        lib = ctypes.CDLL(str(so_path))
        draw = lib.ka_draw_apply
        draw.restype = ctypes.c_long
        draw.argtypes = [ctypes.c_char_p, ctypes.c_long, ctypes.c_long,
                         ctypes.c_long, ctypes.c_long,
                         ctypes.POINTER(ctypes.c_int32),
                         ctypes.POINTER(ctypes.c_long)]
        cycle = lib.ka_schedule_cycle
        cycle.restype = ctypes.c_long
        cycle.argtypes = [ctypes.c_char_p, ctypes.c_long, ctypes.c_long,
                          ctypes.c_long, ctypes.POINTER(ctypes.c_int32),
                          ctypes.POINTER(ctypes.c_int32),
                          ctypes.POINTER(ctypes.c_int32),
                          ctypes.c_char_p, ctypes.c_long,
                          ctypes.POINTER(ctypes.c_int32),
                          ctypes.POINTER(ctypes.c_int32),
                          ctypes.POINTER(ctypes.c_int32),
                          ctypes.POINTER(ctypes.c_long),
                          ctypes.POINTER(ctypes.c_long)]
        _native_lib = (draw, cycle)
    except Exception:
        _native_lib = None
    return _native_lib


class ExactShuffler:
    """Drop-in ``shuffle`` with bit-identical draws from a bulk buffer."""

    __slots__ = ("rng", "_raw", "_words", "_pos", "_native", "_native_cycle",
                 "_posbox", "_posref", "_identity", "_perm_pool")

    def __init__(self, rng: random.Random, native: Optional[bool] = None):
        self.rng = rng
        self._raw = b""
        self._words: Optional[Sequence[int]] = ()
        self._pos = _WORDS_PER_FETCH       # empty: first use refills
        fns = _load_native() if native is not False else None
        if native is True and fns is None:
            raise RuntimeError("native shuffle backend unavailable")
        self._native, self._native_cycle = fns if fns else (None, None)
        self._posbox = ctypes.c_long(0)
        self._posref = ctypes.byref(self._posbox)
        self._identity: dict = {}          # length -> identity perm bytes
        self._perm_pool: dict = {}         # length -> reusable perm buffer

    @property
    def backend(self) -> str:
        return "native" if self._native is not None else "python"

    def _refill(self):
        raw = self.rng.getrandbits(32 * _WORDS_PER_FETCH)
        self._raw = raw.to_bytes(4 * _WORDS_PER_FETCH, "little")
        self._words = None                 # unpacked lazily (python path)
        self._pos = 0

    def _word_tuple(self) -> Sequence[int]:
        if self._words is None:
            self._words = _UNPACK(self._raw)
        return self._words or ()

    # ---- permutation API (both backends) ----------------------------------
    def make_perm(self, n: int):
        """An identity permutation draw_apply can mutate: int32 ctypes
        array (native) or plain list (python)."""
        if self._native is not None:
            arr = (ctypes.c_int32 * n)(*range(n))
            return arr
        return list(range(n))

    def reset_perm(self, perm, n: int):
        if self._native is not None:
            ident = self._identity.get(n)
            if ident is None:
                ident = self._identity[n] = struct.pack(f"<{n}i", *range(n))
            ctypes.memmove(perm, ident, 4 * n)
        else:
            perm[:] = range(n)

    def draw_apply(self, perm, n: int) -> None:
        """Consume exactly the words ``rng.shuffle`` would for a list of
        ``n`` and apply the identical Fisher-Yates swaps to ``perm``."""
        if n < 2:
            return
        if self._native is not None:
            done = 0
            top = n - 1
            while True:
                if self._pos >= _WORDS_PER_FETCH:
                    self._refill()
                done = self._native(self._raw, _WORDS_PER_FETCH, self._pos,
                                    n, done, perm, self._posref)
                self._pos = self._posbox.value
                if done >= top:
                    return
                self._refill()
        else:
            apply_swaps(perm, self.draw_swaps(n))

    def schedule_cycle(self, perm, n_nodes: int, free_cpu, free_mem, ready,
                       n_pods: int, pod_cpu, pod_mem, bind_out,
                       state) -> None:
        """Native scatter cycle: per pod, reshuffle ``perm`` (identical
        draw stream) and first-fit scan against the free arrays,
        charging them in place; ``bind_out[j]`` gets the node index or
        -1. Callers must check :attr:`has_native_cycle`."""
        state[0] = 0
        state[1] = 0
        while True:
            if self._pos >= _WORDS_PER_FETCH:
                self._refill()
            done = self._native_cycle(
                self._raw, _WORDS_PER_FETCH, self._pos, n_nodes, perm,
                free_cpu, free_mem, ready, n_pods, pod_cpu, pod_mem,
                bind_out, state, self._posref)
            self._pos = self._posbox.value
            if done:
                return
            self._refill()

    @property
    def has_native_cycle(self) -> bool:
        return self._native_cycle is not None

    # ---- python draw path --------------------------------------------------
    def draw_swaps(self, length: int) -> List[int]:
        """Consume exactly the words ``shuffle`` would for a list of
        ``length``, returning the Fisher-Yates targets ``[r_{L-1} ..
        r_1]`` without applying them."""
        if length < 2:
            return []
        if length >= len(_SHIFT):
            _ensure_shift(length)
        shift_tab = _SHIFT
        words = self._word_tuple()
        pos = self._pos
        end = len(words)
        out = []
        append = out.append
        for i in range(length - 1, 0, -1):
            n = i + 1
            shift = shift_tab[n]
            while True:
                if pos >= end:
                    self._refill()
                    words = self._word_tuple()
                    pos = 0
                    end = len(words)
                r = words[pos] >> shift
                pos += 1
                if r < n:
                    break
            append(r)
        self._pos = pos
        return out

    def shuffle(self, x: list) -> None:
        """Identical permutation to ``self.rng.shuffle(x)`` (same seed,
        same consumed word stream), minus the per-draw call overhead."""
        n = len(x)
        if n < 2:
            return
        if self._native is not None:
            perm = self._perm_pool.get(n)
            if perm is None:
                perm = self._perm_pool[n] = self.make_perm(n)
            else:
                self.reset_perm(perm, n)
            self.draw_apply(perm, n)
            x[:] = [x[i] for i in perm]
        else:
            apply_swaps(x, self.draw_swaps(n))


def apply_swaps(x, swaps: Sequence[int]) -> None:
    """Apply Fisher-Yates targets from :meth:`ExactShuffler.draw_swaps`
    (equivalent to the shuffle those draws encode)."""
    i = len(x) - 1
    for r in swaps:
        x[i], x[r] = x[r], x[i]
        i -= 1
