"""Exact bulk replica of ``random.Random.shuffle`` for hot loops.

The disordered level-2 scheduler (cluster.py) must keep the paper's
"disorderly, scattered" semantics bit-for-bit: the same seeded RNG and
the same draw sequence, so a fixed seed reproduces the same pod->node
binding sequence before and after any optimization. That rules out
fewer draws — but not cheaper ones.

CPython's ``shuffle`` burns one Python-level ``_randbelow`` call per
element: ``k = n.bit_length(); r = getrandbits(k); while r >= n:
r = getrandbits(k)``, and each ``getrandbits(k<=32)`` consumes exactly
one Mersenne-Twister word (``genrand_uint32() >> (32 - k)``).
``ExactShuffler`` consumes the identical word stream, replayed against
a flat buffer of words in genrand order.

Two backends produce that stream:

* native — a small C helper (compiled once with the system cc into
  ``_native/``, loaded via ctypes) carrying its OWN MT19937 core,
  seeded from ``rng.getstate()`` at construction: the exact genrand
  word sequence the wrapped ``random.Random`` would have produced, but
  generated straight into a reusable uint32 buffer (no bignum
  assembly, no ``to_bytes`` copy).  On top of the word stream the
  helper fuses the whole disordered-scheduler cycle
  (``ka_schedule_cycle``): the pending-pod shuffle, the per-pod node
  reshuffle, the first-fit capacity scan and the in-place charging all
  run in one call — only the resulting binds come back to Python.
* python — ``rng.getrandbits(32 * N)`` bulk fetches unpacked into
  tuples (used when no compiler is available, or under
  ``REPRO_SHUFFLE_NO_NATIVE=1``); the pure-Python cycle in cluster.py
  is the semantic reference for the fused native cycle.

Both produce identical permutations and identical word consumption —
pinned against ``random.shuffle`` by tests/test_scale_core.py, and the
fused cycle is pinned transitively by every binding-sequence hash
(tests/test_scale_core.py, tests/test_policy_pipeline.py,
tests/test_informer_views.py), which run on the native path wherever a
compiler exists and on the fallback in CI's no-native job.

Chaos-plane interaction (ISSUE 7): node loss removes capacity from
the fused cycle without touching the word stream.  ``kill_node`` /
``drain_node`` zero the node's slot in the ``ready[]`` array the
native cycle consults (both in its cycle-start free-capacity maxima
and in the first-fit check), exactly as ``fail_node`` always has, and
``restore_node`` writes it back — so a cordoned node is simply never
bound to while every shuffle still consumes its full draw sequence.
That is what keeps a chaos-free run bit-identical to PR 6 and a fixed
chaos seed exactly replayable: chaos draws come from a separate
sha256-spawned stream (core/chaos.py) and the scheduler RNG's
consumption schedule never changes.

The elastic autoscaler (ISSUE 9) rides the exact same contract: the
FULL max roster is materialized at cluster build, so the native
arrays keep their fixed node indices for the whole run, and
``provision_node`` / ``deprovision_node`` only flip the node's
``ready[]`` slot (plus the free-capacity words) through the same
``restore_node`` / ``drain_node`` writes chaos uses.  A deprovisioned
node is never bound to, every shuffle still consumes its full draw
sequence, and the daemon itself draws nothing — an autoscaler-free
run is bit-identical to PR 8 and an autoscaled run is a pure
function of the seed on both backends.

Scored placement (ISSUE 8) follows the same word-stream discipline:
``placement="scored-spread"`` / ``"scored-pack"`` change ONLY which
node the fused cycle picks (an integer least-allocated score over the
per-node alloc arrays), never how many words any shuffle consumes —
so first-fit stays bit-identical to every pinned hash and a scored
run is the same pure function of the seed on both backends.

The wrapped ``random.Random`` must have no other consumers while a
shuffler is attached (the python backend buffers words ahead; the
native backend forks the generator state at construction and never
consumes the Python object again).  The cluster's scheduling RNG
satisfies this — it is consumed exclusively by the scheduler's
shuffles.
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import random
import struct
import subprocess
import tempfile
from pathlib import Path
from typing import List, Optional, Sequence

_WORDS_PER_FETCH = 4096
_UNPACK = struct.Struct(f"<{_WORDS_PER_FETCH}I").unpack

# _SHIFT[n] = 32 - n.bit_length(): getrandbits(k) == word >> _SHIFT[n]
_SHIFT: List[int] = [32, 31]


def _ensure_shift(n: int) -> None:
    while len(_SHIFT) <= n:
        _SHIFT.append(32 - len(_SHIFT).bit_length())


# ---------------------------------------------------------------------------
# native backend: MT19937 word stream + fused Fisher-Yates/scatter cycle
# ---------------------------------------------------------------------------
_C_SRC = r"""
#include <stdint.h>

/* MT19937 core, bit-identical to CPython's _randommodule.c genrand
 * stream.  `state` is the 625-word layout of random.Random.getstate():
 * state[0..623] = mt[], state[624] = mti (624 means "twist before the
 * next draw"). */
#define MT_N 624
#define MT_M 397
#define MATRIX_A   0x9908b0dfU
#define UPPER_MASK 0x80000000U
#define LOWER_MASK 0x7fffffffU

static uint32_t mt_next(uint32_t *state)
{
    uint32_t *mt = state;
    uint32_t mti = state[MT_N];
    uint32_t y;
    if (mti >= MT_N) {
        int kk;
        for (kk = 0; kk < MT_N - MT_M; kk++) {
            y = (mt[kk] & UPPER_MASK) | (mt[kk + 1] & LOWER_MASK);
            mt[kk] = mt[kk + MT_M] ^ (y >> 1) ^ ((y & 1U) ? MATRIX_A : 0U);
        }
        for (; kk < MT_N - 1; kk++) {
            y = (mt[kk] & UPPER_MASK) | (mt[kk + 1] & LOWER_MASK);
            mt[kk] = mt[kk + (MT_M - MT_N)] ^ (y >> 1)
                     ^ ((y & 1U) ? MATRIX_A : 0U);
        }
        y = (mt[MT_N - 1] & UPPER_MASK) | (mt[0] & LOWER_MASK);
        mt[MT_N - 1] = mt[MT_M - 1] ^ (y >> 1) ^ ((y & 1U) ? MATRIX_A : 0U);
        mti = 0;
    }
    y = mt[mti++];
    state[MT_N] = mti;
    y ^= (y >> 11);
    y ^= (y << 7) & 0x9d2c5680U;
    y ^= (y << 15) & 0xefc60000U;
    y ^= (y >> 18);
    return y;
}

/* Refill the shared word buffer from the generator state.  The Python
 * side and the fused cycle below both consume through this buffer, so
 * the global word order is a single stream regardless of which entry
 * point drains it. */
void ka_mt_fill(uint32_t *state, uint32_t *words, long n)
{
    for (long i = 0; i < n; i++)
        words[i] = mt_next(state);
}

static inline uint32_t next_word(uint32_t *state, uint32_t *words,
                                 long n_words, long *pos)
{
    if (*pos >= n_words) {
        ka_mt_fill(state, words, n_words);
        *pos = 0;
    }
    return words[(*pos)++];
}

/* Replay random.shuffle's draw stream for a list of `length`, applying
 * the swaps to `perm`.  Draws flow through the shared buffer; the word
 * cursor is read from and written back to *pos_io. */
void ka_draw_apply(uint32_t *state, uint32_t *words, long n_words,
                   long *pos_io, long length, int32_t *perm)
{
    long pos = *pos_io;
    long top = length - 1;
    for (long j = 0; j < top; j++) {
        uint32_t n = (uint32_t)(length - j);
        int shift = __builtin_clz(n);           /* 32 - bit_length(n) */
        uint32_t r;
        do {
            r = next_word(state, words, n_words, &pos) >> shift;
        } while (r >= n);
        int32_t i = (int32_t)(length - 1 - j);
        int32_t tmp = perm[i];
        perm[i] = perm[r];
        perm[r] = tmp;
    }
    *pos_io = pos;
}

/* Utilization score scale: integer fixed-point so the C kernel and
 * the pure-Python reference agree bit-for-bit (truncating division of
 * non-negative operands == Python //). */
#define KA_SCORE_SCALE (1 << 20)

/* One fused disordered-scheduler cycle, identical to the pure-Python
 * reference in cluster.py:
 *   1. shuffle `pod_perm` (identity-initialized here) with exactly the
 *      draws random.shuffle(pending) would consume;
 *   2. for each pod in that shuffled order: reshuffle the node `perm`
 *      (same continuous stream), then scan it against the
 *      free-capacity arrays — first-fit (score_mode 0) or
 *      least-allocated scored (1 = spread: maximize post-bind free
 *      fraction; 2 = pack: minimize it) — charging the chosen node in
 *      place so later pods of the cycle see earlier binds;
 *   3. record the chosen node index (or -1) in bind_out[j] for the
 *      j-th pod of the SHUFFLED order (its original index is
 *      pod_perm[j]).
 * The scored modes consume the IDENTICAL draw stream as first-fit:
 * only node selection changes, never word consumption.  Ties on the
 * integer score go to the earliest position in the shuffled `perm`
 * (strict comparison), keeping the choice a pure function of the
 * draws + capacities.  The cycle-start free maxima skip the scan
 * (never the draws) for pods that provably fit no node — the same
 * upper-bound argument the Python reference uses. */
void ka_schedule_cycle(uint32_t *state, uint32_t *words, long n_words,
                       long *pos_io, long n_nodes, int32_t *perm,
                       int32_t *free_cpu, int32_t *free_mem,
                       const uint8_t *ready,
                       const int32_t *alloc_cpu, const int32_t *alloc_mem,
                       int32_t score_mode,
                       long n_pods, int32_t *pod_perm,
                       const int32_t *pod_cpu, const int32_t *pod_mem,
                       int32_t *bind_out)
{
    long pos = *pos_io;
    for (long j = 0; j < n_pods; j++)
        pod_perm[j] = (int32_t)j;
    long ptop = n_pods - 1;
    for (long j = 0; j < ptop; j++) {
        uint32_t n = (uint32_t)(n_pods - j);
        int shift = __builtin_clz(n);
        uint32_t r;
        do {
            r = next_word(state, words, n_words, &pos) >> shift;
        } while (r >= n);
        int32_t i = (int32_t)(n_pods - 1 - j);
        int32_t tmp = pod_perm[i];
        pod_perm[i] = pod_perm[r];
        pod_perm[r] = tmp;
    }
    int32_t max_cpu = 0, max_mem = 0;     /* cycle-start upper bounds */
    for (long s = 0; s < n_nodes; s++) {
        if (!ready[s]) continue;
        if (free_cpu[s] > max_cpu) max_cpu = free_cpu[s];
        if (free_mem[s] > max_mem) max_mem = free_mem[s];
    }
    long ntop = n_nodes - 1;
    for (long j = 0; j < n_pods; j++) {
        for (long elem = 0; elem < ntop; elem++) {
            uint32_t n = (uint32_t)(n_nodes - elem);
            int shift = __builtin_clz(n);
            uint32_t r;
            do {
                r = next_word(state, words, n_words, &pos) >> shift;
            } while (r >= n);
            int32_t i = (int32_t)(n_nodes - 1 - elem);
            int32_t tmp = perm[i];
            perm[i] = perm[r];
            perm[r] = tmp;
        }
        long p = pod_perm[j];
        int32_t cpu = pod_cpu[p], mem = pod_mem[p];
        int32_t chosen = -1;
        if (cpu <= max_cpu && mem <= max_mem) {
            if (score_mode == 0) {
                for (long s = 0; s < n_nodes; s++) {
                    int32_t idx = perm[s];
                    if (ready[idx] && free_cpu[idx] >= cpu
                            && free_mem[idx] >= mem) {
                        chosen = idx;
                        break;
                    }
                }
            } else {
                int64_t best_score = 0;
                for (long s = 0; s < n_nodes; s++) {
                    int32_t idx = perm[s];
                    if (!ready[idx] || free_cpu[idx] < cpu
                            || free_mem[idx] < mem)
                        continue;
                    int64_t fc = (int64_t)(free_cpu[idx] - cpu);
                    int64_t fm = (int64_t)(free_mem[idx] - mem);
                    int64_t score = fc * KA_SCORE_SCALE / alloc_cpu[idx]
                                    + fm * KA_SCORE_SCALE / alloc_mem[idx];
                    if (chosen < 0
                            || (score_mode == 1 ? score > best_score
                                                : score < best_score)) {
                        chosen = idx;
                        best_score = score;
                    }
                }
            }
            if (chosen >= 0) {
                free_cpu[chosen] -= cpu;
                free_mem[chosen] -= mem;
            }
        }
        bind_out[j] = chosen;
    }
    *pos_io = pos;
}
"""

_NATIVE_DIR = Path(__file__).resolve().parent / "_native"
_native_lib = None
_native_tried = False

_U32P = ctypes.POINTER(ctypes.c_uint32)
_I32P = ctypes.POINTER(ctypes.c_int32)
_LONGP = ctypes.POINTER(ctypes.c_long)


def _load_native():
    global _native_lib, _native_tried
    if _native_tried:
        return _native_lib
    _native_tried = True
    if os.environ.get("REPRO_SHUFFLE_NO_NATIVE"):
        return None
    try:
        tag = hashlib.sha256(_C_SRC.encode()).hexdigest()[:16]
        so_path = _NATIVE_DIR / f"ka_shuffle_{tag}.so"
        if not so_path.exists():
            _NATIVE_DIR.mkdir(parents=True, exist_ok=True)
            with tempfile.NamedTemporaryFile(
                    "w", suffix=".c", dir=str(_NATIVE_DIR),
                    delete=False) as f:
                f.write(_C_SRC)
                c_path = f.name
            try:
                subprocess.run(
                    ["cc", "-O2", "-shared", "-fPIC", "-o",
                     str(so_path) + ".tmp", c_path],
                    check=True, capture_output=True, timeout=60)
                os.replace(str(so_path) + ".tmp", so_path)
            finally:
                os.unlink(c_path)
        lib = ctypes.CDLL(str(so_path))
        fill = lib.ka_mt_fill
        fill.restype = None
        fill.argtypes = [_U32P, _U32P, ctypes.c_long]
        draw = lib.ka_draw_apply
        draw.restype = None
        draw.argtypes = [_U32P, _U32P, ctypes.c_long, _LONGP,
                         ctypes.c_long, _I32P]
        cycle = lib.ka_schedule_cycle
        cycle.restype = None
        cycle.argtypes = [_U32P, _U32P, ctypes.c_long, _LONGP,
                          ctypes.c_long, _I32P, _I32P, _I32P,
                          ctypes.POINTER(ctypes.c_uint8),
                          _I32P, _I32P, ctypes.c_int32,
                          ctypes.c_long, _I32P, _I32P, _I32P, _I32P]
        _native_lib = (fill, draw, cycle)
    except Exception:
        _native_lib = None
    return _native_lib


class ExactShuffler:
    """Drop-in ``shuffle`` with bit-identical draws from a bulk buffer."""

    __slots__ = ("rng", "_raw", "_words", "_pos", "_fill", "_draw",
                 "_native_cycle", "_state", "_buf", "_posbox", "_posref",
                 "_identity", "_perm_pool")

    def __init__(self, rng: random.Random, native: Optional[bool] = None):
        self.rng = rng
        self._raw = b""
        self._words: Optional[Sequence[int]] = ()
        self._pos = _WORDS_PER_FETCH       # empty: first use refills
        fns = _load_native() if native is not False else None
        if native is True and fns is None:
            raise RuntimeError("native shuffle backend unavailable")
        self._fill, self._draw, self._native_cycle = fns if fns else \
            (None, None, None)
        if self._fill is not None:
            # fork the generator: the C core continues the exact word
            # stream from the wrapped rng's current state, and the
            # Python object is never consumed again (see module doc)
            key = rng.getstate()[1]        # 624 mt words + index
            self._state = (ctypes.c_uint32 * 625)(*key)
            self._buf = (ctypes.c_uint32 * _WORDS_PER_FETCH)()
        else:
            self._state = self._buf = None
        self._posbox = ctypes.c_long(_WORDS_PER_FETCH)
        self._posref = ctypes.byref(self._posbox)
        self._identity: dict = {}          # length -> identity perm bytes
        self._perm_pool: dict = {}         # length -> reusable perm buffer

    @property
    def backend(self) -> str:
        return "native" if self._fill is not None else "python"

    # ---- python word buffer ------------------------------------------------
    def _refill(self):
        raw = self.rng.getrandbits(32 * _WORDS_PER_FETCH)
        self._raw = raw.to_bytes(4 * _WORDS_PER_FETCH, "little")
        self._words = None                 # unpacked lazily
        self._pos = 0

    def _word_tuple(self) -> Sequence[int]:
        if self._words is None:
            self._words = _UNPACK(self._raw)
        return self._words or ()

    # ---- permutation API (both backends) ----------------------------------
    def make_perm(self, n: int):
        """An identity permutation draw_apply can mutate: int32 ctypes
        array (native) or plain list (python)."""
        if self._fill is not None:
            arr = (ctypes.c_int32 * n)(*range(n))
            return arr
        return list(range(n))

    def reset_perm(self, perm, n: int):
        if self._fill is not None:
            ident = self._identity.get(n)
            if ident is None:
                ident = self._identity[n] = struct.pack(f"<{n}i", *range(n))
            ctypes.memmove(perm, ident, 4 * n)
        else:
            perm[:] = range(n)

    def draw_apply(self, perm, n: int) -> None:
        """Consume exactly the words ``rng.shuffle`` would for a list of
        ``n`` and apply the identical Fisher-Yates swaps to ``perm``."""
        if n < 2:
            return
        if self._fill is not None:
            self._draw(self._state, self._buf, _WORDS_PER_FETCH,
                       self._posref, n, perm)
        else:
            apply_swaps(perm, self.draw_swaps(n))

    def schedule_cycle(self, perm, n_nodes: int, free_cpu, free_mem, ready,
                       alloc_cpu, alloc_mem, score_mode: int,
                       n_pods: int, pod_perm, pod_cpu, pod_mem,
                       bind_out) -> None:
        """Fused native scatter cycle: shuffle the pending order into
        ``pod_perm`` (identity-initialized C-side), then per pod
        reshuffle ``perm`` and scan the free arrays — first-fit
        (``score_mode=0``) or utilization-scored least-allocated
        (``1`` spread / ``2`` pack, over the per-node ``alloc_*``
        capacities) — charging them in place; ``bind_out[j]`` gets the
        node index (or -1) for the pod originally at index
        ``pod_perm[j]``.  Identical draw stream to ``shuffle(pending)``
        + per-pod ``draw_apply`` in every mode, and identical binds to
        the matching Python scan in cluster.py.  Callers must check
        :attr:`has_native_cycle`."""
        self._native_cycle(self._state, self._buf, _WORDS_PER_FETCH,
                           self._posref, n_nodes, perm, free_cpu, free_mem,
                           ready, alloc_cpu, alloc_mem, score_mode,
                           n_pods, pod_perm, pod_cpu, pod_mem,
                           bind_out)

    @property
    def has_native_cycle(self) -> bool:
        return self._native_cycle is not None

    # ---- python draw path --------------------------------------------------
    def draw_swaps(self, length: int) -> List[int]:
        """Consume exactly the words ``shuffle`` would for a list of
        ``length``, returning the Fisher-Yates targets ``[r_{L-1} ..
        r_1]`` without applying them.  Python backend only — the native
        backend's word stream lives in the C state."""
        if length < 2:
            return []
        if length >= len(_SHIFT):
            _ensure_shift(length)
        shift_tab = _SHIFT
        words = self._word_tuple()
        pos = self._pos
        end = len(words)
        out = []
        append = out.append
        for i in range(length - 1, 0, -1):
            n = i + 1
            shift = shift_tab[n]
            while True:
                if pos >= end:
                    self._refill()
                    words = self._word_tuple()
                    pos = 0
                    end = len(words)
                r = words[pos] >> shift
                pos += 1
                if r < n:
                    break
            append(r)
        self._pos = pos
        return out

    def shuffle(self, x: list) -> None:
        """Identical permutation to ``self.rng.shuffle(x)`` (same seed,
        same consumed word stream), minus the per-draw call overhead."""
        n = len(x)
        if n < 2:
            return
        if self._fill is not None:
            perm = self._perm_pool.get(n)
            if perm is None:
                perm = self._perm_pool[n] = self.make_perm(n)
            else:
                self.reset_perm(perm, n)
            self.draw_apply(perm, n)
            x[:] = [x[i] for i in perm]
        else:
            apply_swaps(x, self.draw_swaps(n))


def apply_swaps(x, swaps: Sequence[int]) -> None:
    """Apply Fisher-Yates targets from :meth:`ExactShuffler.draw_swaps`
    (equivalent to the shuffle those draws encode)."""
    i = len(x) - 1
    for r in swaps:
        x[i], x[r] = x[r], x[i]
        i -= 1
