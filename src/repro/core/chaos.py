"""Deterministic chaos plane (ISSUE 7): seeded fault injection.

Three fault families, all drawn from ONE dedicated RNG stream so a
fixed chaos seed replays bit-for-bit and ``chaos=None`` performs zero
draws (the scheduler RNG is untouched — every PR-6 pinned binding hash
holds):

  * node crashes / spot-reclaim drains — seeded exponential
    inter-arrival timers pick a ready node and call
    ``Cluster.kill_node`` / ``Cluster.drain_node``; resident pods fail
    with ``evicted=True`` + ``node_lost=True`` and ride the PR-4
    requeue machinery back through admission with no retry-budget
    charge.  An optional seeded downtime restores the node later
    (``restore_node``), re-adding its capacity to the native scheduler
    arrays and the informer aggregates.
  * transient apiserver faults — each ``create_pod``/``delete_pod``
    call flips a seeded coin and may return a retryable
    ``"Unavailable"`` error; the engine absorbs it with capped
    exponential backoff + jitter (generalizing the AlreadyExists
    delete+retry path, see engine.py).
  * task crashes — a started pod may be killed mid-run at a seeded
    point of its duration; unlike node loss this IS a failure and
    charges the §4.5 retry budget (the deterministic driver for the
    ``on_retry_exhausted`` paths).

Determinism argument: every draw happens inside the single-threaded
sim event loop, in event order.  Timer chains draw their next
inter-arrival when they fire; per-call fault coins and per-start crash
plans draw exactly when the triggering call executes.  Two runs with
the same workload, seed and schedule therefore consume the identical
draw sequence — pinned by tests/test_chaos_plane.py.  The stream is
spawned via sha256 (``chaos_stream_seed``), decorrelated from the
scheduler seed and from the sha256-spawned shard seeds, and
``ChaosSchedule.spawn(shard)`` derives per-shard schedules the same
way the sharded plane spawns per-shard scheduler seeds.

``ChaosSchedule`` is a frozen, picklable value object (it crosses the
fork boundary inside ``ShardSpec``); ``ChaosInjector`` is the live
per-plane driver holding the RNG, the timers and the recovery
counters.

Heterogeneous node classes (ISSUE 8) need no special casing here:
victims are picked uniformly over the READY names in the canonical
node order, so big and small nodes are equally likely targets, and
``kill_node``/``drain_node``/``restore_node`` write each node's OWN
``cpu_alloc``/``mem_alloc`` back into the native free/ready mirrors —
killing a 16-core node removes 16 cores, restoring it returns 16
(pinned by tests/test_placement.py's hetero drain/restore
regression).  The descheduler (core/descheduler.py) composes the same
way: it draws nothing, so chaos replay identity is unaffected.

The autoscaler (core/autoscaler.py, ISSUE 9) also draws nothing, but
it shrinks the provisioned roster: victims are picked only among
PROVISIONED ready nodes (never the last one), and a chaos rejoin of a
node the autoscaler deprovisioned while it was down is a no-op — only
``provision_node`` brings reclaimed capacity back.  With no autoscaler
every node stays provisioned, so the candidate lists and the draw
stream are bit-identical to the PR-7/PR-8 pins.
"""
from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass, replace
from typing import Optional, Tuple


def chaos_stream_seed(seed: int) -> int:
    """Decorrelate the chaos stream from every other consumer of the
    run seed (scheduler RNG, arrival RNGs, shard seeds) — same
    sha256-spawn scheme as ``shard.shard_seed`` under its own tag."""
    h = hashlib.sha256(f"repro-chaos/{seed}".encode()).digest()
    return int.from_bytes(h[:8], "big")


def chaos_shard_seed(seed: int, shard: int) -> int:
    h = hashlib.sha256(f"repro-chaos-shard/{seed}/{shard}".encode()).digest()
    return int.from_bytes(h[:8], "big")


@dataclass(frozen=True)
class ChaosSchedule:
    """Declarative, picklable fault plan.  All rates default to off:
    ``ChaosSchedule()`` injects nothing (but still arms the stream, so
    use ``chaos=None`` for the guaranteed-untouched baseline)."""

    seed: int = 0
    # seeded node-event streams: mean exponential inter-arrival seconds
    # (0 = stream off); kills model crashes, drains model spot reclaims
    node_kill_interval_s: float = 0.0
    node_drain_interval_s: float = 0.0
    node_downtime_s: float = 0.0     # restore after this long (0 = stays down)
    max_node_events: int = 0         # cap kills+drains (0 = unbounded)
    start_after_s: float = 0.0       # grace period before the first draw
    # explicit scripted events: (t, "kill"|"drain"|"restore", node_name)
    events: Tuple[Tuple[float, str, str], ...] = ()
    # per-apiserver-call probability of a retryable "Unavailable" error
    api_fault_rate: float = 0.0
    # per-pod-start probability of a mid-run crash (charges retries)
    task_crash_rate: float = 0.0
    # submission-transport faults at the durable gateway (ISSUE 10):
    # per-admitted-submission probability the gate->engine hop drops
    # the submission (recovered by WAL redelivery) or duplicates it
    # (suppressed by exactly-once dedup); no-ops without a gateway
    gateway_drop_rate: float = 0.0
    gateway_dup_rate: float = 0.0

    def spawn(self, shard: int) -> "ChaosSchedule":
        """The schedule for one shard of a sharded plane: same plan,
        decorrelated per-shard seed (mirrors ``shard.shard_seed``)."""
        return replace(self, seed=chaos_shard_seed(self.seed, shard))

    @property
    def active(self) -> bool:
        return bool(self.node_kill_interval_s > 0.0
                    or self.node_drain_interval_s > 0.0
                    or self.events
                    or self.api_fault_rate > 0.0
                    or self.task_crash_rate > 0.0
                    or self.gateway_drop_rate > 0.0
                    or self.gateway_dup_rate > 0.0)


class ChaosInjector:
    """Live fault driver for one control-plane stack.

    Attaches itself as ``cluster.chaos`` — the cluster consults it at
    every apiserver call (fault coin) and pod start (crash plan), and
    the engine uses :meth:`backoff_jitter` for its retry delays.  All
    timer events are daemons: an un-restored schedule must never keep
    the sim alive past the workload.
    """

    def __init__(self, sim, cluster, schedule: ChaosSchedule):
        self.sim = sim
        self.cluster = cluster
        self.schedule = schedule
        self.rng = random.Random(chaos_stream_seed(schedule.seed))
        # recovery accounting (exported via counters(), merged by shard)
        self.node_kills = 0
        self.node_drains = 0
        self.node_restores = 0
        self.pods_lost = 0
        self.api_faults = 0
        self.task_crashes = 0
        self.gateway_drops = 0
        self.gateway_dups = 0
        self.node_downtime_s = 0.0       # accumulated on restore
        self._node_events = 0
        self._down_since: dict = {}      # node -> kill/drain instant
        cluster.chaos = self
        self._arm()

    # -- timers -----------------------------------------------------------
    def _arm(self):
        s = self.schedule
        for t, action, node in s.events:
            self.sim.at(t, self._scripted, daemon=True,
                        note=f"chaos:{action}", args=(action, node))
        if s.node_kill_interval_s > 0.0:
            self._arm_stream("kill", s.node_kill_interval_s, first=True)
        if s.node_drain_interval_s > 0.0:
            self._arm_stream("drain", s.node_drain_interval_s, first=True)

    def _arm_stream(self, action: str, mean_s: float, first: bool = False):
        dt = self.rng.expovariate(1.0 / mean_s)
        if first:
            dt += self.schedule.start_after_s
        self.sim.after(dt, self._fire_stream, daemon=True,
                       note=f"chaos:{action}", args=(action, mean_s))

    def _fire_stream(self, action: str, mean_s: float):
        cap = self.schedule.max_node_events
        if cap and self._node_events >= cap:
            return                       # stream exhausted: stop rearming
        victim = self._pick_victim()
        if victim is not None:
            self._node_event(action, victim)
        self._arm_stream(action, mean_s)

    def _scripted(self, action: str, node: str):
        if action == "restore":
            self._restore(node)
            return
        if node in self.cluster.nodes and self.cluster.nodes[node].ready:
            self._node_event(action, node)

    def _pick_victim(self) -> Optional[str]:
        # canonical node order (the cluster's _node_seq) so the draw is
        # identical across queue backends and shuffle backends; only
        # PROVISIONED ready nodes are candidates — chaos must not kill
        # capacity the autoscaler has already reclaimed, and without an
        # autoscaler every node is provisioned so the candidate list
        # (and therefore the draw stream) is unchanged
        ready = [n.name for n in self.cluster._node_seq
                 if n.ready and n.provisioned]
        if len(ready) <= 1:
            return None        # never take the last provisioned node down
        return ready[self.rng.randrange(len(ready))]

    def _node_event(self, action: str, node: str):
        self._node_events += 1
        if action == "drain":
            lost = self.cluster.drain_node(node)
            self.node_drains += 1
        else:
            lost = self.cluster.kill_node(node)
            self.node_kills += 1
        self.pods_lost += lost
        self._down_since[node] = self.sim.now()
        if self.schedule.node_downtime_s > 0.0:
            self.sim.after(self.schedule.node_downtime_s, self._restore,
                           daemon=True, note="chaos:restore", args=(node,))

    def _restore(self, node: str):
        since = self._down_since.pop(node, None)
        if since is None or self.cluster.nodes[node].ready:
            return
        if not self.cluster.nodes[node].provisioned:
            # the autoscaler deprovisioned this node while it was down;
            # a chaos rejoin must not resurrect reclaimed capacity
            # (restore_node has the same guard — don't count a restore)
            return
        self.node_downtime_s += self.sim.now() - since
        self.node_restores += 1
        self.cluster.restore_node(node)

    # -- per-call draws (consulted by cluster.py / engine.py) -------------
    def api_fault_draw(self) -> bool:
        """One seeded coin per guarded apiserver call."""
        rate = self.schedule.api_fault_rate
        if rate <= 0.0:
            return False
        if self.rng.random() < rate:
            self.api_faults += 1
            return True
        return False

    def task_crash_draw(self, duration_s: float) -> Optional[float]:
        """Crash plan for one started pod: seconds until the mid-run
        kill (strictly < duration), or None to run clean."""
        rate = self.schedule.task_crash_rate
        if rate <= 0.0:
            return None
        if self.rng.random() >= rate:
            return None
        self.task_crashes += 1
        return self.rng.random() * duration_s

    def gateway_fault_draw(self) -> Optional[str]:
        """One seeded draw per gateway transport hop: ``"drop"`` loses
        the submission in flight (the WAL redelivers), ``"dup"``
        delivers it twice (the dedup set suppresses the copy), None
        passes clean.  Zero draws when both rates are 0 — a gateway-
        armed, fault-free run replays the PR-7 chaos stream exactly."""
        drop, dup = (self.schedule.gateway_drop_rate,
                     self.schedule.gateway_dup_rate)
        if drop <= 0.0 and dup <= 0.0:
            return None
        u = self.rng.random()
        if u < drop:
            self.gateway_drops += 1
            return "drop"
        if u < drop + dup:
            self.gateway_dups += 1
            return "dup"
        return None

    def backoff_jitter(self) -> float:
        """Uniform [0,1) jitter factor for the engine's retry backoff
        (seeded: replays bit-for-bit with the rest of the stream)."""
        return self.rng.random()

    # -- accounting -------------------------------------------------------
    def counters(self) -> dict:
        """Recovery accounting; plain ints/floats so per-shard dicts
        merge by summation (see shard.ShardedRunResult.chaos_counters)."""
        return {
            "node_kills": self.node_kills,
            "node_drains": self.node_drains,
            "node_restores": self.node_restores,
            "pods_lost": self.pods_lost,
            "api_faults": self.api_faults,
            "task_crashes": self.task_crashes,
            "gateway_drops": self.gateway_drops,
            "gateway_dups": self.gateway_dups,
            "node_downtime_s": round(self.node_downtime_s, 9),
        }
