"""Workflow DAG model + ConfigMap-JSON parser (paper Listing 1 format).

A workflow is a DAG of tasks; every task carries the six attributes of
the paper's task node (input, output, image, cpuNum, memNum, args) plus
an optional real payload callable. Data dependencies are realized
through the namespace's shared volume (core/volumes.py) exactly like
the paper's PVC/NFS mechanism.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import calibration as cal


@dataclass
class Task:
    id: str
    inputs: List[str] = field(default_factory=list)     # upstream task ids
    outputs: List[str] = field(default_factory=list)    # downstream task ids
    image: str = "shanchenggang/task-emulator:latest"
    cpu_m: int = cal.TASK_CPU_M
    mem_mi: int = cal.TASK_MEM_MI
    args: List[str] = field(default_factory=list)
    duration_s: float = cal.TASK_DURATION_S              # virtual payload
    payload: Optional[Callable[..., Any]] = None         # real payload
    virtual: bool = False                                # entry/exit marker

    def resource_request(self):
        if self.virtual:
            return 50, 50      # negligible pause-container request
        return self.cpu_m, self.mem_mi

    def run_time(self) -> float:
        return 0.0 if self.virtual else self.duration_s


@dataclass
class Workflow:
    name: str
    tasks: Dict[str, Task]
    instance: int = 0          # repeat index (namespace uniquifier)
    tenant: str = "default"    # owning tenant (multi-tenant control plane)

    def __post_init__(self):
        self.validate()

    # -- structure ------------------------------------------------------
    def validate(self):
        ids = set(self.tasks)
        for t in self.tasks.values():
            for dep in t.inputs:
                if dep not in ids:
                    raise ValueError(f"{self.name}: {t.id} depends on unknown {dep}")
            for out in t.outputs:
                if out not in ids:
                    raise ValueError(f"{self.name}: {t.id} outputs to unknown {out}")
        # consistency of edges + acyclicity via topo sort
        self.topo_order()

    def edges(self):
        for t in self.tasks.values():
            for dep in t.inputs:
                yield dep, t.id

    def topo_order(self) -> List[str]:
        """Kahn topological order — ready tasks in insertion order (the
        level-1 scheduling algorithm of the paper: top-down topological)."""
        indeg = {tid: len(t.inputs) for tid, t in self.tasks.items()}
        ready = [tid for tid, d in indeg.items() if d == 0]
        out: List[str] = []
        while ready:
            tid = ready.pop(0)
            out.append(tid)
            for nxt in self.tasks[tid].outputs:
                indeg[nxt] -= 1
                if indeg[nxt] == 0:
                    ready.append(nxt)
        if len(out) != len(self.tasks):
            raise ValueError(f"{self.name}: cycle detected")
        return out

    def levels(self) -> List[List[str]]:
        """Level-synchronous partition (what the Batch Job baseline runs)."""
        depth: Dict[str, int] = {}
        for tid in self.topo_order():
            t = self.tasks[tid]
            depth[tid] = 1 + max((depth[d] for d in t.inputs), default=-1)
        n = max(depth.values()) + 1
        lv: List[List[str]] = [[] for _ in range(n)]
        for tid, d in depth.items():
            lv[d].append(tid)
        return lv

    def critical_path_len(self) -> int:
        return len(self.levels())

    def namespace(self) -> str:
        ns = self.__dict__.get("_ns")
        if ns is None:           # cached: called once per pod event at scale
            if self.tenant != "default":
                ns = f"wf-{self.tenant}-{self.name}-{self.instance}"
            else:
                ns = f"wf-{self.name}-{self.instance}"
            self._ns = ns
        return ns

    def _derive(self, instance: int, tenant: str) -> "Workflow":
        # instances share the validated task dict — re-running
        # validate() (a topo sort) per instance made building a
        # 100k-workflow stream O(instances x tasks) for nothing
        new = object.__new__(Workflow)
        new.name = self.name
        new.tasks = self.tasks
        new.instance = instance
        new.tenant = tenant
        return new

    def with_instance(self, i: int) -> "Workflow":
        return self._derive(i, self.tenant)

    def with_tenant(self, tenant: str) -> "Workflow":
        return self._derive(self.instance, tenant)

    def total_requests(self):
        cpu = sum(t.resource_request()[0] for t in self.tasks.values())
        mem = sum(t.resource_request()[1] for t in self.tasks.values())
        return cpu, mem


# ---------------------------------------------------------------------------
# ConfigMap (Listing 1) parsing: {"0": {"input": [], "output": ["1"], ...}}
# ---------------------------------------------------------------------------
def parse_configmap(data: str | Dict) -> Dict[str, Task]:
    obj = json.loads(data) if isinstance(data, str) else data
    tasks: Dict[str, Task] = {}
    for tid, spec in obj.items():
        args = list(spec.get("args", []))
        dur = cal.TASK_DURATION_S
        if "-t" in args:  # stress -t seconds (+ equal mem phase, see §5.2)
            dur = 2.0 * float(args[args.index("-t") + 1])
        tasks[tid] = Task(
            id=tid,
            inputs=list(spec.get("input", [])),
            outputs=list(spec.get("output", [])),
            image=(spec.get("image") or [Task.image])[0],
            cpu_m=int((spec.get("cpuNum") or [cal.TASK_CPU_M])[0]),
            mem_mi=int((spec.get("memNum") or [cal.TASK_MEM_MI])[0]),
            args=args,
            duration_s=dur,
        )
    return tasks


def make_workflow(name: str, data: str | Dict) -> Workflow:
    return Workflow(name, parse_configmap(data))


def add_virtual_entry_exit(tasks: Dict[str, Task]) -> Dict[str, Task]:
    """Add the paper's virtual entry/exit nodes around a task dict."""
    roots = [tid for tid, t in tasks.items() if not t.inputs]
    leaves = [tid for tid, t in tasks.items() if not t.outputs]
    entry = Task(id="entry", outputs=list(roots), virtual=True, duration_s=0.0)
    exit_ = Task(id="exit", inputs=list(leaves), virtual=True, duration_s=0.0)
    out = {"entry": entry}
    for tid, t in tasks.items():
        t2 = replace(t, inputs=list(t.inputs), outputs=list(t.outputs))
        if tid in roots:
            t2.inputs = ["entry"] + t2.inputs
        if tid in leaves:
            t2.outputs = t2.outputs + ["exit"]
        out[tid] = t2
    out["exit"] = exit_
    return out
