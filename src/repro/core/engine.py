"""KubeAdaptor engine (§4.3–4.6): the docking framework itself.

Modules, mapped 1:1 to the paper's architecture diagram (Fig 3):
  * workflow input interface   — ``submit`` (fed by the injector via the
                                 in-process gRPC analogue)
  * workflow namespace creator — ``_create_namespace`` (+ PVC via
                                 VolumeManager/StorageClass)
  * task container creator     — ``_create_task_pods`` (concurrent
                                 creates for parallel offspring =
                                 the Goroutine mechanism)
  * resource gathering/alloc   — ResourceGatherer admission gate
  * state tracking & monitoring— InformerSet handlers feeding the
                                 EventRegistry (§4.6 sequence diagram)
  * workflow container destroy — ``_on_pod_succeeded`` -> delete; the
                                 deletion event triggers successors
  * fault tolerance (§4.5)     — Failed pods recreated (<= max_retries),
                                 AlreadyExists resolved by delete+retry;
                                 node loss surfaces as pod failures and
                                 takes the same path
  * straggler mitigation       — optional speculative twin when a pod
                                 overruns straggler_factor x expected
                                 (beyond-paper, for the 1000-node brief)

Multi-tenant control plane (beyond-paper). The engine is one stage of

    WorkflowGateway ──submit──▶ KubeAdaptorEngine ──request──▶ AdmissionArbiter
      (N streams,                 (per-workflow state,           (shared headroom,
       arrival processes)          event-trigger chain)           fifo/priority/
           ▲                                                      fair-share)
           └────────────── workflow-complete ◀────────────────────────┘

The arbiter is a single shared instance: every ``_submit_ready`` files
admission *requests* instead of self-servicing headroom, so concurrent
workflows from many tenants contend under a pluggable policy, and any
pod deletion (any tenant) re-evaluates the pending queue. Tenancy
knobs (per-tenant priority / fair-share weight) are registered on the
arbiter by the ControlPlane builder in core/runner.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.core import calibration as cal
from repro.core.cluster import (FAILED, PENDING, RUNNING, SUCCEEDED, Cluster,
                                PodObj)
from repro.core.dag import Task, Workflow
from repro.core.events import EventRegistry
from repro.core.informer import InformerSet
from repro.core.metrics import MetricsCollector
from repro.core.resources import AdmissionArbiter
from repro.core.schedulers import TopologicalScheduler
from repro.core.sim import Sim
from repro.core.volumes import VolumeManager


@dataclass
class WorkflowState:
    wf: Workflow
    scheduler: Optional[object] = None                  # level-1 order source
    rec: Optional[object] = None       # cached metrics WorkflowRecord
    create_cb: Optional[Callable] = None   # admission grant callback
    labels_cache: Dict[str, Dict[str, str]] = field(default_factory=dict)
    pvc: Optional[str] = None
    created: Set[str] = field(default_factory=set)      # tasks with live pods
    completed: Set[str] = field(default_factory=set)    # deps satisfied
    retries: Dict[str, int] = field(default_factory=dict)
    speculated: Set[str] = field(default_factory=set)
    done: bool = False
    # incremental readiness (exact mirror of the all-tasks scan): unmet
    # dependency counts, the ready-but-not-created pool, and each task's
    # definition-order index to reproduce the scan's output order
    unmet: Dict[str, int] = field(default_factory=dict)
    ready_pool: Set[str] = field(default_factory=set)
    order_idx: Dict[str, int] = field(default_factory=dict)
    # task -> instant its pod was lost to a node kill/drain; popped when
    # the replacement pod is created (time-to-reschedule metric).  Empty
    # except under chaos — the hot path tests the dict, nothing more.
    disrupted_at: Dict[str, float] = field(default_factory=dict)

    def __post_init__(self):
        for i, (tid, t) in enumerate(self.wf.tasks.items()):
            self.order_idx[tid] = i
            self.unmet[tid] = len(t.inputs)
            if not t.inputs:
                self.ready_pool.add(tid)

    def note_completed(self, tid: str):
        """First completion of ``tid``: unlock its successors."""
        self.completed.add(tid)
        for nxt in self.wf.tasks[tid].outputs:
            self.unmet[nxt] -= 1
            if self.unmet[nxt] == 0:
                self.ready_pool.add(nxt)

    @property
    def ns(self) -> str:
        return self.wf.namespace()


class KubeAdaptorEngine:
    name = "kubeadaptor"

    def __init__(self, sim: Sim, cluster: Cluster, informers: InformerSet,
                 events: EventRegistry, volumes: VolumeManager,
                 metrics: MetricsCollector,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS,
                 scheduler_cls=TopologicalScheduler,
                 speculative: bool = False,
                 arbiter: Optional[AdmissionArbiter] = None,
                 on_workflow_done: Optional[Callable] = None):
        self.sim = sim
        self.cluster = cluster
        self.inf = informers
        self.events = events
        self.volumes = volumes
        self.metrics = metrics
        self.p = params
        self.scheduler_cls = scheduler_cls
        self.speculative = speculative
        self.arbiter = arbiter if arbiter is not None else AdmissionArbiter(informers)
        self.on_workflow_done = on_workflow_done
        self._ws: Dict[str, WorkflowState] = {}
        self._started = False

    # ------------------------------------------------------------------ #
    # wiring (event-trigger mechanism, Fig 4)
    # ------------------------------------------------------------------ #
    def start(self):
        if self._started:
            return
        self._started = True
        self.inf.pods.add_handlers(on_update=self._pod_updated,
                                   on_delete=self._pod_deleted)
        self.inf.nodes.add_handlers(on_update=self._node_updated)
        self.events.register("pod-succeeded", self._on_pod_succeeded)
        self.events.register("pod-failed", self._on_pod_failed)
        self.events.register("pod-removed", self._on_pod_removed)

    def _mine(self, pod: PodObj) -> Optional[WorkflowState]:
        # namespace probe first: it alone rejects foreign pods, and the
        # label check only guards cross-engine namespace collisions
        ws = self._ws.get(pod.namespace)
        if ws is None or pod.labels.get("engine") != self.name:
            return None
        return ws

    def _pod_updated(self, pod: PodObj):
        ws = self._mine(pod)
        if ws is None:
            return
        if pod.phase == RUNNING:
            self.metrics.note_start_rec(ws.rec, pod.task_id)
            if self.speculative and not pod.labels.get("twin"):
                self._arm_straggler_check(ws, pod)
        elif pod.phase == SUCCEEDED:
            self.events.emit("pod-succeeded", pod)
        elif pod.phase == FAILED:
            self.events.emit("pod-failed", pod)

    def _pod_deleted(self, pod: PodObj):
        if pod.labels.get("engine") == self.name:
            self.arbiter.pod_removed(pod)
            self.events.emit("pod-removed", pod)

    def _node_updated(self, node):
        # a restored node (chaos plane) re-opens headroom, but no pod
        # event follows it — without this wake, losing every running
        # pod to a node kill leaves the arbiter's pending queue with no
        # pod-removal trigger and the run strands silently.  Normal
        # runs emit no node MODIFIED events, so this is chaos-only.
        if node.ready:
            self.arbiter.evaluate()

    # ------------------------------------------------------------------ #
    # workflow input interface
    # ------------------------------------------------------------------ #
    def submit(self, wf: Workflow):
        self.start()
        ws = WorkflowState(wf=wf, scheduler=self.scheduler_cls(wf))
        ws.create_cb = lambda task: self._admitted(ws, task)
        self._ws[ws.ns] = ws
        ws.rec = self.metrics.note_submitted(wf)
        self.cluster.create_namespace(ws.ns, cb=lambda _ns: self._ns_ready(ws))

    def _ns_ready(self, ws: WorkflowState):
        self.metrics.note_ns_created(ws.wf)
        ws.pvc = self.volumes.provision(ws.ns, cb=lambda _p: self._submit_ready(ws))

    # ------------------------------------------------------------------ #
    # task container creator + resource gate
    # ------------------------------------------------------------------ #
    def _ready_tasks(self, ws: WorkflowState) -> List[str]:
        # ready_pool ⊇ {deps satisfied, not created/completed}; filter +
        # definition-order sort reproduce the old all-tasks scan exactly
        out = [tid for tid in ws.ready_pool
               if tid not in ws.completed and tid not in ws.created]
        if len(out) > 1:
            out.sort(key=ws.order_idx.__getitem__)
        return ws.scheduler.order_ready(out)

    def _submit_ready(self, ws: WorkflowState):
        if ws.done:
            return
        ready = [ws.wf.tasks[t] for t in self._ready_tasks(ws)]
        self.arbiter.submit(ws.ns, ws.wf.tenant, ready, ws.create_cb)

    def _admitted(self, ws: WorkflowState, task: Task) -> bool:
        # a grant may arrive after the workflow moved on (late wake-up);
        # the False return tells the arbiter not to count the grant
        if ws.done or task.id in ws.created or task.id in ws.completed:
            return False
        self._create_pod(ws, task)
        return True

    def _create_pod(self, ws: WorkflowState, task: Task, twin: bool = False,
                    attempt: int = 0):
        name = task.id + ("-twin" if twin else "")
        if twin:
            labels = {"engine": self.name, "task": task.id,
                      "tenant": ws.wf.tenant, "twin": "1"}
            if task.virtual:
                labels["virtual"] = "1"
        else:
            # one immutable labels dict per (workflow, task), shared by
            # every incarnation (retries) — pod labels are never
            # mutated after creation
            labels = ws.labels_cache.get(task.id)
            if labels is None:
                labels = {"engine": self.name, "task": task.id,
                          "tenant": ws.wf.tenant}
                if task.virtual:
                    labels["virtual"] = "1"
                ws.labels_cache[task.id] = labels
        cpu, mem = task.resource_request()
        payload = None
        if task.payload is not None:
            vol = self.volumes.volume(ws.pvc)
            payload = (lambda t=task, v=vol: t.payload(v, t))
        pod = PodObj(name=name, namespace=ws.ns, task_id=task.id,
                     workflow=ws.wf.name, cpu_m=cpu, mem_mi=mem,
                     duration_s=task.run_time(), payload=payload,
                     volume=ws.pvc, labels=labels, tenant=ws.wf.tenant)
        ws.created.add(task.id)
        ws.ready_pool.discard(task.id)
        if ws.disrupted_at and not twin:
            # replacement for a pod lost to a node kill/drain: close
            # the disruption window (time-to-reschedule percentile)
            t0 = ws.disrupted_at.pop(task.id, None)
            if t0 is not None:
                self.metrics.note_rescheduled(self.sim.now() - t0)
        # charge headroom until the informer observes the pod — retried
        # pods and twins bypass admission but must not double-spend
        # (the ledger is idempotent per pod name, so transient-fault
        # retries of the same create re-use the original reservation)
        self.arbiter.reserve(ws.ns, name, ws.wf.tenant, cpu, mem)
        self.metrics.note_first_create_rec(ws.rec)
        self.cluster.create_pod(
            pod,
            error_cb=lambda reason, existing: self._on_create_error(
                ws, task, reason, existing, twin, attempt))

    def _fault_backoff(self, attempt: int) -> float:
        """Capped exponential backoff for retryable apiserver faults,
        with seeded jitter (chaos stream) to de-synchronize retry
        storms — the §4.5 AlreadyExists delete+retry generalized."""
        delay = min(self.p.api_fault_backoff_s * (2 ** attempt),
                    self.p.api_fault_backoff_max_s)
        chaos = self.cluster.chaos
        if chaos is not None:
            delay *= 0.5 + 0.5 * chaos.backoff_jitter()
        return delay

    def _retry_create(self, ws: WorkflowState, task: Task, twin: bool,
                      attempt: int):
        if ws.done:
            return               # workflow tore down while backing off
        self._create_pod(ws, task, twin=twin, attempt=attempt)

    def _delete_pod(self, ws: WorkflowState, name: str,
                    cb: Optional[Callable] = None, attempt: int = 0):
        """``delete_pod`` with the transient-fault retry policy: every
        engine-side deletion routes through here so a chaos-injected
        "Unavailable" is re-issued after ``_fault_backoff`` instead of
        silently dropping the deletion (which would strand the §4.6
        trigger chain waiting on the DELETED event)."""
        def on_error(_reason, _key):
            if ws.done:
                return           # namespace cascade owns cleanup now
            if attempt >= self.p.max_api_fault_retries:
                raise RuntimeError(
                    f"{ws.ns}/{name}: apiserver unavailable after "
                    f"{attempt} delete retries")
            self.sim.after(self._fault_backoff(attempt), self._delete_pod,
                           note="api-retry", args=(ws, name, cb, attempt + 1))
        self.cluster.delete_pod(ws.ns, name, cb=cb, error_cb=on_error)

    def _on_create_error(self, ws: WorkflowState, task: Task, reason: str,
                         existing: PodObj, twin: bool = False,
                         attempt: int = 0):
        # §4.5: duplicate pod -> destroy it, back off, request creation again
        if reason == "AlreadyExists":
            self._delete_pod(
                ws, existing.name,
                cb=lambda _p: self.sim.after(
                    self.p.create_retry_backoff,
                    lambda: self._create_pod(ws, task)))
        elif reason == "NamespaceNotFound" and not ws.done:
            self.cluster.create_namespace(
                ws.ns, cb=lambda _ns: self._create_pod(ws, task))
        elif reason == "Unavailable" and not ws.done:
            # transient apiserver fault (chaos plane): retryable —
            # capped exponential backoff + jitter, then raise (a real
            # outage must not masquerade as a hung run)
            if attempt >= self.p.max_api_fault_retries:
                raise RuntimeError(
                    f"{ws.ns}/{task.id}: apiserver unavailable after "
                    f"{attempt} create retries")
            self.sim.after(self._fault_backoff(attempt), self._retry_create,
                           note="api-retry",
                           args=(ws, task, twin, attempt + 1))

    # ------------------------------------------------------------------ #
    # event callbacks (the §4.6 trigger chain)
    # ------------------------------------------------------------------ #
    def _on_pod_succeeded(self, pod: PodObj):
        ws = self._mine(pod)
        if ws is None or ws.done:
            return
        task_id = pod.task_id
        if task_id not in ws.completed:
            self.metrics.note_finish_rec(ws.rec, task_id)
        # destruction module removes the finished pod (twin too)
        self._delete_pod(ws, pod.name)
        if task_id in ws.speculated:
            other = task_id + ("-twin" if pod.name == task_id else "")
            if other != pod.name:
                self._delete_pod(ws, other)

    def _on_pod_removed(self, pod: PodObj):
        ws = self._mine(pod)
        if ws is None or ws.done:
            return
        if pod.phase != SUCCEEDED:
            return                       # failed-pod removals handled elsewhere
        tid = pod.task_id
        first_completion = tid not in ws.completed
        if first_completion:
            ws.note_completed(tid)
            if len(ws.completed) == len(ws.wf.tasks):
                self._workflow_complete(ws)
            else:
                # trigger the subsequent task pods right now
                self._submit_ready(ws)

    def _on_pod_failed(self, pod: PodObj):
        ws = self._mine(pod)
        if ws is None or ws.done:
            return
        tid = pod.task_id
        if tid in ws.completed:          # twin already finished the task
            self._delete_pod(ws, pod.name)
            return
        if getattr(pod, "evicted", False):
            # preempted by the admission pipeline — or disrupted by a
            # node kill/drain (node_lost) or a descheduler offload
            # (rebalanced): not a failure — the task re-enters the
            # ready pool and re-queues through admission (it must not
            # steal back the freed headroom), with no retry-budget
            # charge
            if getattr(pod, "node_lost", False):
                ws.rec.node_lost += 1
                if not pod.name.endswith("-twin"):
                    ws.disrupted_at[tid] = self.sim.now()
            elif getattr(pod, "rebalanced", False):
                ws.rec.rebalanced += 1
            else:
                ws.rec.preempted += 1

            def requeue(_p):
                if ws.done:
                    return               # evicted in the same instant the
                #                          workflow tore down: the namespace
                #                          cascade owns cleanup — re-adding
                #                          the task to ready_pool here would
                #                          double-count it into a dead run
                if pod.name.endswith("-twin"):
                    return               # the RUNNING primary still owns the
                #                          task — touching created/ready here
                #                          would spawn a duplicate primary
                ws.created.discard(tid)
                if tid not in ws.completed and ws.unmet[tid] == 0:
                    ws.ready_pool.add(tid)
                self._submit_ready(ws)
            self._delete_pod(ws, pod.name, cb=requeue)
            return
        n = ws.retries.get(tid, 0) + 1
        ws.retries[tid] = n
        ws.rec.retries += 1
        task = ws.wf.tasks[tid]
        if n > self.p.max_retries:
            if self.p.on_retry_exhausted == "fail-workflow":
                # quarantine the blast radius to this workflow: the other
                # tenants' runs must not die with it (§4.5 at 10k scale)
                self._fail_workflow(
                    ws, f"{tid} exceeded {self.p.max_retries} retries")
                return
            raise RuntimeError(f"{ws.ns}/{tid} exceeded retries")
        # remove the failed pod, then request generation again (§4.5)
        def recreate(_p):
            if ws.done:
                return                   # failed while the workflow was
            #                              already being torn down — a new
            #                              pod would land in the dying
            #                              namespace and resurrect state
            #                              the cascade just removed
            ws.created.discard(tid)
            if tid not in ws.completed and ws.unmet[tid] == 0:
                ws.ready_pool.add(tid)   # retry: eligible again
            if pod.name.endswith("-twin"):
                return                   # only the primary is retried
            self._create_pod(ws, task)
        self._delete_pod(ws, pod.name, cb=recreate)

    # ------------------------------------------------------------------ #
    # straggler mitigation (speculative twin)
    # ------------------------------------------------------------------ #
    def _arm_straggler_check(self, ws: WorkflowState, pod: PodObj):
        expected = max(pod.duration_s, 0.1)
        wait = max(self.p.straggler_min_wait, self.p.straggler_factor * expected)

        def check():
            live = self.cluster.pods.get((pod.namespace, pod.name))
            if (live is not None and live.phase == RUNNING
                    and live.task_id not in ws.completed
                    and live.task_id not in ws.speculated):
                ws.speculated.add(pod.task_id)
                self._create_pod(ws, ws.wf.tasks[pod.task_id], twin=True)

        self.sim.after(wait, check)

    def _fail_workflow(self, ws: WorkflowState, reason: str):
        """Terminal failure of ONE workflow: record it, then the same
        teardown as success — the other tenants' runs must not die
        with it."""
        self.metrics.note_failed(ws.wf, reason)
        self._teardown(ws, "workflow-failed")

    # ------------------------------------------------------------------ #
    # completion
    # ------------------------------------------------------------------ #
    def _workflow_complete(self, ws: WorkflowState):
        self._teardown(ws, "workflow-complete")

    def _teardown(self, ws: WorkflowState, event: str):
        """Release admission state, destroy the namespace (cascading
        pods/PVCs), and hand the completion back to the gateway so
        closed-loop streams keep flowing."""
        ws.done = True
        self.arbiter.forget_namespace(ws.ns)

        def ns_gone(_ns):
            self.metrics.note_ns_deleted(ws.wf)
            self.volumes.release(ws.ns)
            self.events.emit(event, ws.wf)
            # drop the per-workflow state only now: ns deletion takes
            # ns_delete_latency (≫ informer latency), so every in-flight
            # pod-update delivery for this namespace has already landed —
            # deleting at teardown start would change _mine()/_pod_updated
            # behavior for those late events.  Keeps engine memory
            # O(in-flight), not O(total workflows) (1M-workflow tier).
            self._ws.pop(ws.ns, None)
            if self.on_workflow_done:
                self.on_workflow_done(ws.wf)

        self.cluster.delete_namespace(ws.ns, cb=ns_gone)
