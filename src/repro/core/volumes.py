"""Shared storage: StorageClass/PVC/PV analogue + per-namespace data store.

Task data dependencies (the DAG edges) flow through a ``SharedVolume``
— the stand-in for the NFS-backed PersistentVolume every task pod of a
workflow mounts. Real ML payloads put/get numpy arrays (activations,
checkpoint refs); the stress workload just writes completion markers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from repro.core import calibration as cal
from repro.core.cluster import Cluster
from repro.core.sim import Sim


class SharedVolume:
    """The PV: a namespace-scoped key-value store (NFS directory analogue)."""

    def __init__(self, name: str):
        self.name = name
        self._data: Dict[str, Any] = {}

    def put(self, key: str, value: Any):
        self._data[key] = value

    def get(self, key: str, default=None):
        return self._data.get(key, default)

    def keys(self):
        return list(self._data)

    def __contains__(self, key):
        return key in self._data


class VolumeManager:
    """StorageClass + NFS provisioner: dynamic PVC->PV per workflow ns."""

    def __init__(self, sim: Sim, cluster: Cluster,
                 params: cal.ClusterParams = cal.DEFAULT_PARAMS):
        self.sim = sim
        self.cluster = cluster
        self.p = params
        self.volumes: Dict[str, SharedVolume] = {}

    def provision(self, namespace: str, cb: Optional[Callable] = None) -> str:
        """Create the namespace PVC; PV binds via StorageClass dynamically."""
        pvc_name = f"{namespace}-pvc"

        def bound(pvc):
            self.volumes[pvc_name] = SharedVolume(pvc_name)
            if cb:
                cb(pvc_name)

        self.cluster.create_pvc(namespace, pvc_name, cb=bound)
        return pvc_name

    def volume(self, pvc_name: str) -> Optional[SharedVolume]:
        return self.volumes.get(pvc_name)

    def release(self, namespace: str):
        self.volumes.pop(f"{namespace}-pvc", None)
