"""Level-1 workflow scheduling algorithms.

The paper's algorithm is top-down topological order; KubeAdaptor's job
is to make the level-2 (cluster) execution follow whatever order the
level-1 algorithm emits. We ship the paper's algorithm plus a
longest-path-first variant to demonstrate the docking framework is
algorithm-agnostic (the engine consumes any ``order_ready``).

``SCHEDULERS`` is the registry the ControlPlane builder resolves its
``scheduler=`` knob against (core/runner.py); register new level-1
algorithms here to make them selectable by name in experiments and
benchmarks.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.core.dag import Workflow


class TopologicalScheduler:
    """Paper §5.2: schedule tasks topologically, top-down."""

    name = "topological"

    def __init__(self, wf: Workflow):
        self.rank = {tid: i for i, tid in enumerate(wf.topo_order())}

    def order_ready(self, ready: Sequence[str]) -> List[str]:
        return sorted(ready, key=lambda t: self.rank[t])


class LongestPathScheduler:
    """HEFT-flavoured: higher upward-rank (height to exit) first."""

    name = "longest-path"

    def __init__(self, wf: Workflow):
        height: Dict[str, int] = {}
        for tid in reversed(wf.topo_order()):
            t = wf.tasks[tid]
            height[tid] = 1 + max((height[o] for o in t.outputs), default=-1)
        self.height = height
        self.rank = {tid: i for i, tid in enumerate(wf.topo_order())}

    def order_ready(self, ready: Sequence[str]) -> List[str]:
        return sorted(ready, key=lambda t: (-self.height[t], self.rank[t]))


SCHEDULERS = {
    "topological": TopologicalScheduler,
    "longest-path": LongestPathScheduler,
}
