"""Task payloads: the paper's stress emulator + real ML payloads.

A payload is ``fn(volume, task) -> None``; it reads upstream outputs
from the namespace SharedVolume and writes its own (the PV-mediated
data dependency of §3.2). Virtual-clock benchmarks use stress_payload
(markers only); the ML workflow examples run real jitted JAX steps.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np


def stress_payload(volume, task):
    """task-emulator analogue: consume inputs, emit a completion marker."""
    if volume is None:
        return
    for dep in task.inputs:
        _ = volume.get(f"{dep}/out")        # data dependency read
    volume.put(f"{task.id}/out", {"task": task.id, "ok": True})


def matmul_payload(n: int = 256, iters: int = 4) -> Callable:
    """A real CPU-bound JAX payload (used in payload_mode='real')."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def body(x):
        def step(h, _):
            return jnp.tanh(h @ h) * 0.5 + h * 0.5, None
        out, _ = jax.lax.scan(step, x, None, length=iters)
        return out

    def run(volume, task):
        x = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)),
                        jnp.float32)
        y = body(x)
        y.block_until_ready()
        if volume is not None:
            for dep in task.inputs:
                _ = volume.get(f"{dep}/out")
            volume.put(f"{task.id}/out", np.asarray(y[0, :4]))

    return run


def fn_payload(fn: Callable[[], Optional[dict]]) -> Callable:
    """Wrap an arbitrary thunk (e.g. a jitted train step) as a payload."""

    def run(volume, task):
        result = fn()
        if volume is not None:
            for dep in task.inputs:
                _ = volume.get(f"{dep}/out")
            volume.put(f"{task.id}/out", result if result is not None else True)

    return run
