"""Elastic node autoscaler: pending-depth scale-up, idle-drain scale-down.

KubeAdaptor's headline win over Argo is resource usage rate; its
follow-up (Shan et al., "Adaptive Resource Allocation for Workflow
Containerization on Kubernetes") and xpk's node-auto-provisioning
push the same engine toward elastic clusters where capacity is paid
for only while the workload needs it.  This daemon is that loop for
the simulated cluster: declared **node pools** (one per
``calibration`` node class) scale up when admission pressure is
sustained and drain back down when the cluster goes idle, turning
resource usage rate into an optimizable axis — equal makespan and
SLO hit-rate at materially fewer node-seconds (``Cluster.cost_summary``).

Mechanics (all through existing primitives, no new scheduler paths):

* The FULL max roster is materialized up front by the cluster
  builder, so the native ``ka_schedule_cycle`` mirrors keep fixed
  node indices for the whole run.  Scale state is a per-node
  ``provisioned`` bit: scale-up flips a node back in via
  ``Cluster.provision_node`` (a ``restore_node``-style ready-array
  write + node MODIFIED fan-out + scheduler kick), scale-down
  cordons+drains through ``Cluster.deprovision_node`` (the PR-7
  ``drain_node`` reclaim path — residents requeue through admission
  with no retry-budget charge).
* Scale-up: when pending depth (admission queue + unbound pod queue)
  stays at or above ``pending_threshold`` for ``sustain_s``, each
  subsequent tick provisions ``scale_step`` more nodes (first
  deprovisioned member, pools in declared order) — monotone growth
  to the pool max, so a persistent backlog always reaches full
  capacity (liveness).
* Scale-down: ONLY when the pending depth is zero AND the unbound
  pod queue is empty (never strands a pending pod), nodes that have
  held zero resource-bound pods for ``idle_s`` are drained in
  reverse roster order, respecting each pool's ``min`` and never
  dropping the cluster's last provisioned node.

Determinism contract (same as the PR-8 descheduler): every decision
is a pure function of cluster state, nodes are visited in canonical
``_node_seq`` order, the timer is a ``Sim.after(daemon=True)`` event
(an armed autoscaler never keeps an otherwise-drained run alive),
and NO random draw is ever consumed — arming it does not move the
scheduler/chaos RNG word streams, so a fixed seed replays exactly
and every pinned binding hash is untouched when it is disabled.

Sharding: ``AutoscalePolicy`` is frozen/picklable and crosses the
fork like ``ShardSpec.deschedule``; ``spawn(index, workers)`` slices
explicit pool min/max across shards with the same base+remainder
split as the node partition, while derived pools (``pools=()``) pass
through and re-derive from each shard's own roster prefix.  The cost
integrals it shapes merge exactly across shards (areas and flips
add, peaks/lows take max/min).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import calibration
from repro.core.cluster import Cluster
from repro.core.sim import Sim


def _split(total: int, index: int, workers: int) -> int:
    """Base+remainder share of ``total`` for shard ``index`` — the
    same split ``shard.partition_nodes`` applies to the roster, so a
    pool's min/max slices line up with each shard's node prefix."""
    base, rem = divmod(total, workers)
    return base + (1 if index < rem else 0)


@dataclass(frozen=True)
class NodePool:
    """One elastic pool: the members of ``node_class`` may be scaled
    between ``min`` and ``max`` provisioned nodes.  ``max=None``
    means the whole class population; classes without a declared
    pool stay fully provisioned and unmanaged."""
    node_class: str
    min: int = 0
    max: Optional[int] = None


@dataclass(frozen=True)
class AutoscalePolicy:
    """Picklable autoscaler knobs (frozen: shareable across shards).

    With ``pools=()`` one pool per node class is derived from the
    roster: ``max`` = the class population, ``min`` =
    ``ceil(min_frac * population)`` (at least 1)."""
    pools: Tuple[NodePool, ...] = ()
    min_frac: float = 0.25             # derived-pool floor fraction
    interval_s: float = 15.0           # wake cadence
    pending_threshold: int = 1         # depth that counts as pressure
    sustain_s: float = 30.0            # pressure must persist this long
    scale_step: int = 1                # nodes provisioned per hot tick
    idle_s: float = 60.0               # zero-usage span before drain
    start_after_s: float = 0.0         # calm period before the first tick

    def spawn(self, index: int, workers: int) -> "AutoscalePolicy":
        """Per-shard slice: explicit pool min/max partition like the
        node roster; derived pools re-derive per shard."""
        if workers <= 1 or not self.pools:
            return self
        sliced = tuple(
            NodePool(p.node_class,
                     _split(p.min, index, workers),
                     None if p.max is None
                     else _split(p.max, index, workers))
            for p in self.pools)
        return replace(self, pools=sliced)


class _Pool:
    """Resolved pool state: ordered member names + provision floor."""
    __slots__ = ("node_class", "names", "min_n")

    def __init__(self, node_class: str, names: List[str], min_n: int):
        self.node_class = node_class
        self.names = names
        self.min_n = min_n


class Autoscaler:
    """The live daemon: arm once per run, read ``counters()`` after."""

    def __init__(self, sim: Sim, cluster: Cluster, policy: AutoscalePolicy,
                 cluster_cfg=None,
                 pending_fn: Optional[Callable[[], int]] = None):
        if policy.interval_s <= 0:
            raise ValueError("interval_s must be positive")
        if policy.pending_threshold < 1:
            raise ValueError("pending_threshold must be >= 1")
        if policy.sustain_s < 0 or policy.idle_s < 0:
            raise ValueError("sustain_s and idle_s must be >= 0")
        if policy.scale_step < 1:
            raise ValueError("scale_step must be >= 1")
        if not (0.0 < policy.min_frac <= 1.0):
            raise ValueError("min_frac must be in (0, 1]")
        if policy.start_after_s < 0:
            raise ValueError("start_after_s must be >= 0")
        self.sim = sim
        self.cluster = cluster
        self.policy = policy
        self.pending_fn = pending_fn
        self.ticks = 0
        self.scale_up_events = 0       # ticks that provisioned >= 1 node
        self.scale_down_events = 0     # ticks that drained >= 1 node
        self.nodes_provisioned = 0
        self.nodes_deprovisioned = 0
        self.pods_drained = 0          # residents disrupted by scale-down
        self._above_since: Optional[float] = None
        self._idle_since: Dict[str, float] = {}
        self._pools = self._resolve_pools(cluster_cfg)
        # shrink to each pool's floor before the run starts: the max
        # roster is materialized (fixed native indices) but only the
        # floor is paid for until pressure shows up
        for pool in self._pools:
            full = self._class_names[pool.node_class]
            for name in full[pool.min_n:]:
                cluster.deprovision_node(name)
        # the shrink runs at t=0 (zero cost accrued at full size): the
        # run's peak/low start from the floor, not the materialized max
        cluster._prov_peak = cluster._prov_low = cluster._prov_nodes
        sim.after(policy.start_after_s + policy.interval_s, self._tick,
                  daemon=True, note="autoscaler")

    # ---- pool resolution --------------------------------------------------
    def _resolve_pools(self, cluster_cfg) -> List[_Pool]:
        roster = [n.name for n in self.cluster._node_seq]
        if cluster_cfg is not None:
            labels = calibration.node_class_names(cluster_cfg)
            if len(labels) != len(roster):
                raise ValueError(
                    f"cluster config declares {len(labels)} nodes but the "
                    f"cluster materialized {len(roster)}")
        else:
            labels = ("node",) * len(roster)
        by_class: Dict[str, List[str]] = {}
        for name, label in zip(roster, labels):
            by_class.setdefault(label, []).append(name)
        self._class_names = by_class
        pools: List[_Pool] = []
        if self.policy.pools:
            for p in self.policy.pools:
                names = by_class.get(p.node_class)
                if names is None:
                    raise ValueError(
                        f"unknown node class {p.node_class!r}; roster has "
                        f"{sorted(by_class)}")
                max_n = len(names) if p.max is None \
                    else max(0, min(p.max, len(names)))
                min_n = max(0, min(p.min, max_n))
                # members beyond max stay deprovisioned for the whole
                # run (shrunk below); scale-up only walks names[:max_n]
                pools.append(_Pool(p.node_class, names[:max_n], min_n))
        else:
            for label, names in by_class.items():
                floor = min(len(names),
                            max(1, math.ceil(
                                self.policy.min_frac * len(names))))
                pools.append(_Pool(label, names, floor))
        return pools

    # ---- the daemon loop --------------------------------------------------
    def _depth(self) -> int:
        """Admission-queue depth (runner wires the arbiter's pending
        map in) plus the cluster's unbound pod queue — both mean
        work waiting on capacity."""
        base = self.pending_fn() if self.pending_fn is not None else 0
        return base + len(self.cluster._pending_pods)

    def _tick(self):
        self.ticks += 1
        now = self.sim.now()
        self._track_idle(now)
        depth = self._depth()
        if depth >= self.policy.pending_threshold:
            if self._above_since is None:
                self._above_since = now
            # NOT reset after a scale-up: every further hot tick adds
            # scale_step more, so a persistent backlog reaches max
            if now - self._above_since + 1e-9 >= self.policy.sustain_s:
                self._scale_up()
        else:
            self._above_since = None
            if depth == 0:
                self._scale_down(now)
        self.sim.after(self.policy.interval_s, self._tick, daemon=True,
                       note="autoscaler")

    def _track_idle(self, now: float):
        """A node is idle when it holds zero bound resources (even
        virtual entry/exit pods request 50m/50Mi, so zero usage means
        zero resident pods).  First-seen-idle timestamps persist
        across ticks and clear the moment the node is busy again."""
        idle = self._idle_since
        nodes = self.cluster.nodes
        for pool in self._pools:
            for name in pool.names:
                node = nodes[name]
                if node.provisioned and not node.cpu_used \
                        and not node.mem_used:
                    if name not in idle:
                        idle[name] = now
                else:
                    idle.pop(name, None)

    def _scale_up(self):
        budget = self.policy.scale_step
        flipped = 0
        for pool in self._pools:
            if budget <= 0:
                break
            for name in pool.names:
                if budget <= 0:
                    break
                if not self.cluster.nodes[name].provisioned:
                    self.cluster.provision_node(name)
                    self._idle_since.pop(name, None)
                    budget -= 1
                    flipped += 1
        if flipped:
            self.scale_up_events += 1
            self.nodes_provisioned += flipped

    def _scale_down(self, now: float):
        flipped = 0
        cluster = self.cluster
        for pool in self._pools:
            n_prov = sum(1 for nm in pool.names
                         if cluster.nodes[nm].provisioned)
            for name in reversed(pool.names):
                if n_prov <= pool.min_n or cluster._prov_nodes <= 1:
                    break
                node = cluster.nodes[name]
                if not node.provisioned:
                    continue
                since = self._idle_since.get(name)
                if since is None \
                        or now - since + 1e-9 < self.policy.idle_s:
                    continue
                self.pods_drained += cluster.deprovision_node(name)
                self._idle_since.pop(name, None)
                n_prov -= 1
                flipped += 1
        if flipped:
            self.scale_down_events += 1
            self.nodes_deprovisioned += flipped

    def counters(self) -> dict:
        return {"ticks": self.ticks,
                "scale_up_events": self.scale_up_events,
                "scale_down_events": self.scale_down_events,
                "nodes_provisioned": self.nodes_provisioned,
                "nodes_deprovisioned": self.nodes_deprovisioned,
                "pods_drained": self.pods_drained,
                "managed_nodes": sum(len(p.names) for p in self._pools),
                "floor_nodes": sum(p.min_n for p in self._pools),
                "interval_s": self.policy.interval_s,
                "pending_threshold": self.policy.pending_threshold,
                "sustain_s": self.policy.sustain_s,
                "idle_s": self.policy.idle_s}
