"""Flash attention forward — Pallas TPU kernel.

Tiling: grid (batch*heads, q_blocks, kv_blocks), kv minor (TPU executes
the grid sequentially minor-to-major, so the VMEM scratch accumulators
persist across the kv sweep of each q block). Per grid step the kernel
holds one (block_q, hd) query tile and one (block_k, hd) KV tile in
VMEM and maintains the online-softmax running (m, l, acc) — the same
algorithm as models/attention.chunked_attention, with O(block_q *
block_k) live scores.

MXU alignment: block_q/block_k default 128 and hd is 64..256 for every
assigned arch — all multiples of the 128-lane MXU tiles (64 via lane
packing). Causally-dead kv tiles are skipped with pl.when (the §Perf
block-skipping the pure-jnp path lacks).

Validated on CPU with interpret=True against kernels/ref.attention_ref
(see tests/test_kernels.py); on TPU the same call compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            kv_blocks: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causally-dead tile: every k position strictly after every q position
    live = (not causal) or (j * block_k <= i * block_q + (block_q - 1))

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(j == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q,k,v: (B, S, H, hd) full-H form -> (B, S, H, hd).

    interpret=True runs the kernel body in Python on CPU (the validation
    mode for this container); pass interpret=False on real TPU.
    """
    Bz, S, H, hd = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    qf = q.transpose(0, 2, 1, 3).reshape(Bz * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(Bz * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(Bz * H, T, hd)
    kv_blocks = T // block_k
    grid = (Bz * H, S // block_q, kv_blocks)
    scale = 1.0 / (hd ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, kv_blocks=kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bz * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
            pltpu.VMEM((block_q,), jnp.float32),       # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),       # l (running denom)
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(Bz, H, S, hd).transpose(0, 2, 1, 3)
