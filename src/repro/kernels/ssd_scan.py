"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid (batch, n_chunks) with the chunk axis minor: the inter-chunk SSM
state (H, P, N) lives in VMEM scratch and persists across the
sequential chunk sweep (the TPU grid guarantees in-order execution).
Per chunk the kernel computes, entirely in VMEM:

  * the intra-chunk quadratic term (the "attention-like" dual form),
  * the chunk-boundary states,
  * the inter-chunk contribution from the carried state,

then updates the carried state — i.e. one fused kernel does what the
pure-jnp path (models/ssm.ssd_chunked) spreads over einsums + a
lax.scan, with no HBM round-trips for the decay/score intermediates.

VMEM budget @ chunk=128, H=80, P=64, N=128 (mamba2-2.7b):
  x tile 2.6MB(f32) + decay (c,c,H)->per-head loop avoided by einsum
  over (c,c) x (c,H) factorization: L = exp(cum_i - cum_j) is formed as
  (c, c, H) only when H<=8; otherwise the kernel folds the decay into
  B/x first (seg form), keeping the largest live tensor at
  max(c*c, c*H*P) f32 ~ 2.6MB. Fits the ~16MB VMEM comfortably.

Validated in interpret mode against kernels/ref.ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, st_out_ref, state_ref,
            *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (c, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (c, H)
    A = A_ref[...].astype(jnp.float32)        # (H,)
    Bm = B_ref[0].astype(jnp.float32)         # (c, N)
    Cm = C_ref[0].astype(jnp.float32)         # (c, N)

    dA = dt * A[None, :]                      # (c, H) log-decay
    cum = jnp.cumsum(dA, axis=0)              # (c, H)

    # ---- intra-chunk (dual / attention-like form) ----------------------
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (c, c)
    decay = jnp.exp(cum[:, None, :] - cum[None, :, :])           # (c, c, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (ii >= jj)
    w = jnp.where(tri[:, :, None], CB[:, :, None] * decay, 0.0)  # (c, c, H)
    w = w * dt[None, :, :]
    y_diag = jnp.einsum("ijh,jhp->ihp", w, x)

    # ---- inter-chunk contribution from carried state --------------------
    state = state_ref[...]                                        # (H, P, N)
    y_off = jnp.einsum("in,ih,hpn->ihp", Cm, jnp.exp(cum), state)

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # ---- state update ----------------------------------------------------
    seg = jnp.exp(cum[-1:, :] - cum) * dt                         # (c, H)
    st_chunk = jnp.einsum("jn,jh,jhp->hpn", Bm, seg, x)
    chunk_decay = jnp.exp(cum[-1, :])                             # (H,)
    new_state = chunk_decay[:, None, None] * state + st_chunk
    state_ref[...] = new_state
    st_out_ref[0] = new_state                 # last write = final state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """Chunked SSD. x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,n).

    Returns (y:(b,s,h,p), final_state:(b,h,p,n)). interpret=True is the
    CPU validation mode; on TPU pass interpret=False.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (b, nc)

    y, st = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda i, c: (i, c, 0)),
            pl.BlockSpec((h,), lambda i, c: (0,)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i, c: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, st
