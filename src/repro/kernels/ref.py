"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernel tests assert against
(``tests/test_kernels.py`` sweeps shapes/dtypes with
``np.testing.assert_allclose``). They are intentionally the simplest
possible formulations — O(S^2) attention, step-by-step SSD recurrence —
NOT the chunked/blocked production paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """Dense reference attention. q,k,v: (B, S, H, hd) (full-H form)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        S, T = s.shape[-2], s.shape[-1]
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32)).astype(v.dtype)


def ssd_ref(x, dt, A, B, C):
    """Sequential SSD recurrence (the literal state-space definition).

    x: (b, s, h, p)  dt: (b, s, h)  A: (h,)  B, C: (b, s, n)
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).

      state_t = exp(dt_t * A) * state_{t-1} + dt_t * B_t (x) x_t
      y_t     = C_t . state_t
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    f32 = jnp.float32

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt.astype(f32) * A.astype(f32))          # (b, h)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt.astype(f32),
                         Bt.astype(f32), xt.astype(f32))
        state = dA[:, :, None, None] * state + dBx
        y = jnp.einsum("bn,bhpn->bhp", Ct.astype(f32), state)
        return state, y

    init = jnp.zeros((b, h, p, n), f32)
    final, ys = jax.lax.scan(
        step, init,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), B.swapaxes(0, 1), C.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), final
