"""Jit'd dispatch wrappers for the Pallas kernels.

Backends:
  'pallas'     — native TPU lowering (production target)
  'interpret'  — Pallas interpret mode (kernel body on CPU; validation)
  'jnp'        — the pure-jnp production paths (models/attention,
                 models/ssm), used by the distributed dry-run
  'auto'       — pallas on TPU, jnp elsewhere
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ssd_scan as ssd_mod
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def attention(q, k, v, *, causal: bool = True, impl: str = "auto",
              block_q: int = 128, block_k: int = 128, chunk: int = 1024):
    """Full-H attention (B,S,H,hd)x3 -> (B,S,H,hd)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "pallas":
        return fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=False)
    if impl == "interpret":
        return fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=True)
    if impl == "jnp":
        if q.shape[1] > chunk:
            return attn_lib.chunked_attention(q, k, v, chunk=chunk,
                                              causal=causal)
        return attn_lib.full_attention(q, k, v, causal=causal)
    raise ValueError(impl)


def ssd(x, dt, A, B, C, *, chunk: int = 128, impl: str = "auto"):
    """Chunked SSD scan -> (y, final_state)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "pallas":
        return ssd_mod.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=False)
    if impl == "interpret":
        return ssd_scan_interpret(x, dt, A, B, C, chunk=chunk)
    if impl == "jnp":
        dtf = jnp.asarray(dt, jnp.float32)
        return ssm_lib.ssd_chunked(x, dtf, A, B, C, chunk)
    raise ValueError(impl)


def ssd_scan_interpret(x, dt, A, B, C, *, chunk: int = 128):
    return ssd_mod.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
