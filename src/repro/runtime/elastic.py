"""Elastic training: node loss -> mesh shrink -> checkpoint restore.

The ElasticRunner owns the full fault-tolerance loop the brief asks for
at 1000-node scale, demonstrated end-to-end on host devices:

  1. build a mesh from the currently-healthy device set,
  2. train with periodic async checkpoints,
  3. on a (simulated or injected) device failure, rebuild the mesh from
     the surviving devices, re-lower the train step, restore the last
     checkpoint INTO THE NEW SHARDINGS, and continue — the checkpoint
     layout is mesh-independent (see checkpoint/checkpointer.py).

The KubeAdaptor engine drives the same loop at the workflow level: a
NodeLost informer event fails the training task pod, the fault-
tolerance module recreates it, and the recreated payload calls
``resume()`` here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.models import RunConfig
from repro.optim.adamw import OptConfig
from repro.parallel.sharding import ShardingPolicy
from repro.runtime.train import (TrainRunConfig, build_train_step,
                                 init_sharded_state, state_shardings)
from repro.parallel.sharding import to_named
from repro.launch.mesh import make_mesh


def best_mesh_shape(n_devices: int, prefer_model: int = 0):
    """Largest (data, model) grid over n usable devices (model axis
    fixed if prefer_model given; else the squarest factorization)."""
    if prefer_model and n_devices % prefer_model == 0:
        return (n_devices // prefer_model, prefer_model)
    best = (n_devices, 1)
    for m in range(1, int(n_devices ** 0.5) + 1):
        if n_devices % m == 0:
            best = (n_devices // m, m)
    return best


@dataclass
class ElasticRunner:
    cfg: Any                          # ArchConfig
    B: int
    S: int
    ckpt_dir: str
    rc: RunConfig = field(default_factory=RunConfig)
    trc: TrainRunConfig = field(default_factory=TrainRunConfig)
    policy: ShardingPolicy = field(default_factory=ShardingPolicy)
    ckpt_every: int = 20
    prefer_model: int = 0
    events: List[str] = field(default_factory=list)

    def __post_init__(self):
        self.ckpt = Checkpointer(self.ckpt_dir)
        self.devices = list(jax.devices())
        self.state = None
        self._build()

    def _build(self, restore: bool = True):
        n = len(self.devices)
        if n > 1:
            shape = best_mesh_shape(n, self.prefer_model)
            axes = ("data", "model")
            self.mesh = make_mesh(shape, axes)
        else:
            self.mesh = None
        (self.step_fn, self.state_sds, self.batch_sds,
         self.st_sh, self.b_sh, self.model) = build_train_step(
            self.cfg, self.mesh, B=self.B, S=self.S, rc=self.rc,
            policy=self.policy, trc=self.trc)
        if self.state is None and restore and self.ckpt.latest_step() is not None:
            self.state = self.ckpt.restore(self.state_sds, shardings=self.st_sh)
            self.events.append(f"restored step={self.ckpt.latest_step()} "
                               f"mesh={getattr(self.mesh, 'shape', None)}")
        elif self.state is None:
            self.state = init_sharded_state(self.model, self.mesh, self.st_sh)
            self.events.append(f"init mesh={getattr(self.mesh, 'shape', None)}")

    # -- failure handling --------------------------------------------------
    def fail_devices(self, k: int = 1):
        """Simulate losing k devices (a node): shrink and restore."""
        self.ckpt.wait()
        survivors = self.devices[:-k]
        if not survivors:
            raise RuntimeError("no devices left")
        self.events.append(f"device failure: {len(self.devices)} -> "
                           f"{len(survivors)}")
        self.devices = survivors
        self.state = None
        self._build(restore=True)

    # -- training loop -------------------------------------------------------
    def run(self, data_iter, steps: int,
            on_step: Optional[Callable[[int, Dict], None]] = None,
            fail_at: Optional[int] = None, fail_devices: int = 1) -> Dict:
        from repro.data.pipeline import shard_batch
        losses = []
        done = 0
        while done < steps:
            if fail_at is not None and done == fail_at:
                self.fail_devices(fail_devices)
                fail_at = None
            batch = next(data_iter)
            batch = shard_batch(batch, self.mesh,
                                None if self.mesh is None else
                                jax.tree.map(lambda s: s.spec, self.b_sh))
            self.state, metrics = self.step_fn(self.state, batch)
            done += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            if on_step:
                on_step(done, metrics)
            if done % self.ckpt_every == 0 or done == steps:
                self.ckpt.save(self.state, int(self.state.step))
        self.ckpt.wait()
        return {"losses": losses, "events": list(self.events),
                "final_step": int(self.state.step)}
