"""ShapeDtypeStruct input stand-ins per (arch, shape) cell.

``input_specs`` never allocates device memory — the dry-run lowers
against these (the shannon/kernels pattern: weak-type-correct,
shardable placeholders).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    specs: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        specs["embeds"] = _sds((B, S, cfg.d_model), "bfloat16")
    else:
        specs["tokens"] = _sds((B, S), "int32")
        if cfg.frontend == "vision":
            specs["img_embeds"] = _sds((B, cfg.n_img_tokens, cfg.d_model), "bfloat16")
    specs["labels"] = _sds((B, S), "int32")
    return specs


def prefill_batch_specs(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    specs = train_batch_specs(cfg, B, S)
    specs.pop("labels")
    return specs


def decode_batch_specs(cfg: ArchConfig, B: int) -> Dict[str, Any]:
    if cfg.frontend == "audio":
        return {"embeds": _sds((B, 1, cfg.d_model), "bfloat16")}
    return {"tokens": _sds((B, 1), "int32")}


def input_specs(cfg: ArchConfig, shape: ShapeConfig, model=None) -> Dict[str, Any]:
    """All model inputs for one workload cell, as ShapeDtypeStructs.

    For decode cells this includes the KV/SSM cache of ``shape.seq_len``
    (the cell's definition: one new token against a cache of seq_len).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, B, S)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, B, S)}
    if shape.kind == "decode":
        assert model is not None, "decode specs need the model for cache shapes"
        cache = model.init_cache_eval_shape(B, S)
        return {"cache": cache, "batch": decode_batch_specs(cfg, B)}
    raise ValueError(shape.kind)
