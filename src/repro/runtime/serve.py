"""Serving steps: prefill (sequence -> cache) and decode (token + cache).

Both are built with explicit shardings so the decode cells of the
dry-run (`decode_32k`, `long_500k`) lower exactly what production would
run: one new token against a seq_len-deep cache.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import Model, RunConfig, build
from repro.parallel.mesh import make_constrain, pick_attn_shard
from repro.parallel.sharding import (ShardingPolicy, batch_specs, cache_specs,
                                     param_specs, to_named)
from repro.runtime.specs import decode_batch_specs, prefill_batch_specs


def build_prefill_step(cfg, mesh: Optional[Mesh], *, B: int, S: int,
                       rc: Optional[RunConfig] = None,
                       policy: Optional[ShardingPolicy] = None):
    """Returns (jitted, params_sds, batch_sds, param_sh, model)."""
    policy = policy or ShardingPolicy()
    rc = rc or RunConfig()
    if mesh is not None:
        rc = rc.replace(constrain=make_constrain(mesh, policy.r()),
                        attn_shard=pick_attn_shard(cfg, mesh))
    model = build(cfg, rc)
    params_sds = model.init_eval_shape()
    batch_sds = prefill_batch_specs(cfg, B, S)

    def prefill(params, batch):
        return model.prefill(params, batch)

    if mesh is None:
        return jax.jit(prefill), params_sds, batch_sds, None, model

    p_sh = to_named(param_specs(params_sds, mesh, policy), mesh)
    b_sh = to_named(batch_specs(batch_sds, mesh, policy), mesh)
    jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh), out_shardings=None)
    return jitted, params_sds, batch_sds, p_sh, model


def build_decode_step(cfg, shape_cfg, mesh: Optional[Mesh], *,
                      rc: Optional[RunConfig] = None,
                      policy: Optional[ShardingPolicy] = None):
    """Decode one token against a cache of shape_cfg.seq_len.

    Returns (jitted, params_sds, cache_sds, batch_sds, shardings, model)."""
    policy = policy or ShardingPolicy()
    rc = rc or RunConfig()
    if mesh is not None:
        rc = rc.replace(constrain=make_constrain(mesh, policy.r()),
                        attn_shard=pick_attn_shard(cfg, mesh))
    model = build(cfg, rc)
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    params_sds = model.init_eval_shape()
    cache_sds = model.init_cache_eval_shape(B, S)
    batch_sds = decode_batch_specs(cfg, B)

    def decode(params, cache, batch):
        return model.decode(params, cache, batch)

    if mesh is None:
        jitted = jax.jit(decode, donate_argnums=(1,))
        return jitted, params_sds, cache_sds, batch_sds, None, model

    p_sh = to_named(param_specs(params_sds, mesh, policy), mesh)
    c_sh = to_named(cache_specs(cache_sds, mesh, cfg, shape_cfg, policy), mesh)
    b_sh = to_named(batch_specs(batch_sds, mesh, policy), mesh)
    jitted = jax.jit(decode, in_shardings=(p_sh, c_sh, b_sh),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
    return jitted, params_sds, cache_sds, batch_sds, (p_sh, c_sh, b_sh), model
