"""Distributed train-step builder: pjit + FSDP/TP shardings + grad accum.

``build_train_step`` returns everything the launchers and the dry-run
need: the jitted step, eval-shape stand-ins for state/batch, and the
sharding trees (for device_put / checkpoint restore).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Model, RunConfig, build
from repro.optim.adamw import OptConfig, TrainState, apply_updates, init_state
from repro.parallel import compression as comp_lib
from repro.parallel.mesh import make_constrain, pick_attn_shard
from repro.parallel.sharding import (ShardingPolicy, batch_specs, param_specs,
                                     to_named)
from repro.runtime.specs import train_batch_specs


@dataclass(frozen=True)
class TrainRunConfig:
    opt: OptConfig = field(default_factory=OptConfig)
    grad_accum: int = 1
    compression: Optional[str] = None    # None | "int8"


def make_train_step(model: Model, trc: TrainRunConfig):
    """Pure train step (no sharding — composable under jit or plain CPU)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch):
        if trc.grad_accum > 1:
            a = trc.grad_accum

            def split(x):
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / a, gsum)
            loss = lsum / a
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        if trc.compression == "int8":
            grads = comp_lib.quantize_dequantize_int8(grads)

        new_state, metrics = apply_updates(state, grads, trc.opt)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def state_shardings(params_sds, mesh: Mesh, policy: ShardingPolicy):
    p_specs = param_specs(params_sds, mesh, policy)
    return TrainState(params=p_specs,
                      m=jax.tree.map(lambda s: s, p_specs),
                      v=jax.tree.map(lambda s: s, p_specs),
                      step=P())


def build_train_step(cfg, mesh: Optional[Mesh], *, B: int, S: int,
                     rc: Optional[RunConfig] = None,
                     policy: Optional[ShardingPolicy] = None,
                     trc: Optional[TrainRunConfig] = None):
    """Returns (jitted_step, state_sds, batch_sds, state_sh, batch_sh, model)."""
    policy = policy or ShardingPolicy()
    trc = trc or TrainRunConfig()
    rc = rc or RunConfig()
    if mesh is not None:
        rc = rc.replace(constrain=make_constrain(mesh, policy.r()),
                        attn_shard=pick_attn_shard(cfg, mesh))
    model = build(cfg, rc)

    params_sds = model.init_eval_shape()
    state_sds = jax.eval_shape(init_state, params_sds)
    batch_sds = train_batch_specs(cfg, B, S)
    step_fn = make_train_step(model, trc)

    if mesh is None:
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        return jitted, state_sds, batch_sds, None, None, model

    st_sh = to_named(state_shardings(params_sds, mesh, policy), mesh)
    b_sh = to_named(batch_specs(batch_sds, mesh, policy), mesh)
    jitted = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
    return jitted, state_sds, batch_sds, st_sh, b_sh, model


def init_sharded_state(model: Model, mesh: Optional[Mesh], st_sh, seed: int = 0):
    """Initialise TrainState directly into its shardings (no host blowup)."""
    def make():
        return init_state(model.init(jax.random.PRNGKey(seed)))
    if mesh is None:
        return make()
    return jax.jit(make, out_shardings=st_sh)()
