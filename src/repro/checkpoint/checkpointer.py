"""Sharded checkpointing: npy-per-leaf + JSON manifest, async save,
reshard-on-restore.

Design points for the 1000-node brief:
  * layout-independent: leaves are saved as full logical arrays keyed by
    their pytree path, so a checkpoint written on a (16,16) mesh
    restores onto (8,16), (2,16,16), or 1 device — elastic shrink just
    passes different shardings to ``restore`` (runtime/elastic.py);
  * async: ``save`` returns immediately after device_get; serialization
    happens on a background thread (``wait()`` joins);
  * atomic: writes go to ``step_NNN.tmp`` and are renamed only after the
    manifest lands, so a crash mid-save never corrupts the latest step;
  * retention: ``keep`` most recent steps are retained.

On a real multi-host pod each process would write only its addressable
shards (process-local npy files + a global manifest); the single-host
container collapses that to one writer, which is noted here rather than
faked.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ---- save ------------------------------------------------------------
    def save(self, state: Any, step: int, blocking: bool = False):
        self.wait()
        host = {}
        for k, v in _flatten(state).items():
            arr = np.asarray(jax.device_get(v))
            true_dtype = str(jax.numpy.asarray(v).dtype)
            if arr.dtype.kind == "V":        # bf16 etc: not numpy-native
                arr = np.asarray(jax.device_get(
                    jax.numpy.asarray(v).astype(jax.numpy.float32)))
            host[k] = (arr, true_dtype)

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": {}}
            for i, (key, (arr, true_dtype)) in enumerate(sorted(host.items())):
                fname = f"leaf_{i:05d}.npy"
                np.save(tmp / fname, arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": true_dtype}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- restore -----------------------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``target`` (arrays or SDS).

        ``shardings``: optional matching pytree of NamedSharding — leaves
        are device_put directly into their (possibly NEW mesh's) layout.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_target = _flatten(target)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        restored = {}
        for key, spec in manifest["leaves"].items():
            if key not in flat_target:
                continue
            arr = np.load(d / spec["file"])
            sds = flat_target[key]
            if tuple(arr.shape) != tuple(sds.shape):
                raise ValueError(f"{key}: checkpoint {arr.shape} vs "
                                 f"target {sds.shape}")
            val = jax.numpy.asarray(arr).astype(spec["dtype"])
            sh = flat_sh.get(key)
            restored[key] = (jax.device_put(val, sh) if sh is not None
                             else val)
        missing = set(flat_target) - set(restored)
        if missing:
            raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
        # rebuild the pytree in target order
        leaves_paths = jax.tree_util.tree_flatten_with_path(target)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
                for path, _ in leaves_paths[0]]
        return jax.tree_util.tree_unflatten(
            leaves_paths[1], [restored[k] for k in keys])
