"""Logical-axis rules and sharding helpers.

Logical activation/param axes used across the codebase:
  dp    batch                  -> ("pod", "data")
  fsdp  param-storage shard    -> ("data",)   (ZeRO-3 style, gathered on use)
  tp    tensor-parallel         -> ("model",)
  sp    long-sequence shard     -> ("data",)   (524k KV caches, batch=1)

``resolve_spec`` drops any mesh axis that does not evenly divide the
corresponding dim, so one rule set serves every (arch x shape x mesh)
cell without divisibility landmines (e.g. batch=1 cells simply leave
the dp axes unused).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "dp": ("pod", "data"),
    "fsdp": ("data",),
    "tp": ("model",),
    "sp": ("data",),
}


def axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[n] for n in names if n in mesh.shape.keys()] or [1]))


def resolve_spec(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                 dims: Sequence[int], rules=None) -> P:
    """Logical axes + concrete dims -> PartitionSpec (divisibility-checked)."""
    rules = rules or DEFAULT_RULES
    out = []
    used = set()
    for ax, dim in zip(logical_axes, dims):
        if ax is None:
            out.append(None)
            continue
        mesh_axes = tuple(a for a in rules.get(ax, ()) if a in mesh.shape.keys()
                          and a not in used)
        size = axis_size(mesh, mesh_axes)
        if not mesh_axes or size <= 1 or dim % size != 0:
            # try a prefix that divides (e.g. dp=("pod","data") -> ("pod",))
            while mesh_axes and (dim % axis_size(mesh, mesh_axes) != 0):
                mesh_axes = mesh_axes[:-1]
            if not mesh_axes or dim % axis_size(mesh, mesh_axes) != 0:
                out.append(None)
                continue
        used.update(mesh_axes)
        out.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return P(*out)


def make_constrain(mesh: Mesh, rules=None):
    """RunConfig.constrain hook: constrain(x, logical_axes) -> x."""
    def constrain(x, logical_axes):
        if mesh is None:
            return x
        spec = resolve_spec(mesh, logical_axes, x.shape, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return constrain


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def pick_attn_shard(cfg, mesh: Optional[Mesh]) -> str:
    """'heads' TP when n_heads divides the tp axis, else q-sequence TP."""
    if mesh is None or not getattr(cfg, "n_heads", 0):
        return "heads"
    tp = mesh.shape.get("model", 1)
    return "heads" if cfg.n_heads % tp == 0 else "seq"
