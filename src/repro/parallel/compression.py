"""Gradient compression: per-tensor int8 quantization with error feedback.

``quantize_dequantize_int8`` is the stateless in-graph hook used by the
train step (models the bandwidth saving: the all-reduce payload would be
the int8 payload on a real fabric — XLA on TPU can fuse the scale).

``ErrorFeedback`` keeps the residual across steps so compression error
doesn't accumulate (Karimireddy et al.-style EF); used by the
fault-tolerance tests and available to the launcher via
``TrainRunConfig(compression="int8")`` + feedback state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q8(x):
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_dequantize_int8(grads):
    """Simulate int8-compressed gradient exchange (stateless)."""
    def f(g):
        if g.ndim < 2:          # tiny tensors aren't worth compressing
            return g.astype(jnp.float32)
        q, s = _q8(g)
        return _dq8(q, s)
    return jax.tree.map(f, grads)


def ef_compress(grads, residual) -> Tuple[Any, Any]:
    """Error-feedback int8: returns (decompressed_grads, new_residual)."""
    def f(g, r):
        if g.ndim < 2:
            return g.astype(jnp.float32), jnp.zeros_like(r)
        corrected = g.astype(jnp.float32) + r
        q, s = _q8(corrected)
        dq = _dq8(q, s)
        return dq, corrected - dq
    out = jax.tree.map(f, grads, residual)
    dq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return dq, res


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
