"""Per-arch parameter & cache PartitionSpecs.

One rule table maps parameter names to logical axes; ``param_specs``
walks the (possibly stacked) param tree and emits a matching
PartitionSpec tree with divisibility checked against the actual mesh.

Policies:
  train:  TP on 'model' + FSDP storage on 'data' (ZeRO-3-style; XLA
          all-gathers inside the layer scan). Optimizer state mirrors
          param specs.
  serve:  same TP; FSDP kept for storage unless ``fsdp=False`` —
          decode-latency resharding is a recorded §Perf knob.

Cache specs: batch on dp; kv-heads on tp when divisible else head_dim;
long-context (batch=1) shards the cache *sequence* axis on 'data' (SP).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.mesh import DEFAULT_RULES, axis_size, resolve_spec


@dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True          # shard param storage over 'data'
    rules: Any = None

    def r(self):
        return self.rules or DEFAULT_RULES


# parameter-name -> logical axes, by trailing dims (leading L handled on top)
# key: substring of the leaf path's last key
_PARAM_AXES = {
    # 2-D (in, out) projections: fsdp on input dim, tp on output dim
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "w1": ("fsdp", "tp"), "w3": ("fsdp", "tp"),
    "in_x": ("fsdp", "tp"), "in_z": ("fsdp", "tp"), "in_dt": ("fsdp", "tp"),
    # (out, in) projections: tp on input dim, fsdp on output dim
    "wo": ("tp", "fsdp"), "w2": ("tp", "fsdp"), "out": ("tp", "fsdp"),
    # small projections (N ~ 64-128): fsdp only
    "in_B": ("fsdp", None), "in_C": ("fsdp", None),
    "router": ("fsdp", None),
    # embeddings: vocab on tp, d_model on fsdp
    "embed": ("tp", "fsdp"), "head": ("tp", "fsdp"),
    # depthwise conv (W, C): channel on tp
    "conv_x": (None, "tp"), "conv_B": (None, None), "conv_C": (None, None),
    # 1-D
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "gate_norm": ("tp",),
    "ln": (None,), "ln1": (None,), "ln2": (None,), "final_norm": (None,),
    "A_log": (None,), "dt_bias": (None,), "D_skip": (None,),
    "gate": (),
}

# MoE expert tensors are 3-D (E, in, out): experts on tp, fsdp on 'in'
_MOE_AXES = {
    "w1": ("tp", "fsdp", None), "w3": ("tp", "fsdp", None),
    "w2": ("tp", "fsdp", None),
}


def _leaf_axes(path, leaf) -> tuple:
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1] if keys else ""
    in_moe = "moe" in keys
    stacked = keys and keys[0] in ("blocks", "cross_blocks")
    if in_moe and name in _MOE_AXES and leaf.ndim - (1 if stacked else 0) == 3:
        axes = _MOE_AXES[name]
    elif name in _PARAM_AXES:
        axes = _PARAM_AXES[name]
    else:
        axes = (None,) * leaf.ndim
        stacked = False
    expect = len(axes) + (1 if stacked else 0)
    if leaf.ndim != expect:  # unknown layout: replicate rather than crash
        return (None,) * leaf.ndim
    return ((None,) + tuple(axes)) if stacked else tuple(axes)


def param_specs(params_shape, mesh: Mesh, policy: ShardingPolicy):
    """PartitionSpec tree matching ``params_shape`` (arrays or SDS)."""
    rules = dict(policy.r())
    if not policy.fsdp:
        rules = dict(rules, fsdp=())

    def spec(path, leaf):
        axes = _leaf_axes(path, leaf)
        return resolve_spec(mesh, axes, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def cache_specs(cache_shape, mesh: Mesh, cfg, shape_cfg, policy: ShardingPolicy):
    """Decode-cache PartitionSpecs (see module docstring)."""
    rules = policy.r()
    long_ctx = shape_cfg.global_batch < axis_size(mesh, rules["dp"])

    def spec(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1] if keys else ""
        if leaf.ndim == 0 or name == "pos":
            return P()
        if name in ("k", "v", "xk", "xv"):
            # (L, B, T, K, hd)
            axes = [None, "dp", None, "tp", None]
            if leaf.shape[3] % axis_size(mesh, rules["tp"]) != 0:
                axes[3], axes[4] = None, "tp"
            if long_ctx and name in ("k", "v"):
                axes[1], axes[2] = None, "sp"
            return resolve_spec(mesh, axes, leaf.shape, rules)
        if "ssm" in keys:
            # ssd (L,B,H,P,N) / conv tails (L,B,W-1,C)
            if leaf.ndim == 5:
                return resolve_spec(mesh, (None, "dp", "tp", None, None), leaf.shape, rules)
            return resolve_spec(mesh, (None, "dp", None, "tp"), leaf.shape, rules)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def batch_specs(batch_shape, mesh: Mesh, policy: ShardingPolicy):
    """Input batch PartitionSpecs: batch dim on dp, rest replicated."""
    rules = policy.r()

    def spec(_, leaf):
        axes = ["dp"] + [None] * (leaf.ndim - 1)
        return resolve_spec(mesh, axes, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def to_named(tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
