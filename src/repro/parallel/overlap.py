"""Compute/communication overlap primitives.

``ring_all_reduce`` decomposes an all-reduce into reduce-scatter +
all-gather rings built from ``jax.lax.ppermute`` steps inside a scan.
Expressed this way, XLA's latency-hiding scheduler can interleave the
2(n-1) permute steps with independent compute (e.g. the next
microbatch's backward), which a single monolithic all-reduce cannot —
this is the classic Megatron/MaxText overlap trick and a §Perf knob.

Use under ``jax.shard_map`` over the axis being reduced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def ring_all_reduce(x, axis_name: str):
    """All-reduce over ``axis_name`` as RS + AG rings of ppermutes.

    x: per-device array whose leading dim is divisible by the axis size.
    Returns the summed array (same shape), like lax.psum(x, axis_name).
    """
    n = jax.lax.axis_size(axis_name)
    if n == 1:
        return x
    i = jax.lax.axis_index(axis_name)
    chunks = x.reshape((n, -1) + x.shape[1:])
    perm = [(d, (d + 1) % n) for d in range(n)]

    # --- reduce-scatter: at step s, device i forwards partial chunk
    # (i - s) mod n and folds the incoming partial into (i - s - 1) mod n.
    # After n-1 steps device i owns the fully-reduced chunk (i+1) mod n.
    def rs_step(carry, s):
        c = carry
        send = jnp.take(c, (i - s) % n, axis=0)
        recv = jax.lax.ppermute(send, axis_name, perm)
        c = c.at[(i - s - 1) % n].add(recv)
        return c, None

    chunks, _ = jax.lax.scan(rs_step, chunks, jnp.arange(n - 1))

    # --- all-gather: rotate the reduced chunks around the ring.
    def ag_step(carry, s):
        c = carry
        send = jnp.take(c, (i + 1 - s) % n, axis=0)
        recv = jax.lax.ppermute(send, axis_name, perm)
        c = c.at[(i - s) % n].set(recv)
        return c, None

    chunks, _ = jax.lax.scan(ag_step, chunks, jnp.arange(n - 1))
    return chunks.reshape(x.shape)


def psum_overlapped(x, axis_name: str, use_ring: bool):
    return ring_all_reduce(x, axis_name) if use_ring else jax.lax.psum(x, axis_name)
