"""Data pipeline: deterministic synthetic LM streams + prefetch + sharding.

Synthetic data follows a Zipfian unigram over the vocab with a simple
Markov twist (next token depends on current) so loss curves actually
descend — enough signal for the end-to-end training examples while
remaining fully offline and reproducible.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


@dataclass
class DataConfig:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLM:
    """Deterministic synthetic token stream: dict batches of tokens/labels."""

    def __init__(self, cfg: DataConfig, frontend: Optional[str] = None,
                 d_model: int = 0, n_img_tokens: int = 0):
        self.cfg = cfg
        self.frontend = frontend
        self.d_model = d_model
        self.n_img_tokens = n_img_tokens
        self.rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.p = p / p.sum()

    def _tokens(self) -> np.ndarray:
        c = self.cfg
        base = self.rng.choice(c.vocab_size, size=(c.batch, c.seq_len + 1), p=self.p)
        # Markov twist: even positions repeat (prev+1) mod V with prob .5
        flip = self.rng.random((c.batch, c.seq_len)) < 0.5
        nxt = (base[:, :-1] + 1) % c.vocab_size
        base[:, 1:] = np.where(flip, nxt, base[:, 1:])
        return base.astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        toks = self._tokens()
        batch: Dict[str, np.ndarray] = {"labels": toks[:, 1:]}
        if self.frontend == "audio":
            emb = self.rng.standard_normal((c.batch, c.seq_len, self.d_model))
            batch["embeds"] = emb.astype(np.float32)
        else:
            batch["tokens"] = toks[:, :-1]
            if self.frontend == "vision":
                img = self.rng.standard_normal((c.batch, self.n_img_tokens, self.d_model))
                batch["img_embeds"] = img.astype(np.float32)
        return batch


def shard_batch(batch, mesh: Optional[Mesh], specs=None):
    """Host batch -> device arrays with NamedSharding (or plain arrays)."""
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, specs)


class Prefetcher:
    """Background-thread prefetch (depth N) over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def work():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=work, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
